"""AOT path: lowered HLO artifacts agree with the eager model and the
manifest matches the real signatures."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.model import Config

TINY = Config(vocab=32, d_model=16, n_heads=2, n_layers=1, seq_len=8, batch=2)


def test_build_modules_signature():
    names, params, modules = aot.build_modules(TINY, lr=0.1, seed=0)
    assert names == sorted(params)
    for mod_name, (fn, inputs, outputs) in modules.items():
        if mod_name == "predict":
            assert inputs[-1][0] == "tokens" and inputs[-1][1] == "data"
            assert outputs[0][0] == "logits"
            continue
        assert inputs[-2][0] == "tokens" and inputs[-2][1] == "data"
        assert inputs[-1][0] == "targets" and inputs[-1][1] == "label"
        assert outputs[0][0] == "loss"
    assert len(modules["train_step"][2]) == 1 + len(names)
    assert len(modules["eval_step"][2]) == 1


def test_lowered_train_step_matches_eager():
    names, params, modules = aot.build_modules(TINY, lr=0.1, seed=0)
    fn = modules["train_step"][0]
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, TINY.vocab, (TINY.batch, TINY.seq_len)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, TINY.vocab, (TINY.batch, TINY.seq_len)), jnp.float32)
    args = [params[n] for n in names] + [tok, tgt]
    flat = fn(*args)
    loss_eager, grads_eager = model.train_step(params, tok, tgt, TINY)
    np.testing.assert_allclose(float(flat[0]), float(loss_eager), rtol=1e-6)
    for n, g in zip(names, flat[1:]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(grads_eager[n]), rtol=1e-5)


def test_hlo_text_lowering_smoke():
    names, params, modules = aot.build_modules(TINY, lr=0.1, seed=0)
    fn = modules["eval_step"][0]
    specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    specs += [jax.ShapeDtypeStruct((TINY.batch, TINY.seq_len), jnp.float32)] * 2
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.startswith("HloModule")
    assert "parameter(0)" in text


def test_lower_all_writes_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.lower_all(TINY, lr=0.1, seed=0, out_dir=out, verbose=False)
    files = sorted(os.listdir(out))
    assert files == [
        "eval_step.hlo.txt",
        "manifest.txt",
        "params_init.bin",
        "predict.hlo.txt",
        "sgd_step.hlo.txt",
        "train_step.hlo.txt",
    ]
    manifest = open(os.path.join(out, "manifest.txt")).read()
    assert manifest.count("module ") == 4
    assert "input tokens data" in manifest
    # blob length equals the sum of param sizes
    names, params, _ = aot.build_modules(TINY, lr=0.1, seed=0)
    blob = np.fromfile(os.path.join(out, "params_init.bin"), np.float32)
    assert blob.size == model.num_params(params)
    # first param in sorted order leads the blob
    np.testing.assert_array_equal(
        blob[: params[names[0]].size], np.asarray(params[names[0]], np.float32).ravel()
    )


def test_shape_str():
    assert aot.shape_str(()) == "scalar"
    assert aot.shape_str((3,)) == "3"
    assert aot.shape_str((2, 4)) == "2,4"


def test_predict_matches_forward():
    names, params, modules = aot.build_modules(TINY, lr=0.1, seed=0)
    fn = modules["predict"][0]
    rng = np.random.default_rng(4)
    tok = jnp.asarray(rng.integers(0, TINY.vocab, (TINY.batch, TINY.seq_len)), jnp.float32)
    (logits,) = fn(*[params[n] for n in names], tok)
    from compile import model as M

    want = M.forward(params, tok, TINY)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-6)

"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle, swept
over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_linear import fused_linear
from compile.kernels.ref import ref_fused_linear, ref_softmax_xent
from compile.kernels.softmax_xent import softmax_xent

RNG = np.random.default_rng(1234)


def rand(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu", "gelu"]),
)
def test_fused_linear_matches_ref_fuzzed_shapes(m, k, n, act):
    x, w, b = rand((m, k)), rand((n, k)), rand((n,))
    got = fused_linear(x, w, b, act=act, bm=16, bn=16, bk=16)
    want = ref_fused_linear(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_linear_dtypes(dtype):
    x = rand((32, 48)).astype(dtype)
    w = rand((24, 48)).astype(dtype)
    b = rand((24,)).astype(dtype)
    got = fused_linear(x, w, b, act="relu", bm=16, bn=16, bk=16)
    want = ref_fused_linear(x, w, b, "relu")
    assert got.dtype == dtype
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (128, 128, 128)])
def test_fused_linear_tile_shapes_agree(bm, bn, bk):
    """Block shape is a schedule choice, never a numerics choice."""
    x, w, b = rand((50, 70)), rand((30, 70)), rand((30,))
    base = ref_fused_linear(x, w, b, "gelu")
    got = fused_linear(x, w, b, act="gelu", bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, base, rtol=3e-5, atol=3e-5)


def test_fused_linear_tile_aligned_exact_sizes():
    x, w, b = rand((128, 256)), rand((128, 256)), rand((128,))
    got = fused_linear(x, w, b)
    np.testing.assert_allclose(got, ref_fused_linear(x, w, b, "none"), rtol=3e-5, atol=3e-5)


def test_fused_linear_f32_accumulation_beats_naive_bf16():
    """bf16 inputs must accumulate in f32: the sum of many small terms
    stays accurate where a bf16 accumulator would lose it."""
    k = 4096
    x = jnp.full((1, k), 0.01, jnp.bfloat16)
    w = jnp.full((1, k), 0.01, jnp.bfloat16)
    b = jnp.zeros((1,), jnp.bfloat16)
    got = float(fused_linear(x, w, b, bm=1, bn=1, bk=128)[0, 0])
    # true value ~ 4096 * 1e-4 = 0.4096; bf16 accumulation collapses badly
    assert abs(got - 0.4096) / 0.4096 < 0.05, got


def test_fused_linear_rejects_bad_shapes():
    with pytest.raises(ValueError):
        fused_linear(rand((4, 8)), rand((3, 9)), rand((3,)))
    with pytest.raises(ValueError):
        fused_linear(rand((4, 8)), rand((3, 8)), rand((4,)))
    with pytest.raises(ValueError):
        fused_linear(rand((4, 8)), rand((3, 8)), rand((3,)), act="swish")


# ---------------------------------------------------------------------
# softmax_xent
# ---------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 80), v=st.integers(2, 50))
def test_softmax_xent_matches_ref_fuzzed_shapes(m, v):
    logits = rand((m, v), scale=3.0)
    labels = jnp.asarray(RNG.integers(0, v, size=(m,)), jnp.float32)
    loss, probs = softmax_xent(logits, labels, bm=16)
    rloss, rprobs = ref_softmax_xent(logits, labels.astype(jnp.int32))
    np.testing.assert_allclose(loss, rloss, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(probs, rprobs, rtol=2e-5, atol=2e-6)


def test_softmax_xent_rows_sum_to_one():
    logits = rand((33, 17), scale=5.0)
    labels = jnp.zeros((33,), jnp.float32)
    _, probs = softmax_xent(logits, labels, bm=8)
    np.testing.assert_allclose(np.asarray(probs).sum(axis=1), np.ones(33), rtol=1e-5)


def test_softmax_xent_numerical_stability():
    """Huge logits must not overflow (row-max subtraction)."""
    logits = jnp.asarray([[1e4, 1e4 - 5.0], [-1e4, -1e4 + 2.0]], jnp.float32)
    labels = jnp.asarray([0.0, 1.0], jnp.float32)
    loss, probs = softmax_xent(logits, labels, bm=2)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(probs)).all()


def test_softmax_xent_perfect_prediction_near_zero_loss():
    v = 8
    labels = jnp.asarray(RNG.integers(0, v, size=(16,)), jnp.float32)
    logits = 50.0 * jax.nn.one_hot(labels.astype(jnp.int32), v)
    loss, _ = softmax_xent(logits, labels, bm=8)
    assert float(loss) < 1e-4


def test_softmax_xent_rejects_bad_labels_shape():
    with pytest.raises(ValueError):
        softmax_xent(rand((4, 5)), jnp.zeros((3,), jnp.float32))

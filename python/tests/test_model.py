"""L2 model correctness: shapes, gradient math through the custom-VJP
Pallas wrappers, and optimization progress."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import Config

TINY = Config(vocab=64, d_model=32, n_heads=2, n_layers=1, seq_len=16, batch=4)
RNG = np.random.default_rng(7)


def batch(cfg, seed=0):
    r = np.random.default_rng(seed)
    tok = jnp.asarray(r.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)), jnp.float32)
    tgt = jnp.asarray(r.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)), jnp.float32)
    return tok, tgt


def test_forward_shapes():
    p = model.init_params(TINY, 0)
    tok, _ = batch(TINY)
    logits = model.forward(p, tok, TINY)
    assert logits.shape == (TINY.batch, TINY.seq_len, TINY.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_initial_loss_near_uniform():
    """Untrained loss should sit near ln(vocab)."""
    p = model.init_params(TINY, 0)
    tok, tgt = batch(TINY)
    loss = float(model.eval_step(p, tok, tgt, TINY))
    assert abs(loss - np.log(TINY.vocab)) < 1.0, loss


def test_param_count_matches_formula():
    p = model.init_params(TINY, 0)
    d, v, s, L = TINY.d_model, TINY.vocab, TINY.seq_len, TINY.n_layers
    expected = (
        v * d + s * d  # embeddings
        + L * (4 * d * d + 2 * d * TINY.d_ff + TINY.d_ff + d + 4 * d)  # blocks
        + 2 * d  # final ln
        + v * d + v  # head
    )
    assert model.num_params(p) == expected


def test_grads_cover_every_param_and_are_finite():
    p = model.init_params(TINY, 0)
    tok, tgt = batch(TINY)
    loss, grads = model.train_step(p, tok, tgt, TINY)
    assert set(grads) == set(p)
    for k, g in grads.items():
        assert g.shape == p[k].shape, k
        assert np.isfinite(np.asarray(g)).all(), k
    # embeddings of unused rows must have zero grad
    used = set(np.asarray(tok, np.int32).ravel().tolist())
    unused = next(i for i in range(TINY.vocab) if i not in used)
    np.testing.assert_allclose(np.asarray(grads["tok_emb"])[unused], 0.0)


@pytest.mark.parametrize("pname", ["head_b", "l0.fc1_b", "l0.ln1_g"])
def test_numeric_gradient_check(pname):
    p = model.init_params(TINY, 0)
    tok, tgt = batch(TINY)
    _, grads = model.train_step(p, tok, tgt, TINY)
    eps = 1e-3
    idx = 1
    e = np.zeros(p[pname].shape, np.float32).ravel()
    e[idx] = eps
    e = e.reshape(p[pname].shape)

    def loss_at(v):
        q = dict(p)
        q[pname] = v
        return float(model.eval_step(q, tok, tgt, TINY))

    num = (loss_at(p[pname] + e) - loss_at(p[pname] - e)) / (2 * eps)
    ana = float(np.asarray(grads[pname]).ravel()[idx])
    assert abs(num - ana) < 5e-3, f"{pname}: numeric {num} vs analytic {ana}"


def test_sgd_step_reduces_loss():
    p = model.init_params(TINY, 0)
    tok, tgt = batch(TINY)
    l0, p1 = model.sgd_step(p, tok, tgt, TINY, lr=0.5)
    l1 = model.eval_step(p1, tok, tgt, TINY)
    assert float(l1) < float(l0)


def test_ten_steps_memorize_batch():
    cfg = TINY
    p = model.init_params(cfg, 1)
    tok, tgt = batch(cfg, seed=3)
    losses = []
    for _ in range(10):
        loss, p = model.sgd_step(p, tok, tgt, cfg, lr=0.5)
        losses.append(float(loss))
    assert losses[-1] < 0.6 * losses[0], losses


def test_train_and_sgd_steps_agree():
    """sgd_step must equal train_step + manual update."""
    p = model.init_params(TINY, 0)
    tok, tgt = batch(TINY)
    lr = 0.1
    loss_a, grads = model.train_step(p, tok, tgt, TINY)
    manual = {k: p[k] - lr * grads[k] for k in p}
    loss_b, fused = model.sgd_step(p, tok, tgt, TINY, lr=lr)
    np.testing.assert_allclose(float(loss_a), float(loss_b), rtol=1e-6)
    for k in p:
        np.testing.assert_allclose(
            np.asarray(manual[k]), np.asarray(fused[k]), rtol=1e-5, atol=1e-6
        )


def test_causality():
    """Changing a future token must not affect past logits."""
    p = model.init_params(TINY, 0)
    tok, _ = batch(TINY)
    logits_a = model.forward(p, tok, TINY)
    tok_b = tok.at[:, -1].set((tok[:, -1] + 1) % TINY.vocab)
    logits_b = model.forward(p, tok_b, TINY)
    np.testing.assert_allclose(
        np.asarray(logits_a)[:, :-1], np.asarray(logits_b)[:, :-1], atol=1e-5
    )


def test_deterministic_init():
    a = model.init_params(TINY, 42)
    b = model.init_params(TINY, 42)
    c = model.init_params(TINY, 43)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert any(not np.array_equal(np.asarray(a[k]), np.asarray(c[k])) for k in a)

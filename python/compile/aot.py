"""AOT lowering: JAX -> HLO text artifacts for the Rust runtime.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out-dir ../artifacts

Produces, in the output directory:

* ``train_step.hlo.txt``  — (params..., tokens, targets) -> (loss, grads...)
* ``sgd_step.hlo.txt``    — (params..., tokens, targets) -> (loss, new params...)
* ``eval_step.hlo.txt``   — (params..., tokens, targets) -> (loss,)
* ``predict.hlo.txt``     — (params..., tokens) -> (logits,)
* ``params_init.bin``     — concatenated f32-LE initial parameters, in
  manifest input order (the Rust side splits it by the manifest shapes)
* ``manifest.txt``        — module signatures (see rust/src/runtime/artifacts.rs)

HLO **text** is the interchange format: jax >= 0.5 serializes protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import Config


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text (return_tuple=True so the
    Rust side always unwraps one tuple literal)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_str(shape) -> str:
    return "scalar" if len(shape) == 0 else ",".join(str(d) for d in shape)


def build_modules(cfg: Config, lr: float, seed: int):
    """Positional wrappers around the model's dict-based steps, plus
    their manifest metadata.  Returns (names, params, modules) where
    modules is {module_name: (fn, input_specs, output_specs)}."""
    params = model.init_params(cfg, seed)
    names = sorted(params)

    def unpack(args):
        p = dict(zip(names, args[: len(names)]))
        tokens, targets = args[len(names) :]
        return p, tokens, targets

    def ts(*args):
        p, tokens, targets = unpack(args)
        loss, grads = model.train_step(p, tokens, targets, cfg)
        return (loss, *[grads[n] for n in names])

    def ss(*args):
        p, tokens, targets = unpack(args)
        loss, new_p = model.sgd_step(p, tokens, targets, cfg, lr=lr)
        return (loss, *[new_p[n] for n in names])

    def es(*args):
        p, tokens, targets = unpack(args)
        return (model.eval_step(p, tokens, targets, cfg),)

    def pr(*args):
        p = dict(zip(names, args[: len(names)]))
        (tokens,) = args[len(names) :]
        return (model.forward(p, tokens, cfg),)

    inputs = [(n, "param", params[n].shape) for n in names]
    inputs.append(("tokens", "data", (cfg.batch, cfg.seq_len)))
    predict_inputs = list(inputs)
    inputs.append(("targets", "label", (cfg.batch, cfg.seq_len)))

    loss_out = [("loss", ())]
    modules = {
        "train_step": (ts, inputs, loss_out + [(f"grad:{n}", params[n].shape) for n in names]),
        "sgd_step": (ss, inputs, loss_out + [(f"new:{n}", params[n].shape) for n in names]),
        "eval_step": (es, inputs, loss_out),
        "predict": (
            pr,
            predict_inputs,
            [("logits", (cfg.batch, cfg.seq_len, cfg.vocab))],
        ),
    }
    return names, params, modules


def lower_all(cfg: Config, lr: float, seed: int, out_dir: str, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    names, params, modules = build_modules(cfg, lr, seed)

    specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    specs.append(jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.float32))

    manifest = [
        "# mixnet artifact manifest v1",
        f"# transformer-lm: vocab={cfg.vocab} d_model={cfg.d_model} "
        f"n_heads={cfg.n_heads} n_layers={cfg.n_layers} seq={cfg.seq_len} "
        f"batch={cfg.batch} lr={lr} seed={seed} "
        f"params={model.num_params(params)}",
        "# initial parameters: params_init.bin, f32-LE, param-input order",
    ]
    for mod_name, (fn, inputs, outputs) in modules.items():
        mod_specs = specs if len(inputs) == len(specs) else specs[:-1]
        lowered = jax.jit(fn).lower(*mod_specs)
        text = to_hlo_text(lowered)
        fname = f"{mod_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        if verbose:
            print(f"  {fname}: {len(text)} chars")
        manifest.append(f"module {mod_name}")
        manifest.append(f"hlo {fname}")
        for nm, kind, shape in inputs:
            manifest.append(f"input {nm} {kind} {shape_str(shape)}")
        for nm, shape in outputs:
            manifest.append(f"output {nm} {shape_str(shape)}")
        manifest.append("end")
        manifest.append("")

    import numpy as np

    blob = np.concatenate([np.asarray(params[n], np.float32).ravel() for n in names])
    blob.tofile(os.path.join(out_dir, "params_init.bin"))
    if verbose:
        print(f"  params_init.bin: {blob.size} f32 ({model.num_params(params)} params)")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest))
    if verbose:
        print(f"  manifest.txt: {len(modules)} modules")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=Config.vocab)
    ap.add_argument("--d-model", type=int, default=Config.d_model)
    ap.add_argument("--n-heads", type=int, default=Config.n_heads)
    ap.add_argument("--n-layers", type=int, default=Config.n_layers)
    ap.add_argument("--seq-len", type=int, default=Config.seq_len)
    ap.add_argument("--batch", type=int, default=Config.batch)
    ap.add_argument("--lr", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = Config(
        vocab=args.vocab,
        d_model=args.d_model,
        n_heads=args.n_heads,
        n_layers=args.n_layers,
        seq_len=args.seq_len,
        batch=args.batch,
    )
    print(f"lowering transformer-lm {cfg} -> {args.out_dir}")
    lower_all(cfg, args.lr, args.seed, args.out_dir)


if __name__ == "__main__":
    main()

"""Layer-2: the JAX compute graph that gets AOT-lowered for the Rust
coordinator — a decoder-only transformer language model whose hot spots
(the MLP-block linears and the softmax cross-entropy head) run through
the Layer-1 Pallas kernels.

Everything here is build-time only: `aot.py` lowers `train_step` /
`sgd_step` / `eval_step` to HLO text once, and the Rust runtime executes
the artifacts; Python never touches the training hot path.

The PJRT boundary carries f32 tensors only, so token ids cross it as
f32 and are cast to int32 on entry.
"""

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear
from .kernels.softmax_xent import softmax_xent

Params = Dict[str, jax.Array]


@dataclass(frozen=True)
class Config:
    """Transformer hyper-parameters (defaults sized for a single-core
    e2e run; scale d_model/n_layers up on real hardware — DESIGN §4)."""

    vocab: int = 512
    d_model: int = 256
    n_heads: int = 4
    n_layers: int = 2
    seq_len: int = 64
    batch: int = 16

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


# ---------------------------------------------------------------------
# Pallas-kernel linear with a hand-written VJP.
#
# `pallas_call` has no reverse-mode rule, so the fused kernel is wrapped
# in a custom_vjp whose backward pass re-uses the same kernel for both
# gradient matmuls (dx = dz @ w, dw = dz.T @ x) — every matmul FLOP in
# fwd AND bwd flows through the L1 kernel.
# ---------------------------------------------------------------------


def _act_grad(z, act):
    if act == "none":
        return jnp.ones_like(z)
    if act == "relu":
        return (z > 0).astype(z.dtype)
    # gelu (tanh approximation) derivative
    c = 0.7978845608028654
    t = jnp.tanh(c * (z + 0.044715 * z**3))
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * c * (1.0 + 3 * 0.044715 * z**2)


def _apply_act(z, act):
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "gelu":
        return 0.5 * z * (1.0 + jnp.tanh(0.7978845608028654 * (z + 0.044715 * z**3)))
    return z


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x, w, b, act="none"):
    """act(x @ w.T + b) through the Pallas kernel, differentiable."""
    return fused_linear(x, w, b, act=act)


def _linear_fwd(x, w, b, act):
    z = fused_linear(x, w, b, act="none")
    return _apply_act(z, act), (x, w, z)


def _linear_bwd(act, res, dy):
    x, w, z = res
    dz = dy * _act_grad(z, act)
    zeros_k = jnp.zeros((w.shape[1],), dz.dtype)
    zeros_n = jnp.zeros((x.shape[1],), dz.dtype)
    dx = fused_linear(dz, w.T, zeros_k, act="none")      # [m,n]@[n,k]
    dw = fused_linear(dz.T, x.T, zeros_n, act="none")    # [n,m]@[m,k]
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)


@jax.custom_vjp
def xent(logits, labels):
    """Mean softmax cross-entropy via the Pallas kernel, differentiable
    w.r.t. logits.  labels are float class ids (non-differentiable)."""
    loss, _ = softmax_xent(logits, labels)
    return loss


def _xent_fwd(logits, labels):
    loss, probs = softmax_xent(logits, labels)
    return loss, (probs, labels)


def _xent_bwd(res, dloss):
    probs, labels = res
    m, v = probs.shape
    onehot = jax.nn.one_hot(labels.astype(jnp.int32), v, dtype=probs.dtype)
    return (dloss * (probs - onehot) / m, None)


xent.defvjp(_xent_fwd, _xent_bwd)


# ---------------------------------------------------------------------
# model
# ---------------------------------------------------------------------


def init_params(cfg: Config, seed: int = 0) -> Params:
    """Initialize all parameters (scaled-normal, GPT-2-style)."""
    key = jax.random.PRNGKey(seed)
    p: Params = {}

    def nrm(key, shape, scale):
        return (scale * jax.random.normal(key, shape)).astype(jnp.float32)

    keys = iter(jax.random.split(key, 6 + 12 * cfg.n_layers))
    d = cfg.d_model
    p["tok_emb"] = nrm(next(keys), (cfg.vocab, d), 0.02)
    p["pos_emb"] = nrm(next(keys), (cfg.seq_len, d), 0.01)
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        p[pre + "ln1_g"] = jnp.ones((d,), jnp.float32)
        p[pre + "ln1_b"] = jnp.zeros((d,), jnp.float32)
        for nm in ("wq", "wk", "wv"):
            p[pre + nm] = nrm(next(keys), (d, d), d**-0.5)
        p[pre + "wo"] = nrm(next(keys), (d, d), (d * 2 * cfg.n_layers) ** -0.5)
        p[pre + "ln2_g"] = jnp.ones((d,), jnp.float32)
        p[pre + "ln2_b"] = jnp.zeros((d,), jnp.float32)
        p[pre + "fc1_w"] = nrm(next(keys), (cfg.d_ff, d), d**-0.5)
        p[pre + "fc1_b"] = jnp.zeros((cfg.d_ff,), jnp.float32)
        p[pre + "fc2_w"] = nrm(next(keys), (d, cfg.d_ff), (cfg.d_ff * 2 * cfg.n_layers) ** -0.5)
        p[pre + "fc2_b"] = jnp.zeros((d,), jnp.float32)
    p["lnf_g"] = jnp.ones((d,), jnp.float32)
    p["lnf_b"] = jnp.zeros((d,), jnp.float32)
    p["head_w"] = nrm(next(keys), (cfg.vocab, d), d**-0.5)
    p["head_b"] = jnp.zeros((cfg.vocab,), jnp.float32)
    return p


def num_params(p: Params) -> int:
    return sum(int(a.size) for a in p.values())


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(x, p, pre, cfg: Config):
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    q = linear(flat, p[pre + "wq"], jnp.zeros((d,), x.dtype))
    k = linear(flat, p[pre + "wk"], jnp.zeros((d,), x.dtype))
    v = linear(flat, p[pre + "wv"], jnp.zeros((d,), x.dtype))

    def split(a):
        return a.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (cfg.d_head**0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(b * s, d)
    y = linear(y, p[pre + "wo"], jnp.zeros((d,), x.dtype))
    return y.reshape(b, s, d)


def forward(p: Params, tokens, cfg: Config):
    """Logits [b, s, vocab] for f32 token ids [b, s]."""
    ids = tokens.astype(jnp.int32)
    b, s = ids.shape
    x = p["tok_emb"][ids] + p["pos_emb"][None, :s, :]
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        x = x + _attention(_layernorm(x, p[pre + "ln1_g"], p[pre + "ln1_b"]), p, pre, cfg)
        h = _layernorm(x, p[pre + "ln2_g"], p[pre + "ln2_b"]).reshape(b * s, cfg.d_model)
        h = linear(h, p[pre + "fc1_w"], p[pre + "fc1_b"], act="gelu")
        h = linear(h, p[pre + "fc2_w"], p[pre + "fc2_b"])
        x = x + h.reshape(b, s, cfg.d_model)
    x = _layernorm(x, p["lnf_g"], p["lnf_b"]).reshape(b * s, cfg.d_model)
    logits = linear(x, p["head_w"], p["head_b"])
    return logits.reshape(b, s, cfg.vocab)


def loss_fn(p: Params, tokens, targets, cfg: Config):
    """Mean next-token cross-entropy (targets are f32 class ids)."""
    logits = forward(p, tokens, cfg)
    b, s, v = logits.shape
    return xent(logits.reshape(b * s, v), targets.reshape(b * s))


def train_step(p: Params, tokens, targets, cfg: Config):
    """(loss, grads) — the KVStore-mode artifact (grads leave the step
    so the Rust coordinator can push them to the parameter server)."""
    loss, grads = jax.value_and_grad(loss_fn)(p, tokens, targets, cfg)
    return loss, grads


def sgd_step(p: Params, tokens, targets, cfg: Config, lr: float = 0.25):
    """(loss, new_params) — the single-worker artifact: the SGD update
    fuses into the lowered program so weights never leave the device
    between steps on a real accelerator."""
    loss, grads = jax.value_and_grad(loss_fn)(p, tokens, targets, cfg)
    new_p = {k: p[k] - lr * grads[k] for k in p}
    return loss, new_p


def eval_step(p: Params, tokens, targets, cfg: Config):
    """Loss only (validation)."""
    return loss_fn(p, tokens, targets, cfg)

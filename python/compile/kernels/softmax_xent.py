"""Fused softmax + cross-entropy as a Pallas kernel.

The TPU re-think of the classic CUDA reduction kernel: each grid step
keeps a ``(bm, V)`` slab of logits resident in VMEM and produces both the
probabilities and the per-row negative log-likelihood in one pass — the
row max, exp, normalizer, and label gather never round-trip to HBM
(where a CUDA kernel would stage partial reductions through shared
memory, the whole row simply fits in VMEM: 128 rows x 50k vocab x 4B =
25.6 MB is too big, so vocab stays blocked at <= 4096 columns per row
slab for a 2 MB working set; our LM vocab of 512 fits trivially).

Labels arrive as float (the PJRT boundary carries f32 only) and are cast
to int32 inside.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128  # rows per grid step on a real TPU

import os


def _tile_cap() -> int:
    """CPU-interpret row-tile cap (see fused_linear for the rationale)."""
    return int(os.environ.get("MIXNET_PALLAS_TILE", "2048"))


def _kernel(logits_ref, labels_ref, probs_ref, nll_ref):
    lg = logits_ref[...].astype(jnp.float32)
    lab = labels_ref[...].astype(jnp.int32)
    m = jnp.max(lg, axis=-1, keepdims=True)
    e = jnp.exp(lg - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    probs_ref[...] = e / z
    logp = lg - m - jnp.log(z)
    # gather log p[label] via one-hot dot (MXU-friendly; no dynamic gather)
    v = lg.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, lg.shape, 1) == lab[:, None])
    nll_ref[...] = -jnp.sum(jnp.where(onehot, logp, 0.0), axis=-1)


def _pad_rows(a, mult):
    rem = (-a.shape[0]) % mult
    if rem == 0:
        return a
    pad = [(0, rem)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def softmax_xent(logits, labels, bm=None, interpret=True):
    """(mean loss, probs) for logits [m, v] and labels [m] (float class ids).

    Matches ``ref.ref_softmax_xent`` to float32 tolerance.  ``bm`` rows
    are processed per grid step (default: min(m, MIXNET_PALLAS_TILE);
    pass ``BM`` when lowering for a real TPU).
    """
    m, v = logits.shape
    if labels.shape != (m,):
        raise ValueError(f"labels {labels.shape} != ({m},)")
    bm_ = min(bm or _tile_cap(), m)
    lp = _pad_rows(logits, bm_)
    # pad labels with -1: never matches an iota column -> nll contribution 0
    lab = _pad_rows(labels, bm_) if m % bm_ == 0 else jnp.concatenate(
        [labels, -jnp.ones(((-m) % bm_,), labels.dtype)]
    )
    mp = lp.shape[0]
    probs, nll = pl.pallas_call(
        _kernel,
        grid=(mp // bm_,),
        in_specs=[
            pl.BlockSpec((bm_, v), lambda i: (i, 0)),
            pl.BlockSpec((bm_,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bm_, v), lambda i: (i, 0)),
            pl.BlockSpec((bm_,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, v), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        interpret=interpret,
    )(lp, lab)
    loss = jnp.sum(nll[:m]) / m
    return loss, probs[:m].astype(logits.dtype)

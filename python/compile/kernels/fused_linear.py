"""Fused linear layer as a Pallas kernel: ``y = act(x @ w.T + b)``.

This is the "manually implemented big operation" of the paper (§3.1)
re-thought for the TPU model rather than ported from CUDA:

* The grid tiles the output into ``(bm, bn)`` blocks (one per MXU-feeding
  program instance) and streams the contraction dimension in ``bk`` slabs
  — the ``BlockSpec`` index maps express the HBM->VMEM schedule a CUDA
  kernel would express with threadblocks + shared-memory staging.
* Accumulation happens in float32 in the revisited output block
  (``preferred_element_type=jnp.float32``), the MXU contract for
  bfloat16/float32 inputs.
* Bias add + activation fuse into the final K step, so the activation
  never round-trips to HBM (the point of the fusion).

Runs under ``interpret=True`` everywhere in this repo: the CPU PJRT
plugin cannot execute Mosaic custom-calls, so real-TPU lowering is a
compile-only target (DESIGN §Hardware-Adaptation has the VMEM/MXU
estimates).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU tile sizes: 128 matches the MXU systolic array edge; VMEM use is
# bm*bk + bn*bk + bm*bn floats = 3*128*128*4B = 192 KiB << 16 MiB VMEM.
MXU_TILE = 128

# CPU-interpret tile cap: the interpreter pays a fixed cost per grid step
# (block slice in/out + predication), so artifacts lowered for the CPU
# runtime amortize it with the largest tile that covers the operand
# (measured: 122 ms -> 4.8 ms for a [1024,1024]x[1024,256] bwd matmul).
# Real-TPU lowering would pass bm=bn=bk=MXU_TILE explicitly.
import os


def _tile_cap() -> int:
    return int(os.environ.get("MIXNET_PALLAS_TILE", "2048"))


def _kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    """One (i, j, k) grid step: accumulate x[i,k] @ w[j,k].T into o[i,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        y = o_ref[...] + b_ref[...][None, :]
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        elif act == "gelu":
            y = 0.5 * y * (1.0 + jnp.tanh(0.7978845608028654 * (y + 0.044715 * y**3)))
        o_ref[...] = y


def _pad_to(a, axis, mult):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk", "interpret"))
def fused_linear(x, w, b, act="none", bm=None, bn=None, bk=None, interpret=True):
    """act(x @ w.T + b) with f32 accumulation.

    x: [m, k]; w: [n, k]; b: [n] -> [m, n] in x.dtype.
    Shapes need not be tile-aligned; inputs are zero-padded to the tile
    grid and the result sliced back.  Tile sizes default to
    min(operand, MIXNET_PALLAS_TILE) — pass bm/bn/bk explicitly (e.g.
    MXU_TILE) when lowering for a real TPU.
    """
    if act not in ("none", "relu", "gelu"):
        raise ValueError(f"unknown act '{act}'")
    m, k = x.shape
    n, k2 = w.shape
    if k2 != k or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    cap = _tile_cap()
    bm_ = min(bm or cap, m)
    bn_ = min(bn or cap, n)
    bk_ = min(bk or cap, k)
    xp = _pad_to(_pad_to(x, 0, bm_), 1, bk_)
    wp = _pad_to(_pad_to(w, 0, bn_), 1, bk_)
    bp = _pad_to(b, 0, bn_)
    mp, kp = xp.shape
    np_, _ = wp.shape
    nk = kp // bk_
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, act=act),
        grid=(mp // bm_, np_ // bn_, nk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn_,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n].astype(x.dtype)

"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every Pallas kernel in this package has a reference implementation here
written with nothing but `jax.numpy`; pytest asserts `assert_allclose`
between the two across shape/dtype sweeps (see python/tests).
"""

import jax.numpy as jnp


def ref_fused_linear(x, w, b, act="none"):
    """y = act(x @ w.T + b).

    x: [m, k] float; w: [n, k]; b: [n].  ``act``: "none" | "relu" | "gelu".
    Accumulation is performed in float32 regardless of input dtype (the
    MXU contract the Pallas kernel follows).
    """
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32).T) + b.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "gelu":
        # tanh approximation, matching the kernel
        y = 0.5 * y * (1.0 + jnp.tanh(0.7978845608028654 * (y + 0.044715 * y**3)))
    elif act != "none":
        raise ValueError(f"unknown act '{act}'")
    return y.astype(x.dtype)


def ref_softmax_xent(logits, labels):
    """(mean loss, probs) of softmax cross-entropy.

    logits: [m, v] float; labels: [m] int (class ids).
    Numerically stabilized by the row max, in float32.
    """
    lg = logits.astype(jnp.float32)
    m = jnp.max(lg, axis=-1, keepdims=True)
    e = jnp.exp(lg - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / z
    logp = lg - m - jnp.log(z)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(nll), probs.astype(logits.dtype)

//! Distributed data-parallel training (paper §2.3 / §3.3 / Figure 8).
//!
//! Part A (real): N "machines" (threads, each with its own engine and a
//! `DistKVStore` client) train an MLP on synthetic data shards through
//! the two-level parameter server over local TCP — exercising the real
//! wire protocol, level-1 aggregation, and consistency models.
//!
//! Part B (virtual): the calibrated cluster simulator replays the
//! paper's GoogLeNet/ILSVRC12 configuration at 1 and 10 machines in
//! virtual time (this host has one core; DESIGN §4).
//!
//! ```text
//! cargo run --release --example distributed_train [machines] [epochs]
//! ```

use std::sync::Arc;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::BindConfig;
use mixnet::graph::infer_shapes;
use mixnet::io::{synth::class_clusters, ArrayDataIter};
use mixnet::kvstore::server::{PsServer, ServerUpdater};
use mixnet::kvstore::{dist::DistKVStore, Consistency};
use mixnet::models::{by_name, mlp};
use mixnet::module::{Module, UpdateMode};
use mixnet::sim::{graph_flops, simulate, ClusterConfig};
use mixnet::Result;

const DIM: usize = 32;
const CLASSES: usize = 4;
const BATCH: usize = 32;

fn worker(machine: u32, machines: usize, addr: std::net::SocketAddr, epochs: usize) -> Result<f32> {
    let engine = create(EngineKind::Threaded, 2);
    let kv = Arc::new(DistKVStore::connect(
        addr,
        machine,
        1,
        Consistency::Sequential,
        engine.clone(),
    )?);
    // each machine sees a disjoint shard (seed by machine id)
    let ds = class_clusters(1024, CLASSES, DIM, 0.3, 1000 + machine as u64);
    let mut iter = ArrayDataIter::new(ds.features, ds.labels, &[DIM], BATCH, true, engine.clone());
    let model = mlp(&[64], DIM, CLASSES);
    let mut module = Module::new(model.symbol, engine);
    module.bind(BATCH, &[DIM], &model_shapes()?, BindConfig::default(), 7)?; // same seed: identical init
    let stats = module.fit(
        &mut iter,
        &UpdateMode::KvStore { store: kv.clone(), device: 0 },
        epochs,
    )?;
    kv.barrier()?;
    let _ = machines;
    Ok(stats.last().unwrap().accuracy)
}

fn model_shapes() -> Result<std::collections::HashMap<String, Vec<usize>>> {
    mlp(&[64], DIM, CLASSES).param_shapes(BATCH)
}

fn main() -> Result<()> {
    let machines: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(4);
    let epochs: usize = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(4);

    // ---- Part A: real two-level PS over TCP ------------------------
    println!("== part A: {machines} machines x {epochs} epochs over local TCP ==");
    let updater = ServerUpdater {
        lr: 0.4 / machines as f32,
        momentum: 0.9,
        weight_decay: 1e-4,
        rescale: 1.0,
    };
    let mut server = PsServer::start(0, machines, updater)?;
    let addr = server.addr();
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..machines as u32)
        .map(|m| std::thread::spawn(move || worker(m, machines, addr, epochs)))
        .collect();
    let mut accs = Vec::new();
    for h in handles {
        accs.push(h.join().expect("worker panicked")?);
    }
    let wall = t0.elapsed();
    println!(
        "  wall {:.2?}; per-machine final accuracy: {:?}",
        wall,
        accs.iter().map(|a| format!("{a:.3}")).collect::<Vec<_>>()
    );
    println!(
        "  server saw {} msgs / {:.1} KiB (level-1 aggregation: 1 push per machine-round)",
        server.messages_received(),
        server.bytes_received() as f64 / 1024.0
    );
    server.shutdown();
    assert!(accs.iter().all(|&a| a > 0.85), "distributed training failed to converge");

    // ---- Part B: virtual-time paper-scale replay --------------------
    println!("\n== part B: virtual-time GoogLeNet/ILSVRC12 (paper Figure 8) ==");
    let inception = by_name("inception-bn")?;
    let (g, vs) = inception.graph(1)?;
    let shapes = infer_shapes(&g, &vs)?;
    let fwd = graph_flops(&g, &shapes);
    let flops_per_image = 3.0 * fwd; // fwd+bwd ~ 3x fwd
    let grad_bytes = inception.num_params()? as f64 * 4.0;
    println!(
        "  model: {:.2} GFLOP/image fwd+bwd, {:.1} MB gradient",
        flops_per_image / 1e9,
        grad_bytes / 1e6
    );
    for machines in [1usize, 10] {
        let mut cfg = ClusterConfig::googlenet_paper(machines, flops_per_image, grad_bytes);
        cfg.passes = 12;
        let stats = simulate(&cfg);
        let s0 = &stats[0];
        println!(
            "  {machines:>2} machine(s): {:>8.0} s/pass | acc by pass: {}",
            s0.seconds,
            stats
                .iter()
                .step_by(2)
                .map(|s| format!("p{}={:.2}", s.pass, s.accuracy))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }
    println!("  (paper: 14K -> 1.4K s/pass; distributed crosses over after ~10 passes)");
    Ok(())
}

//! Quickstart: the paper's programming model in one file.
//!
//! 1. Imperative `NDArray` math (Figure 3) — lazily scheduled on the
//!    dependency engine.
//! 2. A declarative `Symbol` MLP (Figure 2), bound and trained with the
//!    paper's §2.2 mixed loop: symbolic `forward_backward()` plus an
//!    imperative weight update, both flowing through one engine.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::BindConfig;
use mixnet::io::{synth::class_clusters, ArrayDataIter};
use mixnet::module::{Module, UpdateMode};
use mixnet::optimizer::Sgd;
use mixnet::symbol::{Act, Symbol};
use mixnet::Result;

fn main() -> Result<()> {
    // ---- 1. imperative NDArray (paper Figure 3) --------------------
    let engine = create(EngineKind::Threaded, mixnet::engine::default_threads());
    let a = mixnet::ndarray::NDArray::ones(&[2, 3]);
    let b = a.mul_scalar(2.0); // lazy: pushed to the engine, returns now
    println!("(a * 2) = {:?}", b.to_vec()); // reading waits for the result

    // ---- 2. declarative Symbol (paper Figure 2) --------------------
    let mlp = Symbol::var("data")
        .fully_connected("fc1", 64)
        .activation("relu1", Act::Relu)
        .fully_connected("fc2", 4)
        .softmax_output("softmax");
    println!("mlp arguments: {:?}", mlp.list_arguments());

    // ---- 3. train on a synthetic 4-class problem -------------------
    let ds = class_clusters(2048, 4, 32, 0.25, 42);
    let mut iter =
        ArrayDataIter::new(ds.features, ds.labels, &[32], 64, true, engine.clone());

    let mut module = Module::new(mlp, engine.clone());
    let shapes = mixnet::models::mlp(&[64], 32, 4).param_shapes(64)?;
    module.bind(64, &[32], &shapes, BindConfig::default(), 7)?;

    println!("\n{:>5} {:>9} {:>9} {:>8}", "epoch", "loss", "accuracy", "sec");
    let stats = module.fit(
        &mut iter,
        &UpdateMode::Local(Arc::new(Sgd::with_momentum(0.2, 0.9, 1e-4))),
        6,
    )?;
    for s in &stats {
        println!("{:>5} {:>9.4} {:>9.3} {:>8.2}", s.epoch, s.loss, s.accuracy, s.seconds);
    }
    let last = stats.last().unwrap();
    assert!(last.accuracy > 0.9, "training failed to converge");

    // ---- 4. the §2.2 loop, spelled out ------------------------------
    // while(1) { net.forward_backward(); net.w -= eta * net.g }
    let exec = module.executor().unwrap();
    exec.forward_backward()?;
    for name in module.param_names() {
        let w = module.param(name).unwrap();
        let g = exec.grad(name).unwrap();
        w.sub_scaled_(g, 0.05); // imperative update on the same engine
    }
    engine.wait_all();
    println!("\nmixed symbolic+imperative step OK; final accuracy {:.3}", last.accuracy);
    Ok(())
}

//! End-to-end driver (DESIGN E6): train a transformer language model
//! through the full three-layer stack — Rust coordinator (this file)
//! executing the AOT-lowered JAX+Pallas artifacts via PJRT, with Python
//! nowhere on the hot path.
//!
//! Two update modes:
//! * `sgd` (default): the fused `sgd_step` artifact (loss + new params),
//!   single worker — the update itself was lowered into the HLO.
//! * `kvstore N`: N data-parallel workers run the `train_step` artifact
//!   (loss + grads) and synchronize through the level-1 KVStore with a
//!   registered SGD updater — the paper's §2.3 loop at the artifact level.
//!
//! Requires `make artifacts` (build-time Python, run once).
//!
//! ```text
//! cargo run --release --example train_transformer [steps] [sgd|kvstore] [workers]
//! ```

use std::path::Path;
use std::sync::Arc;

use mixnet::engine::{create, EngineKind};
use mixnet::kvstore::{Consistency, KVStore, LocalKVStore};
use mixnet::ndarray::NDArray;
use mixnet::optimizer::Sgd;
use mixnet::runtime::{Runtime, TensorKind};
use mixnet::util::Rng;
use mixnet::{Error, Result};

/// Synthetic corpus: a repeating-template byte stream with noise, so the
/// LM has real structure to learn (DESIGN §4: tiny-corpus substitution).
fn sample_batch(rng: &mut Rng, batch: usize, seq: usize, vocab: usize) -> (Vec<f32>, Vec<f32>) {
    let period = 16.min(vocab);
    let mut tokens = Vec::with_capacity(batch * (seq + 1));
    for _ in 0..batch {
        let phase = rng.below(period);
        for t in 0..=seq {
            // deterministic cycle with 10% noise
            let tok = if rng.next_f32() < 0.1 {
                rng.below(vocab)
            } else {
                (phase + t) % period
            };
            tokens.push(tok as f32);
        }
    }
    let mut data = Vec::with_capacity(batch * seq);
    let mut labels = Vec::with_capacity(batch * seq);
    for b in 0..batch {
        let row = &tokens[b * (seq + 1)..(b + 1) * (seq + 1)];
        data.extend_from_slice(&row[..seq]);
        labels.extend_from_slice(&row[1..]);
    }
    (data, labels)
}

/// Split the `params_init.bin` blob by the module's param input specs.
fn load_init_params(dir: &Path, spec: &mixnet::runtime::ModuleSpec) -> Result<Vec<Vec<f32>>> {
    let blob = std::fs::read(dir.join("params_init.bin"))
        .map_err(|e| Error::Runtime(format!("params_init.bin: {e} (run `make artifacts`)")))?;
    let floats: Vec<f32> =
        blob.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
    let mut out = Vec::new();
    let mut off = 0usize;
    for ts in &spec.inputs {
        if ts.kind == TensorKind::Param {
            if off + ts.size() > floats.len() {
                return Err(Error::Runtime("params_init.bin too short".into()));
            }
            out.push(floats[off..off + ts.size()].to_vec());
            off += ts.size();
        }
    }
    if off != floats.len() {
        return Err(Error::Runtime(format!(
            "params_init.bin has {} extra floats — artifacts out of date?",
            floats.len() - off
        )));
    }
    Ok(out)
}

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(300);
    let mode = std::env::args().nth(2).unwrap_or_else(|| "sgd".into());
    let workers: usize = std::env::args().nth(3).and_then(|a| a.parse().ok()).unwrap_or(2);

    let dir = Path::new("artifacts");
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let programs = rt.load_dir(dir)?;
    let (step_prog, eval_prog) = match mode.as_str() {
        "sgd" => (&programs["sgd_step"], &programs["eval_step"]),
        "kvstore" => (&programs["train_step"], &programs["eval_step"]),
        other => return Err(Error::Config(format!("unknown mode '{other}'"))),
    };
    let spec = step_prog.spec().clone();
    let param_idx = spec.input_indices(TensorKind::Param);
    let (batch, seq) = {
        let d = &spec.inputs[*spec.input_indices(TensorKind::Data).first().unwrap()];
        (d.shape[0], d.shape[1])
    };
    // vocab from the head bias parameter
    let vocab = spec.inputs[param_idx[0]].shape[0]; // head_b is first sorted param
    let mut params = load_init_params(dir, &spec)?;
    let n_params: usize = params.iter().map(Vec::len).sum();
    println!(
        "transformer-lm: {n_params} params, batch {batch} x seq {seq}, vocab {vocab}, \
         {steps} steps, mode {mode}"
    );

    let mut rng = Rng::seed_from_u64(0x5eed);
    let mut curve: Vec<(usize, f32)> = Vec::new();
    let t0 = std::time::Instant::now();

    match mode.as_str() {
        "sgd" => {
            for step in 1..=steps {
                let (data, labels) = sample_batch(&mut rng, batch, seq, vocab);
                let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
                inputs.push(&data);
                inputs.push(&labels);
                let outs = step_prog.run(&inputs)?;
                let loss = outs[0][0];
                for (p, new) in params.iter_mut().zip(outs.into_iter().skip(1)) {
                    *p = new; // fused update: outputs ARE the new params
                }
                if step == 1 || step % 10 == 0 {
                    curve.push((step, loss));
                    println!("step {step:>4}  loss {loss:.4}  ({:.2?} elapsed)", t0.elapsed());
                }
            }
        }
        "kvstore" => {
            // level-1 KVStore with a registered SGD updater; `workers`
            // device slots push grads per round (paper §2.3).
            let engine = create(EngineKind::Threaded, 2);
            let kv = LocalKVStore::new(
                engine.clone(),
                workers,
                Arc::new(Sgd::new(0.25 / workers as f32)),
                Consistency::Sequential,
            );
            let names: Vec<&str> =
                param_idx.iter().map(|&i| spec.inputs[i].name.as_str()).collect();
            for (name, p) in names.iter().zip(&params) {
                kv.init(name, &NDArray::from_vec_on(&[p.len()], p.clone(), engine.clone()))?;
            }
            let weight_bufs: Vec<NDArray> = params
                .iter()
                .map(|p| NDArray::zeros_on(&[p.len()], engine.clone()))
                .collect();
            for step in 1..=steps {
                let mut round_loss = 0.0f32;
                for _w in 0..workers {
                    // pull newest weights
                    for (name, buf) in names.iter().zip(&weight_bufs) {
                        kv.pull(name, buf, _w)?;
                    }
                    kv.flush();
                    for (p, buf) in params.iter_mut().zip(&weight_bufs) {
                        p.copy_from_slice(&buf.to_vec());
                    }
                    let (data, labels) = sample_batch(&mut rng, batch, seq, vocab);
                    let mut inputs: Vec<&[f32]> =
                        params.iter().map(|p| p.as_slice()).collect();
                    inputs.push(&data);
                    inputs.push(&labels);
                    let outs = step_prog.run(&inputs)?;
                    round_loss += outs[0][0] / workers as f32;
                    for (name, g) in names.iter().zip(outs.into_iter().skip(1)) {
                        kv.push(name, &NDArray::from_vec_on(&[g.len()], g, engine.clone()), _w)?;
                    }
                }
                kv.flush();
                if step == 1 || step % 10 == 0 {
                    curve.push((step, round_loss));
                    println!(
                        "step {step:>4}  loss {round_loss:.4}  ({workers} workers, {:.2?})",
                        t0.elapsed()
                    );
                }
            }
        }
        _ => unreachable!(),
    }

    // held-out eval through the eval_step artifact
    let (data, labels) = sample_batch(&mut rng, batch, seq, vocab);
    let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    inputs.push(&data);
    inputs.push(&labels);
    let eval_loss = eval_prog.run(&inputs)?[0][0];

    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    println!(
        "\ndone in {:.2?}: train loss {first:.4} -> {last:.4}, held-out {eval_loss:.4} \
         (uniform = ln({vocab}) = {:.3})",
        t0.elapsed(),
        (vocab as f32).ln()
    );
    let csv: String = std::iter::once("step,loss\n".to_string())
        .chain(curve.iter().map(|(s, l)| format!("{s},{l}\n")))
        .collect();
    std::fs::write("target/transformer_loss_curve.csv", csv)?;
    println!("loss curve -> target/transformer_loss_curve.csv");
    // persist trained weights for `examples/generate_text.rs`
    let blob: Vec<u8> = params
        .iter()
        .flat_map(|p| p.iter().flat_map(|x| x.to_le_bytes()))
        .collect();
    std::fs::write("target/params_trained.bin", blob)?;
    println!("trained params -> target/params_trained.bin");
    assert!(last < 0.8 * first, "loss failed to decrease");
    Ok(())
}

//! Autoregressive generation through the `predict` artifact — the
//! serving-path counterpart of `train_transformer`: load weights, slide a
//! context window, sample next tokens, all from Rust via PJRT.
//!
//! Uses `target/params_trained.bin` when present (written by
//! `train_transformer`), else the untrained `artifacts/params_init.bin`.
//! The synthetic corpus is a noisy period-16 cycle, so generation quality
//! is *measurable*: we report how often the sampled token continues the
//! cycle.
//!
//! ```text
//! make artifacts
//! cargo run --release --example train_transformer 300   # optional: train
//! cargo run --release --example generate_text [n_tokens] [temperature]
//! ```

use std::path::Path;

use mixnet::runtime::{Runtime, TensorKind};
use mixnet::util::Rng;
use mixnet::{Error, Result};

fn load_blob(path: &Path, spec: &mixnet::runtime::ModuleSpec) -> Result<Vec<Vec<f32>>> {
    let blob = std::fs::read(path)?;
    let floats: Vec<f32> =
        blob.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
    let mut out = Vec::new();
    let mut off = 0usize;
    for ts in &spec.inputs {
        if ts.kind == TensorKind::Param {
            if off + ts.size() > floats.len() {
                return Err(Error::Runtime(format!("{} too short", path.display())));
            }
            out.push(floats[off..off + ts.size()].to_vec());
            off += ts.size();
        }
    }
    if off != floats.len() {
        return Err(Error::Runtime(format!("{} has trailing data", path.display())));
    }
    Ok(out)
}

fn main() -> Result<()> {
    let n_tokens: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(96);
    let temperature: f32 =
        std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(0.7);

    let dir = Path::new("artifacts");
    let rt = Runtime::cpu()?;
    let programs = rt.load_dir(dir)?;
    let predict = programs.get("predict").ok_or_else(|| {
        Error::Runtime("no 'predict' module — re-run `make artifacts`".into())
    })?;
    let spec = predict.spec().clone();
    let d = &spec.inputs[spec.input_indices(TensorKind::Data)[0]];
    let (batch, seq) = (d.shape[0], d.shape[1]);
    let vocab = spec.outputs[0].shape[2];

    let trained = Path::new("target/params_trained.bin");
    let (params, source) = if trained.exists() {
        (load_blob(trained, &spec)?, "trained")
    } else {
        (load_blob(&dir.join("params_init.bin"), &spec)?, "UNTRAINED (run train_transformer)")
    };
    println!("generating {n_tokens} tokens at T={temperature} with {source} weights");

    // seed context: the clean period-16 cycle
    let period = 16usize;
    let mut window: Vec<usize> = (0..seq).map(|t| t % period).collect();
    let mut rng = Rng::seed_from_u64(0xfeed);
    let mut generated = Vec::with_capacity(n_tokens);
    let mut continues_cycle = 0usize;

    for _ in 0..n_tokens {
        // batch slot 0 carries the window; other rows are padding
        let mut tokens = vec![0.0f32; batch * seq];
        for (t, &tok) in window.iter().enumerate() {
            tokens[t] = tok as f32;
        }
        let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        inputs.push(&tokens);
        let logits = &predict.run(&inputs)?[0];
        // last position of row 0
        let row = &logits[(seq - 1) * vocab..seq * vocab];
        // temperature sampling
        let maxl = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f32> =
            row.iter().map(|l| ((l - maxl) / temperature.max(1e-3)).exp()).collect();
        let total: f32 = weights.iter().sum();
        let mut pick = rng.next_f32() * total;
        let mut next = vocab - 1;
        for (i, w) in weights.iter().enumerate() {
            if pick <= *w {
                next = i;
                break;
            }
            pick -= w;
        }
        let expected = (window[seq - 1] + 1) % period;
        if next == expected {
            continues_cycle += 1;
        }
        generated.push(next);
        window.rotate_left(1);
        window[seq - 1] = next;
    }

    println!("\nfirst 48 generated tokens:");
    for chunk in generated.iter().take(48).collect::<Vec<_>>().chunks(16) {
        println!("  {:?}", chunk);
    }
    let rate = continues_cycle as f32 / n_tokens as f32;
    println!("\ncycle-continuation rate: {rate:.2} (noise floor in training data: 0.90)");
    if source == "trained" {
        assert!(rate > 0.5, "trained model should follow the cycle, got {rate}");
    }
    Ok(())
}

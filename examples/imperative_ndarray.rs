//! Imperative NDArray deep-dive (paper §2.2 and §3.2): lazy evaluation,
//! automatic parallelism discovery, write-dependency serialization, and
//! the gradient-descent-by-hand loop.
//!
//! ```text
//! cargo run --release --example imperative_ndarray
//! ```

use std::time::Instant;

use mixnet::engine::{create, EngineKind};
use mixnet::ndarray::NDArray;

fn main() {
    let engine = create(EngineKind::Threaded, mixnet::engine::default_threads());
    println!("engine: {} worker threads\n", engine.num_workers());

    // ---- lazy evaluation ------------------------------------------
    // Ops return immediately; the engine runs them when dependencies
    // resolve.  Reading (to_vec / at) waits.
    let x = NDArray::randn_on(&[512, 512], 0.0, 1.0, 1, engine.clone());
    let t0 = Instant::now();
    let y = x.dot(&x); // returns instantly
    let queued = t0.elapsed();
    let _ = y.to_vec(); // blocks until the matmul completes
    let done = t0.elapsed();
    println!("dot push returned in {queued:?}; result ready after {done:?}");
    assert!(queued < done);

    // ---- independent chains run concurrently ----------------------
    // a->b->c and d->e->f share no tags: the engine may interleave or
    // parallelize them; results must match the serial values.
    let a = NDArray::full(&[1024], 3.0);
    let d = NDArray::full(&[1024], 5.0);
    let c = a.add_scalar(1.0).mul_scalar(2.0); // (3+1)*2 = 8
    let f = d.mul_scalar(3.0).add_scalar(-5.0); // 5*3-5 = 10
    assert_eq!(c.at(0), 8.0);
    assert_eq!(f.at(0), 10.0);
    println!("independent chains: c={} f={}", c.at(0), f.at(0));

    // ---- mutation is a first-class dependency ----------------------
    // In-place ops *write* their tag: the engine serializes them against
    // readers, so this alternating read/mutate sequence is race-free.
    let w = NDArray::zeros(&[4]);
    for i in 0..100 {
        let delta = NDArray::full(&[4], 1.0 + (i % 3) as f32);
        w.add_(&delta); // mutates w (write dep)
        let snapshot = w.copy(); // reads w (ordered after the add)
        drop(snapshot);
    }
    let total: f32 = w.to_vec().iter().sum();
    // deltas cycle 1,2,3: i%3==0 occurs 34x, ==1/==2 33x each
    assert_eq!(total, 4.0 * (34.0 + 33.0 * 2.0 + 33.0 * 3.0));
    println!("100 serialized in-place updates: sum = {total}");

    // ---- reproducible RNG via write-tagged seed ---------------------
    // Two randn ops with one seed are serialized by the engine (the
    // paper's same-seed example), so results are deterministic.
    let r1 = NDArray::randn_on(&[8], 0.0, 1.0, 99, engine.clone()).to_vec();
    let r2 = NDArray::randn_on(&[8], 0.0, 1.0, 99, engine.clone()).to_vec();
    assert_eq!(r1, r2);
    println!("same-seed randn reproducible: {:?}", &r1[..3]);

    // ---- gradient descent by hand (paper §2.2) ----------------------
    // minimize f(w) = ||w - target||^2 with pure NDArray ops
    let target = NDArray::full(&[16], 0.7);
    let w = NDArray::randn_on(&[16], 0.0, 1.0, 5, engine.clone());
    for _ in 0..200 {
        let grad = w.sub(&target).mul_scalar(2.0);
        w.sub_scaled_(&grad, 0.05); // w -= 0.05 * grad
    }
    engine.wait_all();
    let err: f32 = w
        .to_vec()
        .iter()
        .map(|v| (v - 0.7).abs())
        .fold(0.0, f32::max);
    println!("hand-rolled GD converged: max |w - 0.7| = {err:.2e}");
    assert!(err < 1e-3);
}

//! Memory-allocation strategies (paper §3.1 + Figure 7): plan internal
//! memory for the zoo networks under `none` / `inplace` / `co-share` /
//! `both`, forward-only (prediction) and forward+backward (training).
//!
//! ```text
//! cargo run --release --example memory_planning [batch]
//! ```

use std::collections::HashMap;

use mixnet::graph::autodiff::build_backward;
use mixnet::graph::memory::{default_external, plan_memory, validate_plan, AllocStrategy};
use mixnet::graph::{infer_shapes, Entry, Graph};
use mixnet::models::by_name;
use mixnet::util::bench::print_table;
use mixnet::{Error, Result};

fn plan_mb(
    graph: &Graph,
    var_shapes: &HashMap<String, Vec<usize>>,
    extra_external: &[Entry],
    strategy: AllocStrategy,
) -> Result<f64> {
    let shapes = infer_shapes(graph, var_shapes)?;
    let external = default_external(graph, extra_external);
    let plan = plan_memory(graph, &shapes, &external, strategy);
    validate_plan(graph, &shapes, &external, &plan).map_err(Error::Graph)?;
    Ok(plan.bytes_mb())
}

/// Forward graph (prediction) or fwd+bwd graph with weight gradients kept
/// external (training), as Figure 7 measures.
fn build(model: &str, batch: usize, training: bool)
    -> Result<(Graph, HashMap<String, Vec<usize>>, Vec<Entry>)> {
    let m = by_name(model)?;
    let (mut g, vs) = m.graph(batch)?;
    if !training {
        return Ok((g, vs, vec![]));
    }
    let wrt: Vec<_> = g
        .variables()
        .into_iter()
        .filter(|&v| {
            let n = &g.nodes[v].name;
            n != "data" && !n.ends_with("_label")
        })
        .collect();
    let gi = build_backward(&mut g, &wrt)?;
    Ok((g, vs, gi.var_grads.values().copied().collect()))
}

fn main() -> Result<()> {
    let batch: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    // Figure 7's workloads; @64 keeps planning instant on one core while
    // preserving every layer (the planner is resolution-agnostic).
    let models = ["mlp", "alexnet@64", "inception-bn@64", "vgg-11@64"];

    for (title, training) in
        [("forward only (prediction)", false), ("forward + backward (training)", true)]
    {
        let mut rows = Vec::new();
        for name in models {
            let (graph, vs, grads) = build(name, batch, training)?;
            let mut row = vec![name.to_string()];
            let baseline = plan_mb(&graph, &vs, &grads, AllocStrategy::None)?;
            for strategy in AllocStrategy::all() {
                let mb = plan_mb(&graph, &vs, &grads, strategy)?;
                row.push(format!("{mb:.1} ({:.1}x)", baseline / mb.max(1e-9)));
            }
            rows.push(row);
        }
        print_table(
            &format!("internal memory MB, batch {batch} — {title}"),
            &["network", "none", "inplace", "co-share", "both"],
            &rows,
        );
        println!();
    }
    println!("(paper Figure 7: inplace+co-share gives ~2x for training, ~4x for prediction)");
    Ok(())
}

#!/usr/bin/env bash
# Multi-process distributed training harness (ISSUE 5 / ROADMAP item 1,
# sharded fleet per ISSUE 10).
#
# Launches a fleet of SHARDS `mixnet server` processes (shard i/N each,
# the ordered address list IS the key router contract) plus N
# `mixnet worker` processes talking to all of them over real TCP, for N
# in $WORKER_COUNTS, and records a Figure 8-style images/sec-vs-workers
# curve into BENCH_dist.json — the measured counterpart of the
# `sim/cluster.rs` virtual curve.  A second loop fixes the worker count
# and sweeps the SHARD count under a serialized per-shard wire
# (PALLAS_KV_WIRE_DELAY_US), recording the `shard_scaling` object CI
# gates on (2-shard throughput must beat 1-shard when the wire is the
# bottleneck).
#
#   scripts/dist_train.sh                 # full run: 1, 2 and 4 workers
#   QUICK=1 scripts/dist_train.sh         # CI smoke: 2 workers, tiny run
#   SHARDS=2 scripts/dist_train.sh        # 2-shard server fleet
#   BENCH_OUT=/tmp/d.json scripts/dist_train.sh
#
# Knobs: QUICK, BENCH_OUT, PORT (base port, default 9731), MODEL,
# SHARDS (server shards, default 1), SHARD_COUNTS (shard-scaling sweep),
# WIRE_US (simulated per-message wire time for the sweep, default 500),
# EXAMPLES (per worker), EPOCHS, BATCH (global batch per worker),
# DEVICES (local replicas per worker), CONSISTENCY (seq|bounded:K|eventual).
#
# CHAOS=1 appends a crash-elastic round: 3 workers against a server with
# degrade-on-expiry leases, one worker killed -9 mid-run.  The survivors
# must finish (exit 0), the server must log the victim's leave event,
# and the degraded images/sec lands in BENCH_dist.json.  Extra knobs:
# CHAOS_EXAMPLES, CHAOS_EPOCHS.

set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BIN="$ROOT/target/release/mixnet"
QUICK="${QUICK:-0}"
PORT="${PORT:-9731}"
MODEL="${MODEL:-mlp}"
DEVICES="${DEVICES:-1}"
SHARDS="${SHARDS:-1}"
WIRE_US="${WIRE_US:-500}"
CONSISTENCY="${CONSISTENCY:-seq}"
BENCH_OUT="${BENCH_OUT:-$ROOT/BENCH_dist.json}"

if [ "$QUICK" = "1" ]; then
  WORKER_COUNTS="${WORKER_COUNTS:-2}"
  SHARD_COUNTS="${SHARD_COUNTS:-1 2}"
  EXAMPLES="${EXAMPLES:-512}"
  EPOCHS="${EPOCHS:-1}"
  BATCH="${BATCH:-32}"
else
  WORKER_COUNTS="${WORKER_COUNTS:-1 2 4}"
  SHARD_COUNTS="${SHARD_COUNTS:-1 2 4}"
  EXAMPLES="${EXAMPLES:-2048}"
  EPOCHS="${EPOCHS:-2}"
  BATCH="${BATCH:-32}"
fi

if [ ! -x "$BIN" ]; then
  echo "== building release binary =="
  (cd "$ROOT" && cargo build --release)
fi

wait_for_port() {
  local port="$1" tries=100
  while ! (exec 3<>"/dev/tcp/127.0.0.1/$port") 2>/dev/null; do
    tries=$((tries - 1))
    if [ "$tries" -le 0 ]; then
      echo "server on port $port never came up" >&2
      return 1
    fi
    sleep 0.1
  done
  exec 3>&- 3<&- || true
}

now_s() { date +%s.%N; }

# Start an $2-shard server fleet at base port $1 for $3 machines.  Sets
# `fleet_pids` (space-joined) and `fleet_addrs` (comma-joined, shard
# order — the ordered address list IS the ShardRouter contract every
# worker shares).
start_fleet() {
  local base="$1" nshards="$2" machines="$3" i p
  fleet_pids=""
  fleet_addrs=""
  for i in $(seq 0 $((nshards - 1))); do
    p=$((base + i))
    if [ "$nshards" -gt 1 ]; then
      "$BIN" server --port "$p" --machines "$machines" --lr 0.2 \
        --shard "$i/$nshards" >/dev/null 2>&1 &
    else
      "$BIN" server --port "$p" --machines "$machines" --lr 0.2 >/dev/null 2>&1 &
    fi
    fleet_pids="$fleet_pids $!"
    [ -n "$fleet_addrs" ] && fleet_addrs="$fleet_addrs,"
    fleet_addrs="${fleet_addrs}127.0.0.1:$p"
  done
  trap 'kill $fleet_pids 2>/dev/null || true' EXIT
  for i in $(seq 0 $((nshards - 1))); do
    wait_for_port $((base + i))
  done
}

stop_fleet() {
  local pid
  for pid in $fleet_pids; do
    kill "$pid" 2>/dev/null || true
    wait "$pid" 2>/dev/null || true
  done
  trap - EXIT
}

records=""
idx=0
for n in $WORKER_COUNTS; do
  port=$((PORT + idx))
  idx=$((idx + SHARDS))
  echo "== $n worker(s) x $SHARDS shard(s) over TCP (base port $port) =="
  start_fleet "$port" "$SHARDS" "$n"

  t0="$(now_s)"
  worker_pids=""
  for m in $(seq 0 $((n - 1))); do
    "$BIN" worker \
      --server "$fleet_addrs" --kv-shards "$SHARDS" --machine "$m" \
      --model "$MODEL" --epochs "$EPOCHS" --batch "$BATCH" \
      --examples "$EXAMPLES" --devices "$DEVICES" \
      --consistency "$CONSISTENCY" >/dev/null &
    worker_pids="$worker_pids $!"
  done
  fail=0
  for pid in $worker_pids; do
    wait "$pid" || fail=1
  done
  t1="$(now_s)"
  stop_fleet
  if [ "$fail" -ne 0 ]; then
    echo "a worker failed at n=$n" >&2
    exit 1
  fi

  wall="$(awk -v a="$t0" -v b="$t1" 'BEGIN { printf "%.3f", b - a }')"
  images=$((n * EXAMPLES * EPOCHS))
  ips="$(awk -v i="$images" -v w="$wall" 'BEGIN { printf "%.1f", i / w }')"
  echo "   $n worker(s): ${wall}s wall, $images images -> $ips img/s"
  [ -n "$records" ] && records="$records,"
  records="$records
    {\"name\": \"dist_train.epoch\", \"case\": \"${n}workers_${SHARDS}shards\", \"n\": $n, \"wall_s\": $wall, \"images\": $images, \"images_per_sec\": $ips}"
done

# ---- shard scaling: images/sec vs server-shard count -----------------
# Fixed worker count, serialized per-shard wire: every push pays
# WIRE_US while holding its shard's connection slot, so with 1 shard
# the whole round's transfers queue behind one wire (the straggler
# case) and with N shards they overlap.  This is the curve the CI jq
# gate checks: ips_2 >= ips_1 whenever the wire is the bottleneck.
shard_scaling=""
sweep_workers=1
sweep_examples=$((EXAMPLES / 2))
[ "$sweep_examples" -lt 256 ] && sweep_examples=256
for s in $SHARD_COUNTS; do
  port=$((PORT + 100 + idx))
  idx=$((idx + s))
  echo "== shard scaling: $s shard(s), $sweep_workers worker, ${WIRE_US}us wire =="
  start_fleet "$port" "$s" "$sweep_workers"

  t0="$(now_s)"
  worker_pids=""
  for m in $(seq 0 $((sweep_workers - 1))); do
    PALLAS_KV_WIRE_DELAY_US="$WIRE_US" "$BIN" worker \
      --server "$fleet_addrs" --kv-shards "$s" --machine "$m" \
      --model "$MODEL" --epochs "$EPOCHS" --batch "$BATCH" \
      --examples "$sweep_examples" --devices "$DEVICES" \
      --consistency "$CONSISTENCY" >/dev/null &
    worker_pids="$worker_pids $!"
  done
  fail=0
  for pid in $worker_pids; do
    wait "$pid" || fail=1
  done
  t1="$(now_s)"
  stop_fleet
  if [ "$fail" -ne 0 ]; then
    echo "a worker failed at $s shard(s)" >&2
    exit 1
  fi

  wall="$(awk -v a="$t0" -v b="$t1" 'BEGIN { printf "%.3f", b - a }')"
  images=$((sweep_workers * sweep_examples * EPOCHS))
  ips="$(awk -v i="$images" -v w="$wall" 'BEGIN { printf "%.1f", i / w }')"
  echo "   $s shard(s): ${wall}s wall, $images images -> $ips img/s"
  [ -n "$records" ] && records="$records,"
  records="$records
    {\"name\": \"dist_train.shard_scaling\", \"case\": \"${s}shards_wire\", \"n\": $s, \"wall_s\": $wall, \"images\": $images, \"images_per_sec\": $ips}"
  [ -n "$shard_scaling" ] && shard_scaling="$shard_scaling,"
  shard_scaling="$shard_scaling \"ips_$s\": $ips"
done

if [ "${CHAOS:-0}" = "1" ]; then
  n=3
  port=$((PORT + 50))
  if [ "$QUICK" = "1" ]; then
    chaos_examples="${CHAOS_EXAMPLES:-1024}"
    chaos_epochs="${CHAOS_EPOCHS:-2}"
  else
    chaos_examples="${CHAOS_EXAMPLES:-2048}"
    chaos_epochs="${CHAOS_EPOCHS:-4}"
  fi
  chaos_log="$(mktemp)"
  echo "== chaos: $n workers, kill -9 one mid-run (port $port) =="
  PALLAS_KV_LEASE_MS=1500 PALLAS_KV_LEASE_POLICY=degrade \
    "$BIN" server --port "$port" --machines "$n" --lr 0.2 >/dev/null 2>"$chaos_log" &
  server_pid=$!
  trap 'kill "$server_pid" 2>/dev/null || true' EXIT
  wait_for_port "$port"

  t0="$(now_s)"
  worker_pids=""
  for m in $(seq 0 $((n - 1))); do
    PALLAS_KV_HEARTBEAT_MS=300 "$BIN" worker \
      --server "127.0.0.1:$port" --machine "$m" \
      --model "$MODEL" --epochs "$chaos_epochs" --batch "$BATCH" \
      --examples "$chaos_examples" --devices "$DEVICES" \
      --consistency "$CONSISTENCY" >/dev/null &
    worker_pids="$worker_pids $!"
  done
  set -- $worker_pids
  victim="$3"
  sleep 1
  echo "   kill -9 worker 2 (pid $victim)"
  kill -9 "$victim" 2>/dev/null || true
  fail=0
  for pid in $1 $2; do
    wait "$pid" || fail=1
  done
  wait "$victim" 2>/dev/null || true
  t1="$(now_s)"
  # let the lease checker log the leave before stopping the server
  sleep 2
  kill "$server_pid" 2>/dev/null || true
  wait "$server_pid" 2>/dev/null || true
  trap - EXIT
  if [ "$fail" -ne 0 ]; then
    echo "a surviving worker failed under chaos" >&2
    cat "$chaos_log" >&2
    exit 1
  fi
  if ! grep -q "leaves" "$chaos_log"; then
    echo "server never logged the killed worker's leave event" >&2
    cat "$chaos_log" >&2
    exit 1
  fi
  grep "lease expired" "$chaos_log" || true

  wall="$(awk -v a="$t0" -v b="$t1" 'BEGIN { printf "%.3f", b - a }')"
  images=$(((n - 1) * chaos_examples * chaos_epochs))
  ips="$(awk -v i="$images" -v w="$wall" 'BEGIN { printf "%.1f", i / w }')"
  echo "   chaos: ${wall}s wall, $images survivor images -> $ips img/s (degraded)"
  [ -n "$records" ] && records="$records,"
  records="$records
    {\"name\": \"dist_train.chaos\", \"case\": \"3workers_kill1\", \"n\": $n, \"wall_s\": $wall, \"images\": $images, \"images_per_sec\": $ips}"
  rm -f "$chaos_log"
fi

# Shared BENCH_*.json metadata block (same keys util/bench.rs emits).
git_sha="${GITHUB_SHA:-$(git -C "$ROOT" rev-parse HEAD 2>/dev/null || echo unknown)}"
intra_threads="${PALLAS_INTRA_THREADS:-default}"
cat > "$BENCH_OUT" <<EOF
{
  "schema_version": "1",
  "bench": "dist_train",
  "git_sha": "$git_sha",
  "intra_threads": "$intra_threads",
  "unix_time": "$(date +%s)",
  "quick": $([ "$QUICK" = "1" ] && echo true || echo false),
  "model": "$MODEL",
  "examples_per_worker": $EXAMPLES,
  "epochs": $EPOCHS,
  "global_batch_per_worker": $BATCH,
  "devices_per_worker": $DEVICES,
  "consistency": "$CONSISTENCY",
  "server_shards": $SHARDS,
  "shard_wire_us": $WIRE_US,
  "shard_scaling": {$shard_scaling },
  "note": "Figure 8-style measured scaling: a SHARDS-process mixnet server fleet + N mixnet workers over real TCP loopback; compare against sim/cluster.rs. Weak scaling: each worker holds its own $EXAMPLES-example synthetic shard. shard_scaling holds the serialized-wire shard sweep (PALLAS_KV_WIRE_DELAY_US): images/sec at each server-shard count.",
  "records": [$records
  ]
}
EOF
echo "wrote $BENCH_OUT"

//! ISSUE 9 acceptance tests: sublinear-memory training via
//! recompute-on-backward checkpoint segments.
//!
//! * **Bitwise equivalence** — an executor bound with
//!   `memopt: Recompute` must produce *bitwise* identical loss curves,
//!   gradients and updated parameters to a `memopt: Off` bind, for MLP
//!   and AlexNet (dropout included: recompute clones re-derive the mask
//!   from the same (seed, step) pair), fused and unfused, at any
//!   segment count, across engine worker counts.  The intra-op thread
//!   pool is a process-wide OnceLock, so CI reruns this binary under
//!   `PALLAS_INTRA_THREADS` ∈ {1, 4}.
//! * **Memory actually shrinks** — the rewritten bind must report
//!   recompute clones, dropped activation bytes, and a planned peak
//!   strictly below the memopt-off planned peak on a deep enough net.
//! * **Pool discipline** — steady-state recompute training steps do
//!   zero pool misses after warmup, same bar as the memopt-off plan.
//!
//! Tests serialize on `POOL_LOCK` where they read the process-global
//! pool counters.

use std::collections::HashMap;
use std::sync::Mutex;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::{BindConfig, Executor};
use mixnet::graph::recompute::MemOpt;
use mixnet::models::{alexnet, conv_tower, mlp, vgg11_tower, Model};
use mixnet::ndarray::{pool, NDArray};
use mixnet::util::Rng;

static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Deterministic values for every variable (data, label, params) of a
/// model — generated once, shared verbatim by every bind under test.
fn gen_values(model: &Model, batch: usize) -> (HashMap<String, Vec<f32>>, Vec<String>) {
    let shapes = model.var_shapes(batch).unwrap();
    let mut names: Vec<String> = shapes.keys().cloned().collect();
    names.sort();
    let mut vals = HashMap::new();
    for (i, name) in names.iter().enumerate() {
        let n: usize = shapes[name].iter().product();
        let mut rng = Rng::seed_from_u64(0x5EC + i as u64);
        let v: Vec<f32> = if name.ends_with("_label") {
            (0..n).map(|j| (j % model.num_classes) as f32).collect()
        } else {
            (0..n).map(|_| rng.normal_with(0.0, 0.15)).collect()
        };
        vals.insert(name.clone(), v);
    }
    let params = names
        .iter()
        .filter(|n| n.as_str() != "data" && !n.ends_with("_label"))
        .cloned()
        .collect();
    (vals, params)
}

/// Bind with the given memopt/fuse knobs, run `steps` of
/// forward/backward + imperative SGD, and return the bit patterns of
/// the per-step loss curve, the head output, every gradient and every
/// updated parameter.
#[allow(clippy::too_many_arguments)]
fn run_model(
    model: &Model,
    batch: usize,
    workers: usize,
    memopt: MemOpt,
    fuse: bool,
    steps: usize,
    vals: &HashMap<String, Vec<f32>>,
    params: &[String],
) -> Vec<Vec<u32>> {
    let engine = create(EngineKind::Threaded, workers);
    let shapes = model.var_shapes(batch).unwrap();
    let args: HashMap<String, NDArray> = vals
        .iter()
        .map(|(k, v)| (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone())))
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let cfg = BindConfig { memopt, fuse, ..Default::default() };
    let exec = Executor::bind(&model.symbol, engine.clone(), args, &grad_names, cfg).unwrap();
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        exec.forward_backward().unwrap();
        let (loss, _acc) = exec.softmax_metrics().unwrap();
        losses.push(loss);
        for p in params {
            exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), 0.05);
        }
    }
    exec.wait();
    let mut out = vec![bits(&losses), bits(&exec.outputs()[0].to_vec())];
    for p in params {
        out.push(bits(&exec.grad(p).unwrap().to_vec()));
        out.push(bits(&exec.arg(p).unwrap().to_vec()));
    }
    out
}

fn assert_bits_eq(got: &[Vec<u32>], want: &[Vec<u32>], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: section count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.len(), w.len(), "{ctx}: length of section {i}");
        let diff = g.iter().zip(w).filter(|(a, b)| a != b).count();
        assert!(diff == 0, "{ctx}: section {i} differs in {diff}/{} words", g.len());
    }
}

#[test]
fn mlp_recompute_is_bitwise_identical_across_segments_and_workers() {
    // Deep enough that sqrt(n) segmentation has interior activations to
    // drop on every segment-count choice below.
    let model = mlp(&[48, 40, 32, 24, 16], 16, 4);
    let (vals, params) = gen_values(&model, 8);
    let reference = run_model(&model, 8, 1, MemOpt::Off, true, 3, &vals, &params);
    for workers in [1usize, 4] {
        for segments in [0usize, 2, 3, 5] {
            let got = run_model(
                &model,
                8,
                workers,
                MemOpt::Recompute { segments },
                true,
                3,
                &vals,
                &params,
            );
            assert_bits_eq(
                &got,
                &reference,
                &format!("mlp workers={workers} segments={segments}"),
            );
        }
    }
}

#[test]
fn alexnet_recompute_is_bitwise_identical_fused_and_unfused() {
    // Full AlexNet topology on a 64x64 input; dropout is live in
    // training mode, so clone nodes must re-derive the identical mask,
    // and under `fuse` the clones inherit the GEMM epilogues.
    let model = alexnet(4, 64);
    let (vals, params) = gen_values(&model, 1);
    for fuse in [true, false] {
        let auto = MemOpt::Recompute { segments: 0 };
        let off = run_model(&model, 1, 4, MemOpt::Off, fuse, 2, &vals, &params);
        let rc = run_model(&model, 1, 4, auto, fuse, 2, &vals, &params);
        assert_bits_eq(&rc, &off, &format!("alexnet fuse={fuse}"));
    }
}

#[test]
fn vgg_tower_recompute_is_bitwise_identical() {
    // The CI-gated benchmark workload itself: five conv stages plus a
    // dropout head.  One step at batch 2 keeps the test CPU-cheap.
    let model = vgg11_tower(4, 64);
    let (vals, params) = gen_values(&model, 2);
    let off = run_model(&model, 2, 4, MemOpt::Off, true, 1, &vals, &params);
    let rc = run_model(&model, 2, 4, MemOpt::Recompute { segments: 0 }, true, 1, &vals, &params);
    assert_bits_eq(&rc, &off, "vgg11-tower");
}

#[test]
fn conv_tower_recompute_is_bitwise_identical() {
    // The uniform-depth CI gate workload, tiny edition: same-width convs
    // at constant resolution, where the sqrt(n) segmentation drops the
    // bulk of the interior activations.
    let model = conv_tower(8, 16, 4, 8);
    let (vals, params) = gen_values(&model, 2);
    let off = run_model(&model, 2, 4, MemOpt::Off, true, 2, &vals, &params);
    for segments in [0usize, 3] {
        let rc = MemOpt::Recompute { segments };
        let got = run_model(&model, 2, 4, rc, true, 2, &vals, &params);
        assert_bits_eq(&got, &off, &format!("conv-tower segments={segments}"));
    }
}

#[test]
fn conv_tower_planned_peak_hits_sublinear_ratio() {
    // On n uniform layers the rewrite's planned walk peak must land well
    // below memopt-off — the property the 0.6x measured CI gate relies
    // on (pyramid nets have a stage-1 floor; this shape does not).
    let model = conv_tower(16, 16, 4, 8);
    let (vals, params) = gen_values(&model, 4);
    let engine = create(EngineKind::Threaded, 2);
    let shapes = model.var_shapes(4).unwrap();
    let args: HashMap<String, NDArray> = vals
        .iter()
        .map(|(k, v)| (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone())))
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let cfg = BindConfig { memopt: MemOpt::Recompute { segments: 0 }, ..Default::default() };
    let exec = Executor::bind(&model.symbol, engine.clone(), args, &grad_names, cfg).unwrap();
    let (_base_total, base_peak) = exec.baseline_bytes().expect("baseline recorded");
    let planned = exec.planned_peak_bytes();
    assert!(
        planned * 10 < base_peak * 7,
        "uniform tower: planned peak {planned} not below 0.7x of memopt-off peak {base_peak}"
    );
}

#[test]
fn recompute_bind_reports_clones_and_smaller_planned_peak() {
    let model = vgg11_tower(4, 64);
    let (vals, params) = gen_values(&model, 4);
    let engine = create(EngineKind::Threaded, 2);
    let shapes = model.var_shapes(4).unwrap();
    let args: HashMap<String, NDArray> = vals
        .iter()
        .map(|(k, v)| (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone())))
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let cfg = BindConfig { memopt: MemOpt::Recompute { segments: 0 }, ..Default::default() };
    let exec = Executor::bind(&model.symbol, engine.clone(), args, &grad_names, cfg).unwrap();
    let info = exec.recompute_info().expect("deep conv net must have droppable activations");
    assert!(info.recompute_nodes > 0, "no clone nodes emitted");
    assert!(info.dropped_entries > 0, "no activations dropped");
    assert!(info.dropped_bytes > 0, "dropped entries must carry bytes");
    assert!(info.segments >= 2, "expected at least 2 segments, got {}", info.segments);
    let (_base_total, base_peak) = exec.baseline_bytes().expect("baseline recorded on rewrite");
    assert!(
        exec.planned_peak_bytes() < base_peak,
        "planned peak {} must shrink below memopt-off peak {}",
        exec.planned_peak_bytes(),
        base_peak
    );
    // And the rewritten bind must still run.
    exec.forward_backward().unwrap();
    exec.wait();
}

#[test]
fn off_bind_reports_no_recompute_info() {
    let model = mlp(&[32, 16], 16, 4);
    let (vals, params) = gen_values(&model, 8);
    let engine = create(EngineKind::Threaded, 2);
    let shapes = model.var_shapes(8).unwrap();
    let args: HashMap<String, NDArray> = vals
        .iter()
        .map(|(k, v)| (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone())))
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let exec =
        Executor::bind(&model.symbol, engine.clone(), args, &grad_names, BindConfig::default())
            .unwrap();
    assert!(exec.recompute_info().is_none());
    assert!(exec.baseline_bytes().is_none());
}

#[test]
fn recompute_steps_do_zero_pool_misses_after_warmup() {
    let _g = lock();
    // Same "no steady-state heap allocation" bar the memopt-off plan
    // meets in tests/plan_pool.rs — recompute segments replay through
    // the same pooled plan blocks.
    let model = alexnet(4, 64);
    let (vals, params) = gen_values(&model, 1);
    let engine = create(EngineKind::Threaded, 4);
    let shapes = model.var_shapes(1).unwrap();
    let args: HashMap<String, NDArray> = vals
        .iter()
        .map(|(k, v)| (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone())))
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let cfg = BindConfig { memopt: MemOpt::Recompute { segments: 0 }, ..Default::default() };
    let exec = Executor::bind(&model.symbol, engine.clone(), args, &grad_names, cfg).unwrap();
    let step = |exec: &Executor| {
        exec.forward_backward().unwrap();
        for p in &params {
            exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), 0.05);
        }
    };
    for _ in 0..2 {
        step(&exec); // warmup
    }
    exec.wait();
    let before = pool::global().stats();
    for _ in 0..3 {
        step(&exec);
    }
    exec.wait();
    let after = pool::global().stats();
    assert_eq!(
        after.misses, before.misses,
        "a steady-state recompute step must not allocate (pool miss counter moved)"
    );
}

#[test]
fn pool_peak_gauge_moves_during_training() {
    let _g = lock();
    // The measured-memory story the bench relies on: live/peak gauges
    // must actually register a training bind's pooled working set.
    pool::global().clear();
    pool::global().reset_peak();
    let model = mlp(&[32, 16], 16, 4);
    let (vals, params) = gen_values(&model, 8);
    let engine = create(EngineKind::Threaded, 2);
    let shapes = model.var_shapes(8).unwrap();
    let args: HashMap<String, NDArray> = vals
        .iter()
        .map(|(k, v)| (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone())))
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let exec =
        Executor::bind(&model.symbol, engine.clone(), args, &grad_names, BindConfig::default())
            .unwrap();
    exec.forward_backward().unwrap();
    exec.wait();
    let stats = pool::global().stats();
    assert!(
        stats.peak_bytes > 0,
        "training through the pool must raise the peak gauge"
    );
    assert!(stats.peak_bytes >= stats.live_bytes, "peak below live is impossible");
}

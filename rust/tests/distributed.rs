//! Integration tests for the two-level parameter server over real TCP:
//! multi-machine convergence, consistency models, bandwidth accounting,
//! and failure behavior.

use std::sync::Arc;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::BindConfig;
use mixnet::io::{synth::class_clusters, ArrayDataIter};
use mixnet::kvstore::server::{PsServer, ServerUpdater};
use mixnet::kvstore::{dist::DistKVStore, Consistency, KVStore};
use mixnet::models::mlp;
use mixnet::module::{Module, UpdateMode};

fn updater(machines: usize) -> ServerUpdater {
    ServerUpdater { lr: 0.4 / machines as f32, momentum: 0.9, weight_decay: 1e-4, rescale: 1.0 }
}

fn train_machine(
    addr: std::net::SocketAddr,
    machine: u32,
    consistency: Consistency,
    epochs: usize,
) -> f32 {
    let engine = create(EngineKind::Threaded, 2);
    let kv = Arc::new(DistKVStore::connect(addr, machine, 1, consistency, engine.clone()).unwrap());
    let ds = class_clusters(512, 4, 16, 0.3, 77 + machine as u64);
    let mut iter = ArrayDataIter::new(ds.features, ds.labels, &[16], 32, true, engine.clone());
    let model = mlp(&[32], 16, 4);
    let shapes = model.param_shapes(32).unwrap();
    let mut module = Module::new(model.symbol, engine);
    module.bind(32, &[16], &shapes, BindConfig::default(), 5).unwrap();
    let stats = module
        .fit(&mut iter, &UpdateMode::KvStore { store: kv.clone(), device: 0 }, epochs)
        .unwrap();
    kv.barrier().unwrap();
    stats.last().unwrap().accuracy
}

#[test]
fn three_machines_converge_sequential() {
    let machines = 3;
    let mut server = PsServer::start(0, machines, updater(machines)).unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..machines as u32)
        .map(|m| std::thread::spawn(move || train_machine(addr, m, Consistency::Sequential, 3)))
        .collect();
    for h in handles {
        let acc = h.join().unwrap();
        assert!(acc > 0.85, "accuracy {acc}");
    }
    server.shutdown();
}

#[test]
fn two_machines_converge_eventual() {
    let machines = 2;
    let mut server = PsServer::start(0, machines, updater(machines)).unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..machines as u32)
        .map(|m| std::thread::spawn(move || train_machine(addr, m, Consistency::Eventual, 4)))
        .collect();
    for h in handles {
        let acc = h.join().unwrap();
        // eventual consistency trades freshness for speed; must still learn
        assert!(acc > 0.75, "accuracy {acc}");
    }
    server.shutdown();
}

/// Level-1 aggregation: with d devices per machine the server must see
/// 1/d of the device pushes (the Figure 5 bandwidth-reduction claim).
#[test]
fn bandwidth_reduced_by_device_count() {
    let mut server = PsServer::start(0, 1, updater(1)).unwrap();
    let engine = create(EngineKind::Threaded, 2);
    let devices = 4;
    let kv =
        DistKVStore::connect(server.addr(), 0, devices, Consistency::Sequential, engine.clone())
            .unwrap();
    let w = mixnet::ndarray::NDArray::zeros_on(&[256], engine.clone());
    kv.init("w", &w).unwrap();
    let rounds = 8;
    for _ in 0..rounds {
        for d in 0..devices {
            kv.push("w", &mixnet::ndarray::NDArray::ones(&[256]), d).unwrap();
        }
    }
    kv.flush();
    // init + one aggregated push per round
    assert_eq!(server.messages_received(), 1 + rounds);
    server.shutdown();
}

/// The server rejects a second init with a different shape but accepts
/// idempotent re-init (first writer wins).
#[test]
fn init_first_writer_wins() {
    let mut server = PsServer::start(0, 2, updater(2)).unwrap();
    let e1 = create(EngineKind::Threaded, 2);
    let e2 = create(EngineKind::Threaded, 2);
    let kv1 =
        DistKVStore::connect(server.addr(), 0, 1, Consistency::Sequential, e1.clone()).unwrap();
    let kv2 =
        DistKVStore::connect(server.addr(), 1, 1, Consistency::Sequential, e2.clone()).unwrap();
    kv1.init("w", &mixnet::ndarray::NDArray::from_vec(&[2], vec![5.0, 5.0])).unwrap();
    // second machine inits the same key with different values: ignored
    kv2.init("w", &mixnet::ndarray::NDArray::from_vec(&[2], vec![9.0, 9.0])).unwrap();
    let out = mixnet::ndarray::NDArray::zeros(&[2]);
    kv2.pull("w", &out, 0).unwrap();
    kv2.flush();
    assert_eq!(out.to_vec(), vec![5.0, 5.0], "first writer must win");
    server.shutdown();
}

/// Pulling an unknown key must error at the client, not hang.
#[test]
fn unknown_key_errors() {
    let mut server = PsServer::start(0, 1, updater(1)).unwrap();
    let engine = create(EngineKind::Threaded, 2);
    let kv =
        DistKVStore::connect(server.addr(), 0, 1, Consistency::Sequential, engine).unwrap();
    let out = mixnet::ndarray::NDArray::zeros(&[4]);
    assert!(kv.pull("ghost", &out, 0).is_err());
    server.shutdown();
}

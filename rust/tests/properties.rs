//! Property-based tests over the core invariants (DESIGN deliverable c):
//! random graphs through the memory planner and executor, random schedules
//! through both engines, wire-protocol fuzzing, kernel algebra.

use std::collections::HashMap;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::{BindConfig, Executor};
use mixnet::graph::memory::{default_external, plan_memory, validate_plan, AllocStrategy};
use mixnet::graph::{infer_shapes, Entry, Graph, Op};
use mixnet::kvstore::wire::{decode, encode, Msg};
use mixnet::ndarray::kernels::{self, ActKind, EwBinary};
use mixnet::ndarray::NDArray;
use mixnet::util::proptest::{check, check_explain};
use mixnet::util::Rng;

/// Random same-shape elementwise DAG over a `[b, d]` input: the planner
/// and executor must handle arbitrary fan-out/fan-in.
fn random_ew_graph(rng: &mut Rng, max_nodes: usize) -> (Graph, usize, usize) {
    let b = 1 + rng.below(4);
    let d = 1 + rng.below(16);
    let mut g = Graph::new();
    let data = g.add_variable("data");
    let mut entries = vec![Entry::new(data)];
    let n = 2 + rng.below(max_nodes);
    for i in 0..n {
        let a = entries[rng.below(entries.len())];
        let op = match rng.below(5) {
            0 => Op::Activation { kind: ActKind::Relu },
            1 => Op::AddScalar { s: rng.uniform(-1.0, 1.0) },
            2 => Op::MulScalar { s: rng.uniform(0.5, 1.5) },
            3 => {
                let b2 = entries[rng.below(entries.len())];
                let id = g.add_node(
                    Op::Elemwise { op: EwBinary::Add },
                    format!("ew{i}"),
                    vec![a, b2],
                );
                entries.push(Entry::new(id));
                continue;
            }
            _ => Op::Identity,
        };
        let id = g.add_node(op, format!("n{i}"), vec![a]);
        entries.push(Entry::new(id));
    }
    // 1-3 outputs picked from the tail
    let k = 1 + rng.below(3.min(entries.len()));
    g.outputs = entries[entries.len() - k..].to_vec();
    g.num_forward = g.nodes.len();
    (g, b, d)
}

#[test]
fn prop_memory_plans_always_validate() {
    check_explain(
        "memory-plan-sound",
        60,
        |rng| random_ew_graph(rng, 24),
        |(g, b, d)| {
            let mut vs = HashMap::new();
            vs.insert("data".to_string(), vec![*b, *d]);
            let shapes = infer_shapes(g, &vs).map_err(|e| e.to_string())?;
            let external = default_external(g, &[]);
            for strategy in AllocStrategy::all() {
                let plan = plan_memory(g, &shapes, &external, strategy);
                validate_plan(g, &shapes, &external, &plan)
                    .map_err(|e| format!("{strategy}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_alloc_strategies_numerically_equal() {
    check_explain(
        "alloc-strategies-equal",
        30,
        |rng| {
            let (g, b, d) = random_ew_graph(rng, 16);
            let data: Vec<f32> = (0..b * d).map(|_| rng.uniform(-2.0, 2.0)).collect();
            (g, b, d, data)
        },
        |(g, b, d, data)| {
            let mut baseline: Option<Vec<Vec<f32>>> = None;
            for strategy in AllocStrategy::all() {
                for fuse in [false, true] {
                    let engine = create(EngineKind::Threaded, 2);
                    let mut args = HashMap::new();
                    args.insert(
                        "data".to_string(),
                        NDArray::from_vec_on(&[*b, *d], data.clone(), engine.clone()),
                    );
                    let exec = Executor::bind_graph(
                        g.clone(),
                        engine,
                        args,
                        &[],
                        BindConfig { strategy, training: false, fuse, ..Default::default() },
                    )
                    .map_err(|e| e.to_string())?;
                    exec.forward();
                    exec.wait();
                    let outs: Vec<Vec<f32>> =
                        exec.outputs().iter().map(|o| o.to_vec()).collect();
                    match &baseline {
                        None => baseline = Some(outs),
                        Some(want) => {
                            for (a, b) in want.iter().zip(&outs) {
                                for (x, y) in a.iter().zip(b) {
                                    if (x - y).abs() > 1e-5 {
                                        return Err(format!(
                                            "{strategy} fuse={fuse}: {x} != {y}"
                                        ));
                                    }
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Per-var program order: ops writing one var must run in push order on
/// BOTH engines (the reproducibility property of §3.2).
#[test]
fn prop_engine_write_order_is_program_order() {
    check_explain(
        "engine-write-order",
        20,
        |rng| {
            // (n_vars, ops as (write_var, [read_vars...]))
            let n_vars = 2 + rng.below(6);
            let ops: Vec<(usize, Vec<usize>)> = (0..30 + rng.below(60))
                .map(|_| {
                    let w = rng.below(n_vars);
                    let reads = (0..rng.below(3)).map(|_| rng.below(n_vars)).collect();
                    (w, reads)
                })
                .collect();
            (n_vars, ops)
        },
        |(n_vars, ops)| {
            for kind in [EngineKind::Threaded, EngineKind::Naive] {
                let engine = create(kind, 4);
                let vars: Vec<_> = (0..*n_vars).map(|_| engine.new_var()).collect();
                let logs: Vec<_> = (0..*n_vars)
                    .map(|_| std::sync::Arc::new(std::sync::Mutex::new(Vec::<usize>::new())))
                    .collect();
                let mut expected: Vec<Vec<usize>> = vec![vec![]; *n_vars];
                for (op_id, (w, reads)) in ops.iter().enumerate() {
                    expected[*w].push(op_id);
                    let log = std::sync::Arc::clone(&logs[*w]);
                    engine.push(
                        "op",
                        reads.iter().map(|&r| vars[r]).collect(),
                        vec![vars[*w]],
                        Box::new(move || log.lock().unwrap().push(op_id)),
                    );
                }
                engine.wait_all();
                for (v, want) in expected.iter().enumerate() {
                    let got = logs[v].lock().unwrap().clone();
                    if got != *want {
                        return Err(format!(
                            "{kind:?} var {v}: got {got:?}, want {want:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_wire_roundtrip() {
    check_explain(
        "wire-roundtrip",
        200,
        |rng| {
            let key: String =
                (0..rng.below(20)).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
            let value: Vec<f32> = (0..rng.below(64)).map(|_| rng.uniform(-1e6, 1e6)).collect();
            match rng.below(10) {
                0 => Msg::Init { key, value },
                1 => Msg::Push {
                    key,
                    value,
                    machine: rng.below(1024) as u32,
                    seq: rng.next_u64(),
                },
                2 => Msg::Pull { key, after_version: rng.next_u64() },
                3 => Msg::Value { key, value, version: rng.next_u64() },
                4 => Msg::Barrier { id: rng.next_u64(), machine: rng.below(64) as u32 },
                5 => Msg::Hello { machine: rng.below(1024) as u32 },
                6 => Msg::Heartbeat { machine: rng.below(1024) as u32 },
                7 => Msg::HelloAck {
                    seq: rng.next_u64(),
                    barrier: rng.next_u64(),
                    shard: rng.below(16) as u32,
                    shards: 1 + rng.below(16) as u32,
                },
                8 => Msg::StatsReply {
                    msgs: rng.next_u64(),
                    bytes: rng.next_u64(),
                    dedup_hits: rng.next_u64(),
                    lease_expiries: rng.next_u64(),
                    applies: rng.next_u64(),
                },
                _ => Msg::Err { msg: key },
            }
        },
        |msg| {
            let enc = encode(msg);
            let dec = decode(&enc[8..]).map_err(|e| e.to_string())?;
            if dec != *msg {
                return Err(format!("roundtrip mismatch: {dec:?}"));
            }
            Ok(())
        },
    );
}

/// Arbitrary corruption of a wire frame must never panic — only error or
/// decode to some (other) valid message.
#[test]
fn prop_wire_fuzz_no_panic() {
    check(
        "wire-fuzz",
        300,
        |rng| {
            let mut enc = encode(&Msg::Push {
                key: "weights".into(),
                value: vec![1.0; 16],
                machine: 3,
                seq: 42,
            });
            for _ in 0..1 + rng.below(8) {
                let i = rng.below(enc.len());
                enc[i] ^= 1 << rng.below(8);
            }
            let cut = 8 + rng.below(enc.len() - 8);
            (enc, cut)
        },
        |(enc, cut)| {
            let _ = decode(&enc[8..]);
            let _ = decode(&enc[8..*cut]);
            true // reaching here without panic is the property
        },
    );
}

/// Serializes tests that read or toggle the process-global reference-
/// kernel mode: without this, `set_reference_kernels(true)` in one test
/// thread can flip another thread's GEMM mid-comparison.
static GEMM_MODE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// GEMM algebra: the three variants agree with each other under explicit
/// transposition, and the reference (slow) kernels agree with the
/// optimized ones.
#[test]
fn prop_gemm_variants_agree() {
    let _mode = GEMM_MODE_LOCK.lock().unwrap();
    check_explain(
        "gemm-agree",
        40,
        |rng| {
            let (m, k, n) = (1 + rng.below(12), 1 + rng.below(12), 1 + rng.below(12));
            let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let (m, k, n) = (*m, *k, *n);
            let mut c0 = vec![0.0; m * n];
            kernels::gemm(a, b, &mut c0, m, k, n, 0.0);
            // b^T laid out as [n, k]
            let mut bt = vec![0.0; n * k];
            for i in 0..k {
                for j in 0..n {
                    bt[j * k + i] = b[i * n + j];
                }
            }
            let mut c1 = vec![0.0; m * n];
            kernels::gemm_nt(a, &bt, &mut c1, m, k, n, 0.0);
            // a^T laid out as [k, m]
            let mut at = vec![0.0; k * m];
            for i in 0..m {
                for j in 0..k {
                    at[j * m + i] = a[i * k + j];
                }
            }
            let mut c2 = vec![0.0; m * n];
            kernels::gemm_tn(&at, b, &mut c2, m, k, n, 0.0);
            // reference mode
            kernels::set_reference_kernels(true);
            let mut c3 = vec![0.0; m * n];
            kernels::gemm(a, b, &mut c3, m, k, n, 0.0);
            kernels::set_reference_kernels(false);
            for i in 0..m * n {
                for (name, c) in [("nt", &c1), ("tn", &c2), ("ref", &c3)] {
                    if (c0[i] - c[i]).abs() > 1e-4 {
                        return Err(format!("{name}[{i}]: {} vs {}", c0[i], c[i]));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Blocked/parallel GEMM == reference oracle across transpose variants,
/// the odd-shape set {1, 7, 8, 9, 64, 65, 96, 130}, and beta in
/// {0, 1, 0.5} (ISSUE 1 satellite: property coverage for the kernel
/// rewrite).  The larger dims keep the packed/blocked path exercised now
/// that dispatch is per-row (`2*k*n`): 65x65 and up crosses the gate.
#[test]
fn prop_blocked_gemm_matches_reference() {
    let _mode = GEMM_MODE_LOCK.lock().unwrap();
    const DIMS: [usize; 8] = [1, 7, 8, 9, 64, 65, 96, 130];
    const BETAS: [f32; 3] = [0.0, 1.0, 0.5];
    check_explain(
        "blocked-gemm-vs-reference",
        120,
        |rng| {
            let m = DIMS[rng.below(DIMS.len())];
            let k = DIMS[rng.below(DIMS.len())];
            let n = DIMS[rng.below(DIMS.len())];
            let beta = BETAS[rng.below(BETAS.len())];
            let variant = rng.below(3); // 0 = nn, 1 = nt, 2 = tn
            let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            (m, k, n, beta, variant, a, b, c0)
        },
        |(m, k, n, beta, variant, a, b, c0)| {
            let (m, k, n, beta) = (*m, *k, *n, *beta);
            let mut got = c0.clone();
            let mut want = c0.clone();
            match variant {
                0 => {
                    kernels::gemm(a, b, &mut got, m, k, n, beta);
                    kernels::gemm_reference(a, b, &mut want, m, k, n, beta, false, false);
                }
                1 => {
                    // b^T laid out [n, k]
                    let mut bt = vec![0.0; n * k];
                    for p in 0..k {
                        for j in 0..n {
                            bt[j * k + p] = b[p * n + j];
                        }
                    }
                    kernels::gemm_nt(a, &bt, &mut got, m, k, n, beta);
                    kernels::gemm_reference(a, &bt, &mut want, m, k, n, beta, false, true);
                }
                _ => {
                    // a^T laid out [k, m]
                    let mut at = vec![0.0; k * m];
                    for i in 0..m {
                        for p in 0..k {
                            at[p * m + i] = a[i * k + p];
                        }
                    }
                    kernels::gemm_tn(&at, b, &mut got, m, k, n, beta);
                    kernels::gemm_reference(&at, b, &mut want, m, k, n, beta, true, false);
                }
            }
            for i in 0..m * n {
                let rel = (got[i] - want[i]).abs() / want[i].abs().max(1.0);
                if rel > 1e-4 {
                    return Err(format!(
                        "variant {variant} beta {beta} [{i}]: {} vs {}",
                        got[i], want[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Same inputs, any intra-op thread budget: bitwise-equal GEMM output
/// (the determinism acceptance criterion — chunk partitions are a pure
/// function of shape, so thread count only moves work between workers).
#[test]
fn prop_gemm_bitwise_deterministic_across_threads() {
    let _mode = GEMM_MODE_LOCK.lock().unwrap();
    check_explain(
        "gemm-thread-determinism",
        12,
        |rng| {
            // Big enough that the blocked path actually fans out.
            let m = 65 + rng.below(100);
            let k = 64 + rng.below(64);
            let n = 64 + rng.below(64);
            let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let (m, k, n) = (*m, *k, *n);
            let run = |budget: usize| {
                mixnet::util::with_intra_budget(budget, || {
                    let mut c = vec![0.0; m * n];
                    kernels::gemm(a, b, &mut c, m, k, n, 0.0);
                    c
                })
            };
            let serial = run(1);
            for budget in [2usize, 4, 8] {
                let par = run(budget);
                for i in 0..m * n {
                    if serial[i].to_bits() != par[i].to_bits() {
                        return Err(format!(
                            "budget {budget} [{i}]: {} != {} (bitwise)",
                            serial[i], par[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Any output row of a GEMM is bitwise identical to the same row computed
/// as a batch-1 GEMM — the serving layer's losslessness invariant: the
/// small/blocked dispatch gate is a function of (k, n) only, and every
/// path accumulates a row in an m-independent order.  Covers shapes on
/// both sides of the dispatch gate and both FC-relevant variants.
#[test]
fn prop_gemm_rows_independent_of_batch() {
    let _mode = GEMM_MODE_LOCK.lock().unwrap();
    check_explain(
        "gemm-batch-row-purity",
        25,
        |rng| {
            let m = 2 + rng.below(80);
            let k = 1 + rng.below(200);
            let n = 1 + rng.below(200);
            let nt = rng.below(2) == 0; // gemm vs gemm_nt (the FC shape)
            let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            (m, k, n, nt, a, b)
        },
        |(m, k, n, nt, a, b)| {
            let (m, k, n, nt) = (*m, *k, *n, *nt);
            let mut batched = vec![0.0f32; m * n];
            if nt {
                kernels::gemm_nt(a, b, &mut batched, m, k, n, 0.0);
            } else {
                kernels::gemm(a, b, &mut batched, m, k, n, 0.0);
            }
            for i in 0..m {
                let row = &a[i * k..(i + 1) * k];
                let mut single = vec![0.0f32; n];
                if nt {
                    kernels::gemm_nt(row, b, &mut single, 1, k, n, 0.0);
                } else {
                    kernels::gemm(row, b, &mut single, 1, k, n, 0.0);
                }
                for j in 0..n {
                    if batched[i * n + j].to_bits() != single[j].to_bits() {
                        return Err(format!(
                            "nt={nt} m={m} k={k} n={n} row {i} col {j}: \
                             {} != {} (bitwise)",
                            batched[i * n + j], single[j]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Fused GEMM epilogues (bias + activation + elementwise chain applied
/// inside the GEMM while output tiles are cache-hot) are bitwise equal
/// to the unfused kernel composition across the odd-shape set
/// {1, 7, 8, 9, 64, 65} (both sides of the small/blocked dispatch
/// gate), all three activations, and intra-op thread budgets {1, 4, 8}
/// — the graph compiler's losslessness contract.
#[test]
fn prop_gemm_epilogue_bitwise_lossless() {
    let _mode = GEMM_MODE_LOCK.lock().unwrap();
    const DIMS: [usize; 6] = [1, 7, 8, 9, 64, 65];
    const KINDS: [ActKind; 3] = [ActKind::Relu, ActKind::Tanh, ActKind::Sigmoid];
    check_explain(
        "gemm-epilogue-bitwise",
        40,
        |rng| {
            let m = DIMS[rng.below(DIMS.len())];
            let k = DIMS[rng.below(DIMS.len())];
            let n = DIMS[rng.below(DIMS.len())];
            let kind = KINDS[rng.below(KINDS.len())];
            let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let w: Vec<f32> = (0..n * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let res: Vec<f32> = (0..m * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            (m, k, n, kind, a, w, bias, res)
        },
        |(m, k, n, kind, a, w, bias, res)| {
            let (m, k, n, kind) = (*m, *k, *n, *kind);
            // Unfused composition (serial): gemm_nt, bias_add,
            // activation, elementwise-mul with a residual operand.
            let unfused = mixnet::util::with_intra_budget(1, || {
                let mut c = vec![0.0; m * n];
                kernels::gemm_nt(a, w, &mut c, m, k, n, 0.0);
                kernels::bias_add(&mut c, bias, m, n);
                let mut y = vec![0.0; m * n];
                kernels::act_forward(kind, &c, &mut y);
                for (v, r) in y.iter_mut().zip(res.iter()) {
                    *v *= r;
                }
                y
            });
            let steps = [
                kernels::EpStep::Act(kind),
                kernels::EpStep::Binary(EwBinary::Mul, res.as_slice()),
            ];
            let ep = kernels::Epilogue {
                bias: Some(bias.as_slice()),
                bias_per_row: false,
                steps: &steps,
            };
            for budget in [1usize, 4, 8] {
                let fused = mixnet::util::with_intra_budget(budget, || {
                    let mut c = vec![0.0; m * n];
                    kernels::gemm_nt_ep(a, w, &mut c, m, k, n, 0.0, &ep);
                    c
                });
                for i in 0..m * n {
                    if unfused[i].to_bits() != fused[i].to_bits() {
                        return Err(format!(
                            "m={m} k={k} n={n} kind={kind:?} budget={budget} \
                             [{i}]: {} != {} (bitwise)",
                            unfused[i], fused[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Conv epilogue fusion is bitwise lossless too: conv2d_forward_ep ==
/// conv2d_forward + act_forward for random NCHW shapes, kernel sizes,
/// activations, and thread budgets.
#[test]
fn prop_conv_epilogue_bitwise_lossless() {
    let _mode = GEMM_MODE_LOCK.lock().unwrap();
    const KINDS: [ActKind; 3] = [ActKind::Relu, ActKind::Tanh, ActKind::Sigmoid];
    check_explain(
        "conv-epilogue-bitwise",
        15,
        |rng| {
            let n = 1 + rng.below(3);
            let c = 1 + rng.below(3);
            let hw = 4 + rng.below(7);
            let f = 1 + rng.below(6);
            let k = [1usize, 3][rng.below(2)];
            let pad = rng.below(2);
            let kind = KINDS[rng.below(KINDS.len())];
            let x: Vec<f32> = (0..n * c * hw * hw).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let wt: Vec<f32> = (0..f * c * k * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let bias: Vec<f32> = (0..f).map(|_| rng.uniform(-1.0, 1.0)).collect();
            (n, c, hw, f, k, pad, kind, x, wt, bias)
        },
        |(n, c, hw, f, k, pad, kind, x, wt, bias)| {
            let (n, c, hw, f, k, pad, kind) = (*n, *c, *hw, *f, *k, *pad, *kind);
            let oh = (hw + 2 * pad - k) + 1; // stride 1
            let out_len = n * f * oh * oh;
            let unfused = mixnet::util::with_intra_budget(1, || {
                let mut y0 = vec![0.0; out_len];
                kernels::conv2d_forward(x, wt, bias, &mut y0, n, c, hw, hw, f, k, 1, pad);
                let mut y = vec![0.0; out_len];
                kernels::act_forward(kind, &y0, &mut y);
                y
            });
            let steps = [kernels::EpStep::Act(kind)];
            for budget in [1usize, 4, 8] {
                let fused = mixnet::util::with_intra_budget(budget, || {
                    let mut y = vec![0.0; out_len];
                    kernels::conv2d_forward_ep(
                        x, wt, bias, &mut y, n, c, hw, hw, f, k, 1, pad, &steps,
                    );
                    y
                });
                for i in 0..out_len {
                    if unfused[i].to_bits() != fused[i].to_bits() {
                        return Err(format!(
                            "n={n} c={c} hw={hw} f={f} k={k} pad={pad} kind={kind:?} \
                             budget={budget} [{i}]: {} != {} (bitwise)",
                            unfused[i], fused[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Pruning to a subset of outputs never changes the values of the outputs
/// that remain (paper §3.1 feature-extraction claim).
#[test]
fn prop_prune_preserves_remaining_outputs() {
    check_explain(
        "prune-preserves",
        30,
        |rng| {
            let (g, b, d) = random_ew_graph(rng, 20);
            let data: Vec<f32> = (0..b * d).map(|_| rng.uniform(-2.0, 2.0)).collect();
            (g, b, d, data)
        },
        |(g, b, d, data)| {
            let run = |graph: Graph, out_idx: usize| -> Result<Vec<f32>, String> {
                let engine = create(EngineKind::Threaded, 2);
                let mut args = HashMap::new();
                args.insert(
                    "data".to_string(),
                    NDArray::from_vec_on(&[*b, *d], data.clone(), engine.clone()),
                );
                let exec = Executor::bind_graph(
                    graph,
                    engine,
                    args,
                    &[],
                    BindConfig { training: false, ..Default::default() },
                )
                .map_err(|e| e.to_string())?;
                exec.forward();
                exec.wait();
                Ok(exec.outputs()[out_idx].to_vec())
            };
            let full = run(g.clone(), 0)?;
            let (pruned, remap) =
                mixnet::graph::optimize::prune(g, &g.outputs[..1]);
            let mut pg = pruned;
            pg.outputs = vec![Entry { node: remap[&g.outputs[0].node], out: g.outputs[0].out }];
            if pg.nodes.len() > g.nodes.len() {
                return Err("prune grew the graph".into());
            }
            let got = run(pg, 0)?;
            if got != full {
                return Err("pruned output differs".into());
            }
            Ok(())
        },
    );
}

/// RecordIO: random payload roundtrip and corruption tolerance.
#[test]
fn prop_recordio_roundtrip_and_corruption() {
    use mixnet::io::{RecordReader, RecordWriter};
    check_explain(
        "recordio",
        25,
        |rng| {
            let recs: Vec<Vec<u8>> = (0..1 + rng.below(10))
                .map(|_| (0..rng.below(200)).map(|_| rng.below(256) as u8).collect())
                .collect();
            let flip = rng.below(200);
            (recs, flip)
        },
        |(recs, flip)| {
            let path = std::env::temp_dir().join(format!(
                "mixnet_prop_{}_{:?}.rec",
                std::process::id(),
                std::thread::current().id()
            ));
            let mut w = RecordWriter::create(&path).map_err(|e| e.to_string())?;
            for r in recs {
                w.write_record(r).map_err(|e| e.to_string())?;
            }
            w.finish().map_err(|e| e.to_string())?;
            // clean read-back
            let mut rd = RecordReader::open(&path).map_err(|e| e.to_string())?;
            for r in recs {
                let got = rd.next_record().map_err(|e| e.to_string())?.ok_or("eof")?;
                if got != *r {
                    std::fs::remove_file(&path).ok();
                    return Err("payload mismatch".into());
                }
            }
            // corruption must not panic
            let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
            if !bytes.is_empty() {
                let i = flip % bytes.len();
                bytes[i] ^= 0xff;
                std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
                if let Ok(mut rd) = RecordReader::open(&path) {
                    while let Ok(Some(_)) = rd.next_record() {}
                }
            }
            std::fs::remove_file(&path).ok();
            Ok(())
        },
    );
}

//! Data-pipeline integration (paper §2.4): synthetic dataset -> RecordIO
//! file -> prefetching iterator -> training run.

use std::sync::Arc;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::BindConfig;
use mixnet::io::synth::{self, write_recordio};
use mixnet::io::{DataIter, PrefetchIter, RecordFileIter};
use mixnet::models::mlp;
use mixnet::module::{Module, UpdateMode};
use mixnet::optimizer::Sgd;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("mixnet_{}_{name}.rec", std::process::id()))
}

#[test]
fn recordio_prefetch_train_end_to_end() {
    let path = tmp("e2e");
    let ds = synth::class_clusters(512, 4, 16, 0.3, 11);
    write_recordio(&ds, &path).unwrap();

    let engine = create(EngineKind::Threaded, 4);
    let inner = RecordFileIter::open(&path, 32, engine.clone()).unwrap();
    let mut iter = PrefetchIter::new(Box::new(inner), 4);

    let model = mlp(&[32], 16, 4);
    let shapes = model.param_shapes(32).unwrap();
    let mut module = Module::new(model.symbol, engine);
    module.bind(32, &[16], &shapes, BindConfig::default(), 3).unwrap();
    let stats = module
        .fit(&mut iter, &UpdateMode::Local(Arc::new(Sgd::new(0.4))), 4)
        .unwrap();
    assert!(stats.last().unwrap().accuracy > 0.9, "{:?}", stats.last());
    std::fs::remove_file(&path).ok();
}

#[test]
fn prefetch_yields_identical_batches() {
    let path = tmp("ident");
    let ds = synth::class_clusters(96, 3, 8, 0.2, 5);
    write_recordio(&ds, &path).unwrap();
    let engine = create(EngineKind::Threaded, 2);

    let mut plain = RecordFileIter::open(&path, 16, engine.clone()).unwrap();
    let mut pref =
        PrefetchIter::new(Box::new(RecordFileIter::open(&path, 16, engine).unwrap()), 3);
    loop {
        match (plain.next_batch(), pref.next_batch()) {
            (None, None) => break,
            (Some(a), Some(b)) => {
                assert_eq!(a.data.to_vec(), b.data.to_vec());
                assert_eq!(a.label.to_vec(), b.label.to_vec());
            }
            (a, b) => panic!("length mismatch: {:?} vs {:?}", a.is_some(), b.is_some()),
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn image_dataset_roundtrip() {
    let path = tmp("img");
    let ds = synth::images(64, 4, 1, 8, 8, 0.2, 9);
    write_recordio(&ds, &path).unwrap();
    let engine = create(EngineKind::Threaded, 2);
    let mut it = RecordFileIter::open(&path, 8, engine).unwrap();
    let mut n = 0;
    while let Some(b) = it.next_batch() {
        assert_eq!(b.data.shape(), &[8, 1, 8, 8]);
        assert!(b.label.to_vec().iter().all(|&l| l < 4.0));
        n += 1;
    }
    assert_eq!(n, 8);
    std::fs::remove_file(&path).ok();
}

//! Sharded parameter-server acceptance (ISSUE 10).
//!
//! The determinism contract extends the data-parallel one: the *shard
//! count of the server fleet* must not change the math.  Whole keys move
//! to their home shard wholesale; oversized keys are range-split into
//! per-shard contiguous slices, and elementwise SGD on a slice is
//! bitwise identical to the same elements updated inside the whole
//! array — so N-shard Sequential training is **bitwise identical** to
//! 1-shard training (asserted below for the MLP and AlexNet, devices
//! {1, 2}, shards {1, 2, 4}, with a split threshold small enough to
//! force the split path on these small models).  Fault injection scoped
//! to a single shard must not change a bit either: PR 6's per-machine
//! seq/dedup/retry machinery holds per shard.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use mixnet::engine::{create, EngineKind};
use mixnet::io::{synth, ArrayDataIter};
use mixnet::kvstore::dist::{DistKVStore, RetryCfg};
use mixnet::kvstore::fault::FaultPlan;
use mixnet::kvstore::server::{PsServer, ServerConfig, ServerUpdater};
use mixnet::kvstore::shard::ShardRouter;
use mixnet::kvstore::{Consistency, KVStore};
use mixnet::models::{alexnet, mlp};
use mixnet::module::{DataParallelTrainer, EpochStats, TrainerConfig};
use mixnet::ndarray::NDArray;

/// One shard process of an `n`-way fleet (all in-process, ephemeral
/// ports).  Returns the servers and the ordered address list — the
/// ordered list IS the router contract.
fn start_fleet(
    n: usize,
    machines: usize,
    up: ServerUpdater,
) -> (Vec<PsServer>, Vec<std::net::SocketAddr>) {
    let mut servers = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for i in 0..n {
        let cfg = ServerConfig { shard: Some((i as u32, n as u32)), ..ServerConfig::default() };
        let s = PsServer::start_with(0, machines, up, cfg).unwrap();
        addrs.push(s.addr());
        servers.push(s);
    }
    (servers, addrs)
}

fn fast_retry() -> RetryCfg {
    RetryCfg {
        connect_timeout: Duration::from_millis(2000),
        op_timeout: Duration::from_millis(400),
        park_timeout: Duration::from_millis(8000),
        max_retries: 20,
        backoff_base: Duration::from_millis(2),
        backoff_cap: Duration::from_millis(20),
        heartbeat: None,
    }
}

fn assert_params_bitwise_eq(a: &HashMap<String, Vec<f32>>, b: &HashMap<String, Vec<f32>>) {
    assert_eq!(a.len(), b.len());
    for (name, va) in a {
        let vb = &b[name];
        assert_eq!(va.len(), vb.len(), "{name}: length");
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{name}[{i}]: {x} vs {y} — shard count changed the math"
            );
        }
    }
}

/// Train the Figure 2 MLP against an `nsrv`-shard fleet and return
/// (master weights, epoch stats).  `split_elems` is tiny so even this
/// small model exercises the range-split path; `plans[i]` injects
/// faults on the connection to shard `i` only.
fn train_mlp_sharded(
    devices: usize,
    nsrv: usize,
    split_elems: usize,
    epochs: usize,
    plans: Option<Vec<Option<Arc<FaultPlan>>>>,
) -> (HashMap<String, Vec<f32>>, Vec<EpochStats>, Vec<u64>) {
    let shards = 2usize; // local device shards (level-1), fixed
    let up = ServerUpdater { lr: 0.5, momentum: 0.9, weight_decay: 1e-4, rescale: 1.0 };
    let (mut servers, addrs) = start_fleet(nsrv, 1, up);
    let engine = create(EngineKind::Threaded, 4);
    let plans = plans.unwrap_or_else(|| vec![None; nsrv]);
    let router = ShardRouter::new(nsrv).with_split_elems(split_elems);
    let kv = Arc::new(
        DistKVStore::connect_sharded(
            &addrs,
            0,
            shards,
            Consistency::Sequential,
            engine.clone(),
            fast_retry(),
            plans,
            router,
        )
        .unwrap()
        .with_grad_rescale(1.0 / shards as f32),
    );
    let store: Arc<dyn KVStore> = kv.clone();
    let model = mlp(&[32], 16, 4);
    let shard_batch = 8usize;
    let shapes = model.param_shapes(shard_batch).unwrap();
    let ds = synth::class_clusters(512, 4, 16, 0.3, 5);
    let mut iter = ArrayDataIter::new(
        ds.features,
        ds.labels,
        &[16],
        shards * shard_batch,
        true,
        engine.clone(),
    );
    let mut t = DataParallelTrainer::bind(
        &model.symbol,
        engine,
        shard_batch,
        &[16],
        &shapes,
        store,
        TrainerConfig { devices, shards, seed: 1, ..Default::default() },
    )
    .unwrap();
    let stats = t.fit(&mut iter, epochs).unwrap();
    kv.barrier().unwrap();
    let params = t.pull_params().unwrap();
    let cs = kv.client_stats();
    assert_eq!(cs.shards.len(), nsrv, "one stats row per shard");
    let per_shard_retries = cs.shards.iter().map(|s| s.retries).collect();
    drop(t);
    drop(kv);
    for s in &mut servers {
        s.shutdown();
    }
    (params, stats, per_shard_retries)
}

/// The tentpole assertion: MLP Sequential training is bitwise identical
/// for server-shard counts {1, 2, 4} and device counts {1, 2}, split
/// path forced (threshold 64 splits every fc weight in this model).
#[test]
fn mlp_bitwise_identical_across_shard_counts() {
    let (ref_p, ref_s, _) = train_mlp_sharded(1, 1, 64, 3, None);
    for devices in [1usize, 2] {
        for nsrv in [1usize, 2, 4] {
            if devices == 1 && nsrv == 1 {
                continue;
            }
            let (p, s, _) = train_mlp_sharded(devices, nsrv, 64, 3, None);
            assert_params_bitwise_eq(&ref_p, &p);
            for (a, b) in ref_s.iter().zip(&s) {
                assert_eq!(
                    a.loss.to_bits(),
                    b.loss.to_bits(),
                    "epoch {} loss ({devices} devices, {nsrv} shards)",
                    a.epoch
                );
                assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            }
        }
    }
    // and it actually learns the task
    assert!(ref_s.last().unwrap().accuracy > 0.85, "{:?}", ref_s.last());
}

/// Whole-key regime (splitting disabled): keys scatter to their home
/// shards and the math is still bitwise stable.
#[test]
fn mlp_bitwise_identical_whole_key_regime() {
    let (p1, _, _) = train_mlp_sharded(1, 1, 0, 2, None);
    let (p4, _, _) = train_mlp_sharded(2, 4, 0, 2, None);
    assert_params_bitwise_eq(&p1, &p4);
}

/// AlexNet (full topology incl. step-seeded Dropout): shard count and
/// device count both invariant, split path forced on the fc layers.
fn train_alexnet_sharded(devices: usize, nsrv: usize) -> HashMap<String, Vec<f32>> {
    let shards = 2usize;
    let up = ServerUpdater { lr: 0.01, momentum: 0.9, weight_decay: 1e-4, rescale: 1.0 };
    let (mut servers, addrs) = start_fleet(nsrv, 1, up);
    let engine = create(EngineKind::Threaded, 4);
    let router = ShardRouter::new(nsrv).with_split_elems(4096);
    let kv = Arc::new(
        DistKVStore::connect_sharded(
            &addrs,
            0,
            shards,
            Consistency::Sequential,
            engine.clone(),
            fast_retry(),
            vec![None; nsrv],
            router,
        )
        .unwrap()
        .with_grad_rescale(1.0 / shards as f32),
    );
    let store: Arc<dyn KVStore> = kv.clone();
    let model = alexnet(4, 64);
    let shard_batch = 2usize;
    let shapes = model.param_shapes(shard_batch).unwrap();
    let ds = synth::images(2 * shards * shard_batch, 4, 3, 64, 64, 0.3, 9);
    let mut iter = ArrayDataIter::new(
        ds.features,
        ds.labels,
        &[3, 64, 64],
        shards * shard_batch,
        false,
        engine.clone(),
    );
    let mut t = DataParallelTrainer::bind(
        &model.symbol,
        engine,
        shard_batch,
        &[3, 64, 64],
        &shapes,
        store,
        TrainerConfig { devices, shards, seed: 3, ..Default::default() },
    )
    .unwrap();
    t.fit(&mut iter, 1).unwrap();
    kv.barrier().unwrap();
    let params = t.pull_params().unwrap();
    drop(t);
    drop(kv);
    for s in &mut servers {
        s.shutdown();
    }
    params
}

#[test]
fn alexnet_bitwise_identical_across_shard_counts() {
    let p1 = train_alexnet_sharded(1, 1);
    let p2 = train_alexnet_sharded(2, 2);
    let p4 = train_alexnet_sharded(1, 4);
    assert_params_bitwise_eq(&p1, &p2);
    assert_params_bitwise_eq(&p1, &p4);
}

/// Big-key split/reassembly property: a key far above the split
/// threshold pushes per-shard sub-range messages and pulls back
/// reassembled bitwise — for lengths that are exact multiples of the
/// shard count, off-by-one remainders, primes, and length < shards.
#[test]
fn big_key_split_reassembly_roundtrip() {
    let up = ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 };
    let nsrv = 4usize;
    let (mut servers, addrs) = start_fleet(nsrv, 1, up);
    let engine = create(EngineKind::Threaded, 4);
    let kv = DistKVStore::connect_sharded(
        &addrs,
        0,
        1,
        Consistency::Sequential,
        engine.clone(),
        fast_retry(),
        vec![None; nsrv],
        ShardRouter::new(nsrv).with_split_elems(8),
    )
    .unwrap();
    // Deterministic xorshift data, fresh key per case.
    let mut state = 0x2545_f491_4f6c_dd1du64;
    let mut rng = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        (state >> 40) as f32 / 256.0 - 32.0
    };
    for (case, len) in [8usize, 16, 17, 31, 97, 3, 1000].into_iter().enumerate() {
        let key = format!("big{case}");
        let init: Vec<f32> = (0..len).map(|_| rng()).collect();
        let grad: Vec<f32> = (0..len).map(|_| rng()).collect();
        kv.init(&key, &NDArray::from_vec_on(&[len], init.clone(), engine.clone())).unwrap();
        kv.push(&key, &NDArray::from_vec_on(&[len], grad.clone(), engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[len], engine.clone());
        kv.pull(&key, &out, 0).unwrap();
        kv.flush();
        let got = out.to_vec();
        // lr=1, no momentum/decay: w = init - grad, elementwise — the
        // split must reassemble to exactly the unsharded SGD result.
        for i in 0..len {
            let want = init[i] - grad[i];
            assert_eq!(
                got[i].to_bits(),
                want.to_bits(),
                "len {len} elem {i}: {} vs {want}",
                got[i]
            );
        }
    }
    kv.barrier().unwrap();
    // Satellite: server_stats fans out to every shard and sums.
    let per = kv.server_stats_sharded().unwrap();
    assert_eq!(per.len(), nsrv);
    let sum = kv.server_stats().unwrap();
    assert_eq!(sum.msgs, per.iter().map(|s| s.msgs).sum::<u64>());
    assert_eq!(sum.applies, per.iter().map(|s| s.applies).sum::<u64>());
    // Every shard saw traffic: lengths >= 8 split across all 4 shards.
    for (i, s) in per.iter().enumerate() {
        assert!(s.msgs > 0, "shard {i} never saw a message");
        assert!(s.applies > 0, "shard {i} never applied a round");
    }
    drop(kv);
    for s in &mut servers {
        s.shutdown();
    }
}

/// Fault injection scoped to ONE shard of a 2-shard fleet: retries land
/// on that shard alone (per-shard seq/dedup/retry isolation) and the
/// run stays bitwise identical to the fault-free sharded run.
#[test]
fn single_shard_faults_stay_bitwise() {
    let (clean_p, _, _) = train_mlp_sharded(2, 2, 64, 2, None);

    let plan = FaultPlan::new(0xfa17).with_drop(0.05).with_dup(0.05);
    let plans = vec![None, Some(Arc::new(plan))];
    let (faulty_p, _, rt) = train_mlp_sharded(2, 2, 64, 2, Some(plans));
    // Per-shard attribution: the chaos is on shard 1's connection, so
    // its retry counter must move (shard 0 may log the odd timeout
    // retry on a loaded runner, but the injected faults land on 1).
    assert!(rt[1] > 0, "faults on shard 1 were not exercised: {rt:?}");
    assert_params_bitwise_eq(&clean_p, &faulty_p);
}

/// One multiplexed heartbeat loop serves every shard: liveness and beat
/// counters tick per shard in `client_stats()`.
#[test]
fn heartbeat_multiplexes_across_shards() {
    let up = ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 };
    let nsrv = 3usize;
    let (mut servers, addrs) = start_fleet(nsrv, 1, up);
    let engine = create(EngineKind::Threaded, 2);
    let cfg = RetryCfg { heartbeat: Some(Duration::from_millis(50)), ..fast_retry() };
    let kv = DistKVStore::connect_sharded(
        &addrs,
        0,
        1,
        Consistency::Sequential,
        engine,
        cfg,
        vec![None; nsrv],
        ShardRouter::new(nsrv),
    )
    .unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let cs = kv.client_stats();
        assert_eq!(cs.shards.len(), nsrv);
        if cs.shards.iter().all(|s| s.heartbeats > 0) {
            assert!(cs.shards.iter().all(|s| s.alive), "a heartbeating shard reads dead");
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "heartbeats never reached every shard: {:?}",
            cs.shards
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    drop(kv);
    for s in &mut servers {
        s.shutdown();
    }
}

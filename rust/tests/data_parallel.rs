//! Data-parallel multi-device training acceptance (ISSUE 4).
//!
//! The determinism contract: the *shard count* defines the math, devices
//! only decide where shards run — so for a fixed shard count, N-device
//! `Sequential` training is **bitwise identical** to 1-device training
//! (asserted for the MLP and AlexNet, and for overlap-on vs overlap-off
//! pushes).  CI repeats this file under `PALLAS_INTRA_THREADS` in
//! {1, 4}; the intra-op budget must not change a single bit either.
//! `Eventual` mode must still reach comparable quality, and the
//! dist-kvstore loopback (trainer -> DistKVStore -> PsServer over local
//! TCP) must converge and round-trip the master weights.

use std::collections::HashMap;
use std::sync::Arc;

use mixnet::engine::{create, EngineKind};
use mixnet::io::{synth, ArrayDataIter};
use mixnet::kvstore::dist::DistKVStore;
use mixnet::kvstore::server::{PsServer, ServerUpdater};
use mixnet::kvstore::{Consistency, KVStore, LocalKVStore};
use mixnet::models::{alexnet, mlp};
use mixnet::module::{DataParallelTrainer, EpochStats, TrainerConfig};
use mixnet::optimizer::Sgd;

/// Train the Figure 2 MLP data-parallel and return (master weights,
/// epoch stats).
fn train_mlp(
    devices: usize,
    shards: usize,
    overlap: bool,
    consistency: Consistency,
    epochs: usize,
) -> (HashMap<String, Vec<f32>>, Vec<EpochStats>) {
    let engine = create(EngineKind::Threaded, 4);
    let model = mlp(&[32], 16, 4);
    let shard_batch = 8usize;
    let global = shards * shard_batch;
    let ds = synth::class_clusters(512, 4, 16, 0.3, 5);
    let mut iter =
        ArrayDataIter::new(ds.features, ds.labels, &[16], global, true, engine.clone());
    let shapes = model.param_shapes(shard_batch).unwrap();
    // merged gradient = sum of per-shard means -> rescale to batch mean
    let store = Arc::new(LocalKVStore::new(
        engine.clone(),
        shards,
        Arc::new(Sgd::new(0.5).rescale(1.0 / shards as f32)),
        consistency,
    ));
    let mut t = DataParallelTrainer::bind(
        &model.symbol,
        engine,
        shard_batch,
        &[16],
        &shapes,
        store,
        TrainerConfig { devices, shards, overlap, seed: 1, ..Default::default() },
    )
    .unwrap();
    let stats = t.fit(&mut iter, epochs).unwrap();
    (t.pull_params().unwrap(), stats)
}

fn assert_params_bitwise_eq(a: &HashMap<String, Vec<f32>>, b: &HashMap<String, Vec<f32>>) {
    assert_eq!(a.len(), b.len());
    for (name, va) in a {
        let vb = &b[name];
        assert_eq!(va.len(), vb.len(), "{name}: length");
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{name}[{i}]: {x} vs {y} — device count changed the math"
            );
        }
    }
}

#[test]
fn mlp_sequential_bitwise_identical_across_device_counts() {
    // 4 shards fixed; 1, 2 and 4 devices must produce identical master
    // weights AND identical per-epoch loss curves, bit for bit.
    let (p1, s1) = train_mlp(1, 4, true, Consistency::Sequential, 3);
    let (p2, s2) = train_mlp(2, 4, true, Consistency::Sequential, 3);
    let (p4, s4) = train_mlp(4, 4, true, Consistency::Sequential, 3);
    assert_params_bitwise_eq(&p1, &p2);
    assert_params_bitwise_eq(&p1, &p4);
    for ((a, b), c) in s1.iter().zip(&s2).zip(&s4) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "epoch {} loss", a.epoch);
        assert_eq!(a.loss.to_bits(), c.loss.to_bits(), "epoch {} loss", a.epoch);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.accuracy.to_bits(), c.accuracy.to_bits());
    }
    // and it actually learns the task
    assert!(s1.last().unwrap().accuracy > 0.85, "{:?}", s1.last());
}

#[test]
fn overlap_on_and_off_are_bitwise_identical() {
    // Per-layer mid-backward pushes vs after-backward pushes: the staged
    // part reduction is in part order either way, so only the timing may
    // differ — never the result.
    let (on, _) = train_mlp(2, 4, true, Consistency::Sequential, 2);
    let (off, _) = train_mlp(2, 4, false, Consistency::Sequential, 2);
    assert_params_bitwise_eq(&on, &off);
}

#[test]
fn eventual_mode_reaches_comparable_loss() {
    let (_, seq) = train_mlp(4, 4, true, Consistency::Sequential, 6);
    let (_, evt) = train_mlp(4, 4, true, Consistency::Eventual, 6);
    let (sa, ea) = (seq.last().unwrap().accuracy, evt.last().unwrap().accuracy);
    assert!(ea > 0.8, "eventual accuracy {ea}");
    assert!(ea > sa - 0.15, "eventual {ea} too far behind sequential {sa}");
}

/// AlexNet (reduced 64x64 input, full topology incl. Dropout): the
/// step-seeded dropout masks draw from the round number, so they are
/// device-count invariant too.
fn train_alexnet(devices: usize, shards: usize) -> HashMap<String, Vec<f32>> {
    let engine = create(EngineKind::Threaded, 4);
    let model = alexnet(4, 64);
    let shard_batch = 2usize;
    let global = shards * shard_batch;
    let ds = synth::images(2 * global, 4, 3, 64, 64, 0.3, 9);
    let mut iter =
        ArrayDataIter::new(ds.features, ds.labels, &[3, 64, 64], global, false, engine.clone());
    let shapes = model.param_shapes(shard_batch).unwrap();
    let store = Arc::new(LocalKVStore::new(
        engine.clone(),
        shards,
        Arc::new(Sgd::new(0.01).rescale(1.0 / shards as f32)),
        Consistency::Sequential,
    ));
    let mut t = DataParallelTrainer::bind(
        &model.symbol,
        engine,
        shard_batch,
        &[3, 64, 64],
        &shapes,
        store,
        TrainerConfig { devices, shards, seed: 3, ..Default::default() },
    )
    .unwrap();
    t.fit(&mut iter, 1).unwrap();
    t.pull_params().unwrap()
}

#[test]
fn alexnet_sequential_bitwise_identical_across_device_counts() {
    let p1 = train_alexnet(1, 2);
    let p2 = train_alexnet(2, 2);
    assert_params_bitwise_eq(&p1, &p2);
}

#[test]
fn dist_kvstore_loopback_roundtrip() {
    // One machine, two local device shards, real TCP loopback: the
    // trainer's per-layer pushes aggregate level-1, ship one message per
    // round, and training converges; pulled master weights round-trip
    // stably.
    let server = PsServer::start(
        0,
        1,
        ServerUpdater { lr: 0.5, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
    )
    .unwrap();
    let engine = create(EngineKind::Threaded, 4);
    // client-side rescale: the shipped gradient is the global-batch mean
    let kv = Arc::new(
        DistKVStore::connect(server.addr(), 0, 2, Consistency::Sequential, engine.clone())
            .unwrap()
            .with_grad_rescale(0.5),
    );
    let store: Arc<dyn KVStore> = kv.clone();
    let model = mlp(&[32], 16, 4);
    let shapes = model.param_shapes(8).unwrap();
    let ds = synth::class_clusters(512, 4, 16, 0.3, 5);
    let mut iter = ArrayDataIter::new(ds.features, ds.labels, &[16], 16, true, engine.clone());
    let mut t = DataParallelTrainer::bind(
        &model.symbol,
        engine,
        8,
        &[16],
        &shapes,
        store,
        TrainerConfig { devices: 2, shards: 2, seed: 1, ..Default::default() },
    )
    .unwrap();
    let stats = t.fit(&mut iter, 4).unwrap();
    assert!(stats.last().unwrap().accuracy > 0.85, "{:?}", stats.last());
    kv.barrier().unwrap();
    // round-trip: two consecutive pulls of the master weights agree
    let a = t.pull_params().unwrap();
    let b = t.pull_params().unwrap();
    for (name, va) in &a {
        assert_eq!(va, &b[name], "{name}: pull round-trip unstable");
    }
    assert!(!a.is_empty());
}

//! ISSUE 3 acceptance tests: static run-plan replay and the pooled
//! storage allocator.
//!
//! * **Replay equivalence** — an executor bound with `replay: true`
//!   (one run-plan op per pass, lock-free in-plan scheduling) must be
//!   *bitwise* identical to the classic per-op push path, across engine
//!   worker counts {1, 4, 8}, for MLP and AlexNet forward/backward,
//!   with imperative SGD updates interleaved between steps (the
//!   plan/engine interop contract).  The intra-op dimension cannot vary
//!   in-process (the intra pool is a process-wide OnceLock sized from
//!   `PALLAS_INTRA_THREADS`), so CI reruns the `*_replay_matches_*`
//!   tests under PALLAS_INTRA_THREADS ∈ {1, 4, 8}; kernel-level
//!   thread-count bitwise independence is additionally property-tested
//!   in tests/properties.rs.
//! * **Pool recycling** — after warmup, a training step, a rebind, and
//!   a served batch must add **zero** misses to the storage pool (the
//!   "no steady-state heap allocation" criterion, asserted through the
//!   pool miss counter), and concurrent serve workers recycling buffers
//!   must never alias each other (responses stay bitwise equal to a
//!   batch-1 forward).
//!
//! Every test takes `POOL_LOCK`: the pool counters are process-global,
//! so tests in this binary serialize to keep miss/hit deltas attributable.

use std::collections::HashMap;
use std::sync::Mutex;

use mixnet::engine::{create, EngineKind, EngineRef};
use mixnet::executor::{BindConfig, Executor};
use mixnet::models::{alexnet, mlp, Model};
use mixnet::module::Module;
use mixnet::ndarray::{pool, NDArray};
use mixnet::serve::{ExecPool, Servable, ServeConfig, Server};
use mixnet::util::Rng;

static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Deterministic values for every variable (data, label, params) of a
/// model — generated once, shared verbatim by every bind under test.
fn gen_values(model: &Model, batch: usize) -> (HashMap<String, Vec<f32>>, Vec<String>) {
    let shapes = model.var_shapes(batch).unwrap();
    let mut names: Vec<String> = shapes.keys().cloned().collect();
    names.sort();
    let mut vals = HashMap::new();
    for (i, name) in names.iter().enumerate() {
        let n: usize = shapes[name].iter().product();
        let mut rng = Rng::seed_from_u64(0xA11CE + i as u64);
        let v: Vec<f32> = if name.ends_with("_label") {
            (0..n).map(|j| (j % model.num_classes) as f32).collect()
        } else {
            (0..n).map(|_| rng.normal_with(0.0, 0.15)).collect()
        };
        vals.insert(name.clone(), v);
    }
    let params = names
        .iter()
        .filter(|n| n.as_str() != "data" && !n.ends_with("_label"))
        .cloned()
        .collect();
    (vals, params)
}

/// Bind (replay or push mode), run `steps` of forward/backward with an
/// imperative `w -= eta * g` between steps, and return the bit patterns
/// of the head output, every gradient and every updated parameter.
fn run_model(
    model: &Model,
    batch: usize,
    workers: usize,
    replay: bool,
    steps: usize,
    vals: &HashMap<String, Vec<f32>>,
    params: &[String],
) -> Vec<Vec<u32>> {
    run_model_fuse(model, batch, workers, replay, true, steps, vals, params)
}

/// `run_model` with the graph-fusion knob exposed (fused vs unfused
/// binds must be bitwise identical — the epilogue-fusion contract).
#[allow(clippy::too_many_arguments)]
fn run_model_fuse(
    model: &Model,
    batch: usize,
    workers: usize,
    replay: bool,
    fuse: bool,
    steps: usize,
    vals: &HashMap<String, Vec<f32>>,
    params: &[String],
) -> Vec<Vec<u32>> {
    let engine = create(EngineKind::Threaded, workers);
    let shapes = model.var_shapes(batch).unwrap();
    let args: HashMap<String, NDArray> = vals
        .iter()
        .map(|(k, v)| (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone())))
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let cfg = BindConfig { replay, fuse, ..Default::default() };
    let exec = Executor::bind(&model.symbol, engine.clone(), args, &grad_names, cfg).unwrap();
    for _ in 0..steps {
        exec.forward_backward().unwrap();
        for p in params {
            // imperative update on the same engine: must order against
            // the replayed plans through the boundary vars
            exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), 0.05);
        }
    }
    exec.wait();
    let mut out = vec![bits(&exec.outputs()[0].to_vec())];
    for p in params {
        out.push(bits(&exec.grad(p).unwrap().to_vec()));
        out.push(bits(&exec.arg(p).unwrap().to_vec()));
    }
    out
}

fn assert_bits_eq(got: &[Vec<u32>], want: &[Vec<u32>], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: section count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.len(), w.len(), "{ctx}: length of section {i}");
        let diff = g.iter().zip(w).filter(|(a, b)| a != b).count();
        assert!(diff == 0, "{ctx}: section {i} differs in {diff}/{} words", g.len());
    }
}

#[test]
fn mlp_replay_matches_push_bitwise_across_worker_counts() {
    let _g = lock();
    let model = mlp(&[32, 16], 16, 4);
    let (vals, params) = gen_values(&model, 8);
    let reference = run_model(&model, 8, 1, false, 3, &vals, &params);
    for workers in [1usize, 4, 8] {
        for replay in [false, true] {
            let got = run_model(&model, 8, workers, replay, 3, &vals, &params);
            assert_bits_eq(&got, &reference, &format!("mlp workers={workers} replay={replay}"));
        }
    }
}

#[test]
fn alexnet_replay_matches_push_bitwise() {
    let _g = lock();
    // Full AlexNet topology on a 64x64 input (the model zoo's CPU-budget
    // knob); dropout is live in training mode and must stay step-seeded
    // identically on both paths.
    let model = alexnet(4, 64);
    let (vals, params) = gen_values(&model, 1);
    let reference = run_model(&model, 1, 1, false, 1, &vals, &params);
    for (workers, replay) in [(1usize, true), (4, true), (4, false)] {
        let got = run_model(&model, 1, workers, replay, 1, &vals, &params);
        assert_bits_eq(
            &got,
            &reference,
            &format!("alexnet workers={workers} replay={replay}"),
        );
    }
}

#[test]
fn alexnet_epilogue_fusion_is_bitwise_lossless_fwd_bwd() {
    let _g = lock();
    // The graph compiler folds conv+relu / fc+relu chains into GEMM
    // epilogues on the fused bind; output, every gradient, and every
    // updated parameter must still match the unfused bind bitwise
    // (forward AND backward — fusion only rewrites forward nodes).
    let model = alexnet(4, 64);
    let (vals, params) = gen_values(&model, 1);
    let unfused = run_model_fuse(&model, 1, 4, false, false, 1, &vals, &params);
    let fused = run_model_fuse(&model, 1, 4, false, true, 1, &vals, &params);
    assert_bits_eq(&fused, &unfused, "alexnet fused-vs-unfused");
}

#[test]
fn fused_plan_does_zero_pool_misses_after_warmup() {
    let _g = lock();
    // Epilogue-fused AlexNet bind: fewer, heavier ops — and still no
    // steady-state pool allocation.
    let model = alexnet(4, 64);
    let (vals, params) = gen_values(&model, 1);
    let engine = create(EngineKind::Threaded, 4);
    let shapes = model.var_shapes(1).unwrap();
    let args: HashMap<String, NDArray> = vals
        .iter()
        .map(|(k, v)| (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone())))
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let exec =
        Executor::bind(&model.symbol, engine.clone(), args, &grad_names, BindConfig::default())
            .unwrap();
    let fused_nodes = exec
        .graph()
        .nodes
        .iter()
        .filter(|n| !n.op.epilogue().is_empty())
        .count();
    assert!(fused_nodes > 0, "alexnet bind should contain epilogue-fused nodes");
    let step = |exec: &Executor| {
        exec.forward_backward().unwrap();
        for p in &params {
            exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), 0.05);
        }
    };
    for _ in 0..2 {
        step(&exec); // warmup
    }
    exec.wait();
    let before = pool::global().stats();
    for _ in 0..3 {
        step(&exec);
    }
    exec.wait();
    let after = pool::global().stats();
    assert_eq!(
        after.misses, before.misses,
        "a steady-state fused-plan step must not allocate (pool miss counter moved)"
    );
}

#[test]
fn training_steps_do_zero_pool_allocations_after_warmup() {
    let _g = lock();
    let model = mlp(&[32, 16], 16, 4);
    let (vals, params) = gen_values(&model, 8);
    let engine = create(EngineKind::Threaded, 4);
    let shapes = model.var_shapes(8).unwrap();
    let args: HashMap<String, NDArray> = vals
        .iter()
        .map(|(k, v)| (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone())))
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let exec =
        Executor::bind(&model.symbol, engine.clone(), args, &grad_names, BindConfig::default())
            .unwrap();
    let step = |exec: &Executor| {
        exec.forward_backward().unwrap();
        for p in &params {
            exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), 0.05);
        }
    };
    for _ in 0..3 {
        step(&exec); // warmup
    }
    exec.wait();
    let before = pool::global().stats();
    for _ in 0..10 {
        step(&exec);
    }
    exec.wait();
    let after = pool::global().stats();
    assert_eq!(
        after.misses, before.misses,
        "a steady-state training step must not allocate (pool miss counter moved)"
    );
}

#[test]
fn rebinding_a_model_draws_all_storage_from_the_pool() {
    let _g = lock();
    let model = mlp(&[32, 16], 16, 4);
    let (vals, params) = gen_values(&model, 8);
    let build_step_drop = || {
        let engine = create(EngineKind::Threaded, 2);
        let shapes = model.var_shapes(8).unwrap();
        let args: HashMap<String, NDArray> = vals
            .iter()
            .map(|(k, v)| {
                (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone()))
            })
            .collect();
        let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
        let exec =
            Executor::bind(&model.symbol, engine.clone(), args, &grad_names, BindConfig::default())
                .unwrap();
        exec.forward_backward().unwrap();
        exec.wait();
        // exec (plan blocks, workspace, outputs, grads) drops here and
        // recycles every buffer
    };
    build_step_drop(); // warm: shelve every size this bind uses
    // No settle needed: the replay barrier's helper gate guarantees that
    // once wait() returns and the executor drops, every plan buffer is
    // already back on the shelf (deterministic release).
    let before = pool::global().stats();
    build_step_drop();
    let after = pool::global().stats();
    assert_eq!(
        after.misses, before.misses,
        "rebinding the same model must be served entirely from the pool"
    );
    assert!(after.hits > before.hits, "rebind should produce pool hits");
}

// ---------------------------------------------------------------------
// serving
// ---------------------------------------------------------------------

const IN_DIM: usize = 12;
const CLASSES: usize = 3;

fn serve_model() -> Model {
    mlp(&[24], IN_DIM, CLASSES)
}

fn servable(engine: &EngineRef) -> Servable {
    let model = serve_model();
    let shapes = model.param_shapes(4).unwrap();
    let mut m = Module::new(serve_model().symbol, engine.clone());
    m.bind_inference(4, &[IN_DIM], &shapes, 42).unwrap();
    let mut params: HashMap<String, NDArray> = HashMap::new();
    for n in m.param_names() {
        params.insert(n.clone(), m.param(n).unwrap().clone());
    }
    Servable::new(model, params, engine.clone()).unwrap()
}

fn sample(i: usize) -> Vec<f32> {
    (0..IN_DIM).map(|j| ((i * IN_DIM + j) as f32 * 0.31).sin()).collect()
}

#[test]
fn serve_dispatch_zero_pool_misses_after_warmup() {
    let _g = lock();
    let engine = create(EngineKind::Threaded, 2);
    let s = servable(&engine);
    let mut pool_exec = ExecPool::for_buckets(&s, &[1, 4]).unwrap();
    let samples: Vec<Vec<f32>> = (0..4).map(sample).collect();
    let rows: Vec<&[f32]> = samples.iter().map(|v| v.as_slice()).collect();
    // warmup: touch every bucket (size 1 -> bucket 1, sizes 2..4 -> 4)
    for size in 1..=4usize {
        pool_exec.run(&rows[..size]);
    }
    engine.wait_all();
    let before = pool::global().stats();
    for round in 0..20usize {
        let size = 1 + round % 4;
        let out = pool_exec.run(&rows[..size]);
        assert_eq!(out.len(), size);
    }
    engine.wait_all();
    let after = pool::global().stats();
    assert_eq!(
        after.misses, before.misses,
        "a steady-state served batch must not allocate (pool miss counter moved)"
    );
    assert!(after.hits > before.hits, "dispatch should lease staging from the pool");
}

#[test]
fn six_worker_serving_is_bitwise_lossless_under_pool_recycling() {
    let _g = lock();
    let engine = create(EngineKind::Threaded, 4);
    let s = servable(&engine);
    let samples: Vec<Vec<f32>> = (0..16).map(sample).collect();
    // batch-1 references (losslessness oracle)
    let mut single = s.bind_bucket(1).unwrap();
    let expected: Vec<Vec<f32>> = samples
        .iter()
        .map(|x| single.run(&[x.as_slice()]).remove(0))
        .collect();
    let cfg = ServeConfig {
        max_batch: 16,
        max_delay_us: 500,
        queue_cap: 256,
        workers: 6,
        buckets: vec![1, 4, 16],
    };
    let mut server = Server::start(&s, &cfg).unwrap();
    // 12 concurrent closed-loop clients: every response must match the
    // batch-1 reference bitwise even though all six workers share the
    // storage pool (scatter leases, bucket buffers) concurrently.
    std::thread::scope(|scope| {
        for c in 0..12usize {
            let (server, samples, expected) = (&server, &samples, &expected);
            scope.spawn(move || {
                for r in 0..15usize {
                    let k = (c + r * 12) % samples.len();
                    let got = server.infer(samples[k].clone()).unwrap();
                    assert_eq!(got, expected[k], "client {c} request {r} sample {k}");
                }
            });
        }
    });
    let stats = server.shutdown();
    assert_eq!(stats.requests, 12 * 15);
}

// ---------------------------------------------------------------------
// plan/engine interop across executors
// ---------------------------------------------------------------------

#[test]
fn two_executors_interleave_through_shared_params() {
    // Two replayed executors bound over the *same* parameter arrays
    // (clone = shared storage + tag, the serving pattern) plus imperative
    // updates: plan boundary vars must serialize everything correctly.
    let _g = lock();
    let model = mlp(&[16], 8, 3);
    let (vals, params) = gen_values(&model, 4);
    let run = |replay: bool| -> Vec<u32> {
        let engine = create(EngineKind::Threaded, 4);
        let shapes = model.var_shapes(4).unwrap();
        let args: HashMap<String, NDArray> = vals
            .iter()
            .map(|(k, v)| {
                (k.clone(), NDArray::from_vec_on(&shapes[k], v.clone(), engine.clone()))
            })
            .collect();
        let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
        let cfg = BindConfig { replay, ..Default::default() };
        let e1 = Executor::bind(&model.symbol, engine.clone(), args.clone(), &grad_names, cfg)
            .unwrap();
        let e2 = Executor::bind(
            &model.symbol,
            engine.clone(),
            args,
            &[],
            BindConfig { replay, ..BindConfig::inference() },
        )
        .unwrap();
        for _ in 0..4 {
            e1.forward_backward().unwrap();
            for p in &params {
                e1.arg(p).unwrap().sub_scaled_(e1.grad(p).unwrap(), 0.1);
            }
            // inference executor reads the freshly-updated params
            e2.forward();
        }
        engine.wait_all();
        bits(&e2.outputs()[0].to_vec())
    };
    assert_eq!(run(true), run(false), "shared-param interleaving differs");
}

#[test]
fn pool_is_enabled_by_default_in_this_suite() {
    // The zero-miss assertions above are vacuous if someone runs the
    // suite with PALLAS_STORAGE_POOL=0; fail loudly instead.
    assert!(
        pool::global().enabled(),
        "plan_pool tests require the storage pool enabled (unset PALLAS_STORAGE_POOL)"
    );
}

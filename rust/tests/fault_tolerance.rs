//! Fault-tolerance acceptance (ISSUE 6): deterministic fault injection
//! over the real TCP transport, retry + seq-dedup idempotence (a
//! retransmitted gradient must never double-apply), lease expiry under
//! both policies, and crash-elastic checkpoint/restore.
//!
//! The bitwise assertions lean on two protocol facts: the server's
//! round reduction pops one pending push per machine in machine-index
//! order (arrival order is irrelevant), and a sequential-consistency
//! client cannot advance a round past an unserved pull — so a run with
//! drops, duplicates, truncations, and connection kills must end at
//! exactly the weights of the fault-free run.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use mixnet::engine::{create, EngineKind, EngineRef};
use mixnet::executor::BindConfig;
use mixnet::io::{synth, ArrayDataIter, DataIter};
use mixnet::kvstore::dist::{DistKVStore, RetryCfg};
use mixnet::kvstore::fault::FaultPlan;
use mixnet::kvstore::server::{ExpiryPolicy, PsServer, ServerConfig, ServerUpdater};
use mixnet::kvstore::{Consistency, KVStore, LocalKVStore};
use mixnet::models::mlp;
use mixnet::module::{DataParallelTrainer, Module, SyncMode, TrainerConfig, UpdateMode};
use mixnet::ndarray::NDArray;
use mixnet::optimizer::Sgd;

fn updater(machines: usize) -> ServerUpdater {
    ServerUpdater { lr: 0.4 / machines as f32, momentum: 0.9, weight_decay: 1e-4, rescale: 1.0 }
}

/// Tight timeouts so injected drops cost milliseconds, not the
/// production 10s/60s deadlines; generous retry budget so a faulty run
/// never gives up.
fn fast_retry() -> RetryCfg {
    RetryCfg {
        connect_timeout: Duration::from_millis(2000),
        op_timeout: Duration::from_millis(400),
        park_timeout: Duration::from_millis(8000),
        max_retries: 20,
        backoff_base: Duration::from_millis(2),
        backoff_cap: Duration::from_millis(20),
        heartbeat: None,
    }
}

fn assert_params_bitwise_eq(a: &HashMap<String, Vec<f32>>, b: &HashMap<String, Vec<f32>>) {
    assert_eq!(a.len(), b.len());
    for (name, va) in a {
        let vb = &b[name];
        assert_eq!(va.len(), vb.len(), "{name}: length");
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}[{i}]: {x} vs {y}");
        }
    }
}

/// One machine of the Figure 2 MLP job through a (possibly faulty)
/// distributed store; returns (accuracy, retries, reconnects).
fn train_machine(
    addr: std::net::SocketAddr,
    machine: u32,
    epochs: usize,
    cfg: RetryCfg,
    plan: Option<Arc<FaultPlan>>,
) -> (f32, u64, u64) {
    let engine = create(EngineKind::Threaded, 2);
    let kv = Arc::new(
        DistKVStore::connect_with(
            addr,
            machine,
            1,
            Consistency::Sequential,
            engine.clone(),
            cfg,
            plan,
        )
        .unwrap(),
    );
    let ds = synth::class_clusters(512, 4, 16, 0.3, 77 + machine as u64);
    let mut iter = ArrayDataIter::new(ds.features, ds.labels, &[16], 32, true, engine.clone());
    let model = mlp(&[32], 16, 4);
    let shapes = model.param_shapes(32).unwrap();
    let mut module = Module::new(model.symbol, engine);
    module.bind(32, &[16], &shapes, BindConfig::default(), 5).unwrap();
    let stats = module
        .fit(&mut iter, &UpdateMode::KvStore { store: kv.clone(), device: 0 }, epochs)
        .unwrap();
    kv.barrier().unwrap();
    let cs = kv.client_stats();
    (stats.last().unwrap().accuracy, cs.retries, cs.reconnects)
}

/// Read the server's final weights over a fresh fault-free connection.
fn final_weights(addr: std::net::SocketAddr) -> HashMap<String, Vec<f32>> {
    let engine = create(EngineKind::Threaded, 2);
    let kv = DistKVStore::connect_with(
        addr,
        0,
        1,
        Consistency::Eventual,
        engine.clone(),
        fast_retry(),
        None,
    )
    .unwrap();
    let model = mlp(&[32], 16, 4);
    let mut out = HashMap::new();
    for (name, shape) in model.param_shapes(32).unwrap() {
        let arr = NDArray::zeros_on(&shape, engine.clone());
        kv.pull(&name, &arr, 0).unwrap();
        kv.flush();
        out.insert(name.clone(), arr.to_vec());
    }
    out
}

struct DistRun {
    weights: HashMap<String, Vec<f32>>,
    applies: u64,
    dedup_hits: u64,
    lease_expiries: u64,
    retries: u64,
    reconnects: u64,
    acc: f32,
}

fn run_dist(
    machines: usize,
    epochs: usize,
    scfg: ServerConfig,
    cfg: RetryCfg,
    plans: Vec<Option<Arc<FaultPlan>>>,
) -> DistRun {
    let mut server = PsServer::start_with(0, machines, updater(machines), scfg).unwrap();
    let addr = server.addr();
    let handles: Vec<_> = plans
        .into_iter()
        .enumerate()
        .map(|(m, plan)| {
            std::thread::spawn(move || train_machine(addr, m as u32, epochs, cfg, plan))
        })
        .collect();
    let mut acc = 1.0f32;
    let (mut retries, mut reconnects) = (0u64, 0u64);
    for h in handles {
        let (a, rt, rc) = h.join().unwrap();
        acc = acc.min(a);
        retries += rt;
        reconnects += rc;
    }
    let weights = final_weights(addr);
    let run = DistRun {
        weights,
        applies: server.rounds_applied(),
        dedup_hits: server.dedup_hits(),
        lease_expiries: server.lease_expiries(),
        retries,
        reconnects,
        acc,
    };
    server.shutdown();
    run
}

/// Drops, duplicates, and truncated frames are retried/dedup'd into a
/// run that ends bitwise identical to the fault-free one, with exactly
/// the same number of optimizer applies (no double-applied gradients).
#[test]
fn faulty_run_is_bitwise_equal_to_fault_free_run() {
    let clean = run_dist(1, 2, ServerConfig::default(), fast_retry(), vec![None]);
    assert!(clean.acc > 0.7, "accuracy {}", clean.acc);

    let plan = FaultPlan::new(0xfa17).with_drop(0.04).with_dup(0.06).with_trunc(0.02);
    let faulty =
        run_dist(1, 2, ServerConfig::default(), fast_retry(), vec![Some(Arc::new(plan))]);
    assert!(faulty.retries > 0, "faults were not exercised");
    assert!(faulty.dedup_hits > 0, "duplicates never reached the dedup filter");
    assert_eq!(clean.applies, faulty.applies, "a retransmission double-applied");
    assert_params_bitwise_eq(&clean.weights, &faulty.weights);
}

/// Killed connections re-dial, replay the un-acked op under the same
/// sequence number, and the server's dedup filter keeps the math exact.
#[test]
fn connection_kills_reconnect_and_stay_bitwise() {
    let clean = run_dist(1, 2, ServerConfig::default(), fast_retry(), vec![None]);
    let plan = FaultPlan::new(7).with_kill_every(40);
    let faulty =
        run_dist(1, 2, ServerConfig::default(), fast_retry(), vec![Some(Arc::new(plan))]);
    assert!(faulty.reconnects > 0, "kills were not exercised");
    assert_eq!(clean.applies, faulty.applies, "a replayed push double-applied");
    assert_params_bitwise_eq(&clean.weights, &faulty.weights);
}

/// The acceptance run: a two-machine job with per-machine fault plans,
/// heartbeat leases held live, zero double-applies, and the exact
/// weights of the clean run.
#[test]
fn two_machine_run_with_faults_has_zero_double_applies() {
    let scfg = || ServerConfig {
        lease: Some(Duration::from_millis(5000)),
        expiry: ExpiryPolicy::Degrade,
        ..ServerConfig::default()
    };
    let cfg = RetryCfg { heartbeat: Some(Duration::from_millis(200)), ..fast_retry() };
    let clean = run_dist(2, 2, scfg(), cfg, vec![None, None]);
    assert_eq!(clean.lease_expiries, 0, "heartbeats must hold the lease");

    let plans = vec![
        Some(Arc::new(FaultPlan::new(0xfa17).with_drop(0.03).with_dup(0.08))),
        Some(Arc::new(FaultPlan::new(0x5eed).with_drop(0.03).with_trunc(0.03))),
    ];
    let faulty = run_dist(2, 2, scfg(), cfg, plans);
    assert!(faulty.retries > 0, "faults were not exercised");
    assert!(faulty.dedup_hits > 0, "duplicates never reached the dedup filter");
    assert_eq!(faulty.lease_expiries, 0, "retries must outpace the 5s lease");
    assert_eq!(clean.applies, faulty.applies, "a retransmission double-applied");
    assert_params_bitwise_eq(&clean.weights, &faulty.weights);
}

/// A worker process restarted from scratch (local seq/barrier counters
/// back at zero) resumes cleanly: the `HelloAck` floors fast-forward its
/// counters past the dead incarnation's, so fresh pushes apply instead
/// of being swallowed by the server's dedup filter and fresh barriers
/// are new generations instead of instant acks against released ones.
#[test]
fn restarted_worker_process_resumes_via_hello_floors() {
    let mut server = PsServer::start_with(0, 1, updater(1), ServerConfig::default()).unwrap();
    let addr = server.addr();
    let engine = create(EngineKind::Threaded, 2);
    // First incarnation: three rounds + a barrier, then kill (drop).
    {
        let kv = DistKVStore::connect_with(
            addr,
            0,
            1,
            Consistency::Sequential,
            engine.clone(),
            fast_retry(),
            None,
        )
        .unwrap();
        kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
        for _ in 0..3 {
            kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], engine.clone()), 0).unwrap();
        }
        kv.flush();
        kv.barrier().unwrap();
    }
    assert_eq!(server.rounds_applied(), 3);
    // Second incarnation: same machine id, fresh counters.
    let kv = DistKVStore::connect_with(
        addr,
        0,
        1,
        Consistency::Sequential,
        engine.clone(),
        fast_retry(),
        None,
    )
    .unwrap();
    kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
    kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], engine.clone()), 0).unwrap();
    let out = NDArray::zeros_on(&[1], engine);
    kv.pull("w", &out, 0).unwrap();
    kv.flush();
    kv.barrier().unwrap();
    assert_eq!(server.rounds_applied(), 4, "the restarted worker's push must apply");
    assert_eq!(
        server.dedup_hits(),
        0,
        "fresh work after a restart must not be mistaken for retransmissions"
    );
    server.shutdown();
}

/// Under `ExpiryPolicy::FailRound` a machine that never joins poisons
/// the round: parked barriers error out instead of hanging.
#[test]
fn bsp_lease_expiry_fails_the_round() {
    let scfg = ServerConfig {
        lease: Some(Duration::from_millis(500)),
        join_grace: Duration::from_millis(500),
        expiry: ExpiryPolicy::FailRound,
        ..ServerConfig::default()
    };
    let mut server = PsServer::start_with(0, 2, updater(2), scfg).unwrap();
    let engine = create(EngineKind::Threaded, 2);
    let cfg = RetryCfg { heartbeat: Some(Duration::from_millis(100)), ..fast_retry() };
    let kv = DistKVStore::connect_with(
        server.addr(),
        0,
        1,
        Consistency::Sequential,
        engine.clone(),
        cfg,
        None,
    )
    .unwrap();
    // Machine 1 never connects; its join grace lapses mid-barrier.  The
    // init may already observe the poisoned state on a slow runner, so
    // only the barrier's outcome is asserted.
    let _ = kv.init("w", &NDArray::from_vec_on(&[2], vec![1.0, 2.0], engine.clone()));
    let err = kv.barrier().unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("lease"), "unexpected error: {msg}");
    assert!(server.lease_expiries() >= 1);
    server.shutdown();
}

/// Under `ExpiryPolicy::Degrade` the survivors finish the job: the dead
/// machine's expiry emits a leave event, pending rounds apply without
/// it, and the remaining machine trains to completion.
#[test]
fn elastic_degrade_survivor_completes_after_peer_death() {
    let scfg = ServerConfig {
        lease: Some(Duration::from_millis(600)),
        join_grace: Duration::from_millis(5000),
        expiry: ExpiryPolicy::Degrade,
        ..ServerConfig::default()
    };
    let mut server = PsServer::start_with(0, 2, updater(2), scfg).unwrap();
    let addr = server.addr();
    // Machine 1 joins (registering its lease) and dies silently.
    {
        let engine = create(EngineKind::Threaded, 2);
        let kv = DistKVStore::connect_with(
            addr,
            1,
            1,
            Consistency::Sequential,
            engine,
            fast_retry(),
            None,
        )
        .unwrap();
        drop(kv);
    }
    // Machine 0 heartbeats through the peer's expiry: its first pull
    // parks until the lease lapses, then every round applies solo.
    let cfg = RetryCfg { heartbeat: Some(Duration::from_millis(150)), ..fast_retry() };
    let (acc, _, _) = train_machine(addr, 0, 1, cfg, None);
    assert!(acc > 0.5, "survivor failed to learn: {acc}");
    assert!(server.lease_expiries() >= 1, "the dead peer never expired");
    assert!(
        server.membership_events().contains(&(1, false)),
        "no leave event: {:?}",
        server.membership_events()
    );
    assert!(server.rounds_applied() > 0, "no rounds applied by the survivor");
    server.shutdown();
}

fn mk_elastic_trainer(engine: EngineRef) -> DataParallelTrainer {
    let model = mlp(&[32], 16, 4);
    let shapes = model.param_shapes(8).unwrap();
    let store = Arc::new(LocalKVStore::new(
        engine.clone(),
        4,
        Arc::new(Sgd::with_momentum(0.5, 0.9, 1e-4).rescale(0.25)),
        Consistency::Sequential,
    ));
    DataParallelTrainer::bind(
        &model.symbol,
        engine,
        8,
        &[16],
        &shapes,
        store,
        TrainerConfig {
            devices: 4,
            shards: 4,
            sync: SyncMode::Elastic,
            weights: vec![],
            seed: 1,
            overlap: true,
            bind: BindConfig::default(),
        },
    )
    .unwrap()
}

fn mk_elastic_iter(engine: EngineRef) -> ArrayDataIter {
    let ds = synth::class_clusters(512, 4, 16, 0.3, 5);
    ArrayDataIter::new(ds.features, ds.labels, &[16], 32, true, engine)
}

/// A killed elastic run restored from its checkpoint reproduces the
/// uninterrupted run's weights bitwise: parameters, versions, momentum
/// state, the applied-event log, and the still-pending rejoin all ride
/// in the checkpoint; the data iterator replays its shuffle schedule by
/// resetting once per completed epoch.
#[test]
fn checkpoint_restore_reproduces_uninterrupted_elastic_run_bitwise() {
    let engine = create(EngineKind::Threaded, 4);
    // Uninterrupted reference: 4 epochs (64 rounds), device 3 leaves at
    // round 5 and rejoins at round 40 — one event on each side of the
    // epoch-2 checkpoint boundary.
    let mut full = mk_elastic_trainer(engine.clone());
    full.leave_at(5, 3).unwrap();
    full.join_at(40, 3).unwrap();
    let mut iter = mk_elastic_iter(engine.clone());
    full.fit(&mut iter, 4).unwrap();
    let reference = full.pull_params().unwrap();

    // Interrupted twin: 2 epochs, checkpoint, crash (drop everything).
    let dir = std::env::temp_dir().join(format!("mixnet_ft_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("elastic.ckpt");
    {
        let mut t = mk_elastic_trainer(engine.clone());
        t.leave_at(5, 3).unwrap();
        t.join_at(40, 3).unwrap();
        let mut iter = mk_elastic_iter(engine.clone());
        t.fit(&mut iter, 2).unwrap();
        t.save_checkpoint(&path, 2).unwrap();
    }

    // Recovery: a fresh store + trainer, restored from disk.  The
    // rejoin at round 40 was still pending at the crash and must fire
    // during the resumed epochs.
    let mut resumed = mk_elastic_trainer(engine.clone());
    let done = resumed.resume_from(&path).unwrap();
    assert_eq!(done, 2, "epochs_done must round-trip");
    let mut iter = mk_elastic_iter(engine);
    for _ in 0..done {
        iter.reset(); // replay the finished epochs' shuffles
    }
    resumed.fit(&mut iter, 2).unwrap();
    assert_params_bitwise_eq(&reference, &resumed.pull_params().unwrap());
    std::fs::remove_dir_all(&dir).unwrap();
}

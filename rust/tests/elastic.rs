//! Elastic heterogeneous training acceptance (ISSUE 5).
//!
//! The pluggable sync layer must preserve PR 4's determinism contract
//! wherever it promises to: `Bsp` reproduces the pre-refactor trainer
//! (asserted in `tests/data_parallel.rs`), `BoundedDelay(0)` is bitwise
//! identical to sequential BSP, and — because elastic rebalancing moves
//! whole shards instead of resizing them — weighted and
//! membership-churned runs are bitwise identical to the static run too.
//! `BoundedDelay(k)` must never serve a snapshot more than `k` rounds
//! stale (asserted via the store's version counters under an injected
//! straggler), and live serving must answer mid-`fit` from committed
//! (never torn) snapshots only.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::BindConfig;
use mixnet::io::{synth, ArrayDataIter};
use mixnet::kvstore::{Consistency, KVStore, LocalKVStore};
use mixnet::models::mlp;
use mixnet::module::{DataParallelTrainer, EpochStats, Module, SyncMode, TrainerConfig, UpdateMode};
use mixnet::ndarray::NDArray;
use mixnet::optimizer::Sgd;
use mixnet::serve::{Servable, ServeConfig, Server};

/// Wraps a store, delaying deliveries of one part — a straggler replica
/// whose gradient transfers are slow.
struct SlowPart {
    inner: Arc<LocalKVStore>,
    slow_part: usize,
    delay: Duration,
}

impl KVStore for SlowPart {
    fn init(&self, key: &str, value: &NDArray) -> mixnet::Result<()> {
        self.inner.init(key, value)
    }
    fn push(&self, key: &str, grad: &NDArray, device: usize) -> mixnet::Result<()> {
        self.inner.push(key, grad, device)
    }
    fn push_part(&self, key: &str, grad: &[f32], part: usize) -> mixnet::Result<()> {
        if part == self.slow_part {
            std::thread::sleep(self.delay);
        }
        self.inner.push_part(key, grad, part)
    }
    fn pull(&self, key: &str, out: &NDArray, device: usize) -> mixnet::Result<()> {
        self.inner.pull(key, out, device)
    }
    fn flush(&self) {
        self.inner.flush()
    }
    fn num_devices(&self) -> usize {
        self.inner.num_devices()
    }
    fn consistency(&self) -> Consistency {
        self.inner.consistency()
    }
}

struct TrainSpec {
    devices: usize,
    shards: usize,
    sync: SyncMode,
    consistency: Consistency,
    weights: Vec<u32>,
    epochs: usize,
    /// (round, device, join) membership events logged before fit.
    events: Vec<(u64, usize, bool)>,
    /// Delay deliveries of this part (straggler injection).
    slow_part: Option<usize>,
}

impl TrainSpec {
    fn bsp(devices: usize, shards: usize, epochs: usize) -> TrainSpec {
        TrainSpec {
            devices,
            shards,
            sync: SyncMode::Bsp,
            consistency: Consistency::Sequential,
            weights: vec![],
            epochs,
            events: vec![],
            slow_part: None,
        }
    }
}

/// Train the Figure 2 MLP under `spec`; returns (master weights, epoch
/// stats, the underlying local store).
fn train_mlp(spec: &TrainSpec) -> (HashMap<String, Vec<f32>>, Vec<EpochStats>, Arc<LocalKVStore>) {
    let engine = create(EngineKind::Threaded, 4);
    let model = mlp(&[32], 16, 4);
    let shard_batch = 8usize;
    let global = spec.shards * shard_batch;
    let ds = synth::class_clusters(512, 4, 16, 0.3, 5);
    let mut iter =
        ArrayDataIter::new(ds.features, ds.labels, &[16], global, true, engine.clone());
    let shapes = model.param_shapes(shard_batch).unwrap();
    let local = Arc::new(LocalKVStore::new(
        engine.clone(),
        spec.shards,
        Arc::new(Sgd::new(0.5).rescale(1.0 / spec.shards as f32)),
        spec.consistency,
    ));
    let store: Arc<dyn KVStore> = match spec.slow_part {
        Some(part) => Arc::new(SlowPart {
            inner: Arc::clone(&local),
            slow_part: part,
            delay: Duration::from_micros(800),
        }),
        None => local.clone() as Arc<dyn KVStore>,
    };
    let mut t = DataParallelTrainer::bind(
        &model.symbol,
        engine,
        shard_batch,
        &[16],
        &shapes,
        store,
        TrainerConfig {
            devices: spec.devices,
            shards: spec.shards,
            sync: spec.sync,
            weights: spec.weights.clone(),
            seed: 1,
            overlap: true,
            bind: BindConfig::default(),
        },
    )
    .unwrap();
    for &(round, device, join) in &spec.events {
        if join {
            t.join_at(round, device).unwrap();
        } else {
            t.leave_at(round, device).unwrap();
        }
    }
    let stats = t.fit(&mut iter, spec.epochs).unwrap();
    (t.pull_params().unwrap(), stats, local)
}

fn assert_params_bitwise_eq(a: &HashMap<String, Vec<f32>>, b: &HashMap<String, Vec<f32>>) {
    assert_eq!(a.len(), b.len());
    for (name, va) in a {
        let vb = &b[name];
        assert_eq!(va.len(), vb.len(), "{name}: length");
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}[{i}]: {x} vs {y}");
        }
    }
}

fn assert_stats_bitwise_eq(a: &[EpochStats], b: &[EpochStats]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "epoch {} loss", x.epoch);
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "epoch {} acc", x.epoch);
    }
}

#[test]
fn bounded_delay_zero_is_bitwise_sequential_bsp() {
    // k = 0: the lookahead window is empty and pulls wait for the full
    // committed state — exactly the sequential BSP schedule, bit for bit.
    let (p_seq, s_seq, _) = train_mlp(&TrainSpec::bsp(2, 4, 3));
    let (p_bd, s_bd, _) = train_mlp(&TrainSpec {
        sync: SyncMode::BoundedDelay(0),
        consistency: Consistency::BoundedDelay(0),
        ..TrainSpec::bsp(2, 4, 3)
    });
    assert_params_bitwise_eq(&p_seq, &p_bd);
    assert_stats_bitwise_eq(&s_seq, &s_bd);
    assert!(s_seq.last().unwrap().accuracy > 0.85, "{:?}", s_seq.last());
}

#[test]
fn bounded_delay_staleness_never_exceeds_k_under_straggler() {
    // One slow part (a straggler's deliveries crawl through the wire).
    // BoundedDelay(2) keeps training, but no pull may ever observe a
    // snapshot more than 2 rounds behind the newest pushed round —
    // asserted via the store's version counters.
    let (_, stats, store) = train_mlp(&TrainSpec {
        sync: SyncMode::BoundedDelay(2),
        consistency: Consistency::BoundedDelay(2),
        slow_part: Some(3),
        ..TrainSpec::bsp(2, 4, 3)
    });
    let s = store.pull_stats();
    assert!(s.max_snap_age <= 2, "staleness bound violated: {s:?}");
    assert!(s.copies > 0, "pulls must have been served");
    assert!(stats.last().unwrap().accuracy > 0.7, "{:?}", stats.last());
}

#[test]
fn weighted_shards_are_bitwise_equal_to_unweighted() {
    // Elastic weights {3, 1} move whole shards between replicas (3:1
    // micro-steps per round) without touching the shard *math* — so the
    // run is bitwise identical to the equal-weight BSP run.
    let (p_eq, s_eq, _) = train_mlp(&TrainSpec::bsp(2, 4, 3));
    let (p_w, s_w, _) = train_mlp(&TrainSpec {
        sync: SyncMode::Elastic,
        weights: vec![3, 1],
        ..TrainSpec::bsp(2, 4, 3)
    });
    assert_params_bitwise_eq(&p_eq, &p_w);
    assert_stats_bitwise_eq(&s_eq, &s_w);
}

#[test]
fn join_leave_mid_training_is_bitwise_equal_and_learns() {
    // Device 3 leaves at round 5 and rejoins at round 12 (pulling fresh
    // master weights on its first micro-step back).  Shards are
    // re-apportioned at each barrier, deterministically from the event
    // log — and since only shard *placement* changes, the run stays
    // bitwise identical to the static 4-device run.
    let (p_static, s_static, _) = train_mlp(&TrainSpec::bsp(4, 4, 3));
    let (p_elastic, s_elastic, _) = train_mlp(&TrainSpec {
        sync: SyncMode::Elastic,
        events: vec![(5, 3, false), (12, 3, true)],
        ..TrainSpec::bsp(4, 4, 3)
    });
    assert_params_bitwise_eq(&p_static, &p_elastic);
    assert_stats_bitwise_eq(&s_static, &s_elastic);
    assert!(
        s_elastic.last().unwrap().accuracy > 0.85,
        "{:?}",
        s_elastic.last()
    );
}

#[test]
fn config_validation_rejects_mismatched_policies() {
    let engine = create(EngineKind::Threaded, 2);
    let model = mlp(&[16], 8, 4);
    let shapes = model.param_shapes(4).unwrap();
    let mk_store = |c: Consistency| {
        Arc::new(LocalKVStore::new(engine.clone(), 2, Arc::new(Sgd::new(0.1)), c))
            as Arc<dyn KVStore>
    };
    let bind = |cfg: TrainerConfig, c: Consistency| {
        DataParallelTrainer::bind(
            &model.symbol,
            engine.clone(),
            4,
            &[8],
            &shapes,
            mk_store(c),
            cfg,
        )
    };
    // BoundedDelay policy requires a matching BoundedDelay store
    let cfg = TrainerConfig {
        devices: 2,
        shards: 2,
        sync: SyncMode::BoundedDelay(2),
        ..Default::default()
    };
    assert!(bind(cfg.clone(), Consistency::Sequential).is_err());
    assert!(bind(cfg.clone(), Consistency::BoundedDelay(1)).is_err());
    assert!(bind(cfg, Consistency::BoundedDelay(2)).is_ok());
    // weights without Elastic sync are rejected
    let cfg = TrainerConfig {
        devices: 2,
        shards: 2,
        weights: vec![3, 1],
        ..Default::default()
    };
    assert!(bind(cfg, Consistency::Sequential).is_err());
    // all-zero elastic weights are rejected
    let cfg = TrainerConfig {
        devices: 2,
        shards: 2,
        sync: SyncMode::Elastic,
        weights: vec![0, 0],
        ..Default::default()
    };
    assert!(bind(cfg, Consistency::Sequential).is_err());
    // membership events are Elastic-only
    let cfg = TrainerConfig { devices: 2, shards: 2, ..Default::default() };
    let mut t = bind(cfg, Consistency::Sequential).unwrap();
    assert!(t.leave_at(3, 1).is_err(), "Bsp has static membership");
    // leaving every replica fails the fit at that round's barrier
    let store = Arc::new(LocalKVStore::new(
        engine.clone(),
        2,
        Arc::new(Sgd::new(0.1)),
        Consistency::Sequential,
    ));
    let cfg = TrainerConfig {
        devices: 2,
        shards: 2,
        sync: SyncMode::Elastic,
        ..Default::default()
    };
    let mut t = DataParallelTrainer::bind(
        &model.symbol,
        engine.clone(),
        4,
        &[8],
        &shapes,
        store,
        cfg,
    )
    .unwrap();
    t.leave_at(2, 0).unwrap();
    t.leave_at(2, 1).unwrap();
    let ds = synth::class_clusters(64, 4, 8, 0.3, 3);
    let mut iter = ArrayDataIter::new(ds.features, ds.labels, &[8], 8, false, engine);
    assert!(t.fit(&mut iter, 1).is_err());
}

#[test]
fn live_serving_answers_mid_fit_from_committed_snapshots() {
    // Serving + training co-location: a trainer pushes rounds into a
    // LocalKVStore while the server answers requests from its committed
    // snapshots.  Every mid-training response must be a valid softmax
    // row (a torn parameter read would poison it), and once the trainer
    // finishes, responses must be *bitwise* identical to a fresh
    // servable built from the store's final committed weights.
    let engine = create(EngineKind::Threaded, 4);
    let model = mlp(&[16], 8, 3);
    let batch = 16usize;
    let shapes = model.param_shapes(batch).unwrap();
    let store = Arc::new(LocalKVStore::new(
        engine.clone(),
        1,
        Arc::new(Sgd::new(0.3)),
        Consistency::Sequential,
    ));
    // Seed the store and a servable holding its own parameter copies.
    let mut seeder = Module::new(mlp(&[16], 8, 3).symbol, engine.clone());
    seeder.bind(batch, &[8], &shapes, BindConfig::default(), 11).unwrap();
    let mut sparams = HashMap::new();
    for name in seeder.param_names() {
        let src = seeder.param(name).unwrap();
        store.init(name, src).unwrap();
        let dst = NDArray::zeros_on(src.shape(), engine.clone());
        dst.copy_from_(src);
        sparams.insert(name.clone(), dst);
    }
    drop(seeder);
    let mut servable = Servable::new(mlp(&[16], 8, 3), sparams, engine.clone()).unwrap();
    servable.attach_live(&store).unwrap();
    let cfg = ServeConfig {
        max_batch: 4,
        max_delay_us: 300,
        queue_cap: 64,
        workers: 2,
        buckets: vec![],
    };
    let server = Server::start(&servable, &cfg).unwrap();

    // Trainer thread: fit through the same store (single replica).
    let t_engine = engine.clone();
    let t_store: Arc<dyn KVStore> = store.clone();
    let trainer = std::thread::spawn(move || {
        let m = mlp(&[16], 8, 3);
        let shapes = m.param_shapes(batch).unwrap();
        let mut module = Module::new(m.symbol, t_engine.clone());
        module.bind(batch, &[8], &shapes, BindConfig::default(), 11).unwrap();
        let ds = synth::class_clusters(512, 3, 8, 0.3, 7);
        let mut iter =
            ArrayDataIter::new(ds.features, ds.labels, &[8], batch, true, t_engine);
        let stats = module
            .fit(&mut iter, &UpdateMode::KvStore { store: t_store, device: 0 }, 6)
            .unwrap();
        stats.last().unwrap().accuracy
    });

    // Mid-fit traffic: every response is a valid softmax row.
    let sample: Vec<f32> = (0..8).map(|i| (i as f32 * 0.31).sin()).collect();
    let mut served = 0usize;
    loop {
        let probs = server.infer(sample.clone()).unwrap();
        assert_eq!(probs.len(), 3);
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "torn/garbage response: {probs:?}");
        served += 1;
        if trainer.is_finished() {
            break;
        }
    }
    let train_acc = trainer.join().unwrap();
    assert!(train_acc > 0.8, "online trainer accuracy {train_acc}");
    assert!(served > 0, "no requests served mid-fit");
    store.flush();

    // Post-fit: the live server must now answer exactly like a fresh
    // servable built from the store's final committed snapshots.
    let mut finals = HashMap::new();
    for name in ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"] {
        let shape = shapes[name].clone();
        let arr = NDArray::zeros_on(&shape, engine.clone());
        store.pull_committed(name, &arr).unwrap();
        finals.insert(name.to_string(), arr);
    }
    engine.wait_all();
    let reference = Servable::new(mlp(&[16], 8, 3), finals, engine.clone()).unwrap();
    let mut ref_exec = reference.bind_bucket(1).unwrap();
    let expect = ref_exec.run(&[sample.as_slice()]);
    let got = server.infer(sample.clone()).unwrap();
    assert_eq!(
        got, expect[0],
        "post-fit live response must match the final committed weights bitwise"
    );
    drop(server);
}

//! ISSUE 7 acceptance tests: the unified tracing/profiling layer.
//!
//! * **Trace well-formedness** — under a real engine workload the
//!   drained spans must have monotonic timestamps, per-thread ordering,
//!   and proper nesting (spans on one thread either nest or are
//!   disjoint — a stack-shaped trace, which is what chrome://tracing
//!   renders).  CI reruns this binary under PALLAS_INTRA_THREADS ∈
//!   {1, 4}.
//! * **Plan replay coverage** — every compiled plan op is recorded
//!   exactly once per replay, keyed by the `(step, op index)` payload.
//! * **Overhead guard** — with profiling disabled a full workload
//!   records nothing at all (the disabled path is one relaxed atomic
//!   load per site), and a timer started while disabled never records.
//! * **Snapshot roundtrip** — `MetricsSnapshot::to_json` output parses
//!   back via `from_json` into an identical document.
//! * **Chrome trace schema** — every event carries ph/ts/dur/pid/tid/
//!   name, parsed with the crate's own JSON reader.
//!
//! Profiling state (the enable flag, span rings, metrics registry) is
//! process-global, so every test takes `PROF_LOCK` and drains residue
//! before starting.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use mixnet::engine::{create, EngineKind, PlanOpSpec, RunPlan};
use mixnet::executor::{BindConfig, Executor};
use mixnet::kvstore::dist::{ClientStats, ServerStats};
use mixnet::kvstore::PullStats;
use mixnet::models::mlp;
use mixnet::ndarray::NDArray;
use mixnet::profile::{self, json::Json, Category, MetricsSnapshot, Span, SpanTimer};
use mixnet::serve::ServeStats;
use mixnet::util::Rng;

static PROF_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    PROF_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Clear any spans left over from a previous test in this process.
fn quiesce() {
    profile::set_enabled(false);
    profile::drain();
    profile::reset();
}

/// A small but real workload: 3 forward/backward/update steps of an
/// MLP on a 4-worker engine (engine ops + GEMM kernels; plan spans too
/// when `replay` is set).
fn run_mlp(replay: bool) {
    let model = mlp(&[32, 16], 16, 4);
    let batch = 8;
    let engine = create(EngineKind::Threaded, 4);
    let shapes = model.var_shapes(batch).unwrap();
    let mut names: Vec<String> = shapes.keys().cloned().collect();
    names.sort();
    let mut args: HashMap<String, NDArray> = HashMap::new();
    for (i, name) in names.iter().enumerate() {
        let n: usize = shapes[name].iter().product();
        let mut rng = Rng::seed_from_u64(0x0B5E + i as u64);
        let v: Vec<f32> = if name.ends_with("_label") {
            (0..n).map(|j| (j % 4) as f32).collect()
        } else {
            (0..n).map(|_| rng.normal_with(0.0, 0.15)).collect()
        };
        args.insert(name.clone(), NDArray::from_vec_on(&shapes[name], v, engine.clone()));
    }
    let params: Vec<String> = names
        .iter()
        .filter(|n| n.as_str() != "data" && !n.ends_with("_label"))
        .cloned()
        .collect();
    let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let cfg = BindConfig { replay, ..Default::default() };
    let exec = Executor::bind(&model.symbol, engine.clone(), args, &grad_names, cfg).unwrap();
    for _ in 0..3 {
        exec.forward_backward().unwrap();
        for p in &params {
            exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), 0.05);
        }
    }
    exec.wait();
    engine.wait_all();
}

fn end_us(s: &Span) -> u64 {
    s.start_us + s.dur_us
}

#[test]
fn engine_trace_is_well_formed() {
    let _g = lock();
    quiesce();
    profile::set_enabled(true);
    run_mlp(false);
    profile::set_enabled(false);
    let spans = profile::drain();
    assert_eq!(profile::dropped(), 0, "ring overflow during a small workload");
    assert!(spans.iter().any(|s| s.cat == Category::Engine), "no engine spans recorded");
    assert!(spans.iter().any(|s| s.cat == Category::Kernel), "no kernel spans recorded");
    let now = profile::now_us();
    let mut by_tid: HashMap<u32, Vec<&Span>> = HashMap::new();
    for s in &spans {
        assert!(!s.name.is_empty(), "span with empty name: {s:?}");
        assert!(end_us(s) <= now, "span ends in the future: {s:?}");
        by_tid.entry(s.tid).or_default().push(s);
    }
    for (tid, ss) in &by_tid {
        for w in ss.windows(2) {
            assert!(w[0].start_us <= w[1].start_us, "tid {tid}: drain order not by start time");
        }
        // Stack discipline: two spans on one thread either nest or are
        // disjoint.  Partial overlap would mean a span "finished" on a
        // different scope than it started — chrome://tracing would
        // render garbage lanes.
        for i in 0..ss.len() {
            for j in (i + 1)..ss.len() {
                let (a, b) = (ss[i], ss[j]);
                let disjoint = b.start_us >= end_us(a) || a.start_us >= end_us(b);
                let nested = (b.start_us >= a.start_us && end_us(b) <= end_us(a))
                    || (a.start_us >= b.start_us && end_us(a) <= end_us(b));
                assert!(disjoint || nested, "tid {tid}: partial overlap\n  {a:?}\n  {b:?}");
            }
        }
    }
    // Engine dispatch spans carry the push→dispatch queue wait; kernels
    // (recorded inside ops, no scheduler in between) never do.
    for s in spans.iter().filter(|s| s.cat == Category::Kernel) {
        assert_eq!(s.queue_us, 0, "kernel span with queue wait: {s:?}");
    }
}

#[test]
fn plan_replay_records_each_op_exactly_once() {
    let _g = lock();
    quiesce();
    let engine = create(EngineKind::Threaded, 4);
    let v0 = engine.new_var();
    let v1 = engine.new_var();
    let specs = vec![
        PlanOpSpec {
            name: "plan.test_a",
            reads: vec![],
            writes: vec![v0],
            cost: f64::NAN,
            body: Arc::new(|_| {}),
        },
        PlanOpSpec {
            name: "plan.test_b",
            reads: vec![v0],
            writes: vec![v1],
            cost: f64::NAN,
            body: Arc::new(|_| {}),
        },
        PlanOpSpec {
            name: "plan.test_c",
            reads: vec![v0, v1],
            writes: vec![],
            cost: 64.0,
            body: Arc::new(|_| {}),
        },
    ];
    let plan = Arc::new(RunPlan::compile(specs));
    profile::set_enabled(true);
    for step in 1..=3u64 {
        engine.run_plan(&plan, step);
        engine.wait_all();
    }
    profile::set_enabled(false);
    let spans = profile::drain();
    let plan_spans: Vec<&Span> = spans
        .iter()
        .filter(|s| s.cat == Category::Plan && s.name.starts_with("plan.test_"))
        .collect();
    assert_eq!(plan_spans.len(), 9, "3 ops x 3 replays, each exactly once");
    let mut seen = HashSet::new();
    for s in &plan_spans {
        assert!(seen.insert((s.a, s.b)), "op (step={}, idx={}) recorded twice", s.a, s.b);
    }
    for step in 1..=3u64 {
        for idx in 0..3u64 {
            assert!(seen.contains(&(step, idx)), "missing span for step {step} op {idx}");
        }
    }
}

#[test]
fn disabled_profiling_records_nothing() {
    let _g = lock();
    quiesce();
    run_mlp(false);
    let spans = profile::drain();
    assert!(spans.is_empty(), "disabled profiling recorded {} spans", spans.len());
    assert_eq!(profile::dropped(), 0);
    // A timer started while disabled must stay silent even if profiling
    // is switched on before it finishes (the capture-once contract).
    let t = SpanTimer::start();
    profile::set_enabled(true);
    t.finish(Category::Engine, "late_enable", 0, 0, 0);
    profile::set_enabled(false);
    assert!(profile::drain().is_empty(), "capture-once timer recorded after late enable");
}

#[test]
fn chrome_trace_events_have_required_keys() {
    let _g = lock();
    quiesce();
    profile::set_enabled(true);
    run_mlp(true);
    profile::set_enabled(false);
    let spans = profile::drain();
    assert!(spans.iter().any(|s| s.cat == Category::Plan), "replay bind recorded no plan spans");
    let doc = profile::chrome_trace(&spans);
    let v = Json::parse(&doc).unwrap();
    let events = v.get("traceEvents").expect("traceEvents key").items();
    assert_eq!(events.len(), spans.len());
    for e in events {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        for key in ["ts", "dur", "pid", "tid"] {
            assert!(e.get(key).and_then(Json::as_u64).is_some(), "missing numeric {key}: {e:?}");
        }
        let name = e.get("name").and_then(Json::as_str).expect("name key");
        assert!(!name.is_empty());
        assert!(e.get("cat").and_then(Json::as_str).is_some(), "missing cat");
        assert!(e.get("args").and_then(|a| a.get("queue_us")).is_some(), "missing args.queue_us");
    }
}

#[test]
fn metrics_snapshot_json_roundtrips() {
    let _g = lock();
    quiesce();
    mixnet::metrics::incr("test.profile_counter", 3);
    mixnet::metrics::observe_us_all("test.profile_hist_us", &[100, 200, 300, 400]);
    profile::set_enabled(true);
    run_mlp(false);
    profile::set_enabled(false);
    let spans = profile::drain();
    let snap = MetricsSnapshot::collect(1_000_000, &spans)
        .with_pull(PullStats { copies: 3, skips: 1, last_snap_age: 2, max_snap_age: 5 })
        .with_serve(ServeStats {
            requests: 64,
            batches: 9,
            rejected: 1,
            mean_batch: 2.5,
            p50_us: 800,
            p95_us: 2100,
            p99_us: 4000,
            uptime_s: 1.25,
            rps: 128.0,
        })
        .with_kv_client(ClientStats { retries: 2, reconnects: 1, ..Default::default() })
        .with_kv_server(ServerStats {
            msgs: 40,
            bytes: 123_456,
            dedup_hits: 4,
            lease_expiries: 0,
            applies: 12,
        });
    assert!(snap.workers > 0, "workload should have produced worker spans");
    assert!(!snap.ops.is_empty());
    let js = snap.to_json();
    let back = MetricsSnapshot::from_json(&js).unwrap();
    assert_eq!(back.to_json(), js, "snapshot JSON must roundtrip byte-identically");
    // Snapshots without the optional sections roundtrip too.
    let bare = MetricsSnapshot::collect(500, &[]);
    let js2 = bare.to_json();
    assert_eq!(MetricsSnapshot::from_json(&js2).unwrap().to_json(), js2);
}

#[test]
fn snapshot_ops_cover_engine_busy_time() {
    // The per-op totals are what the acceptance criterion checks against
    // step time: the engine/plan rows must add up to the snapshot's own
    // busy_us exactly (they are computed from the same spans).
    let _g = lock();
    quiesce();
    profile::set_enabled(true);
    run_mlp(false);
    profile::set_enabled(false);
    let spans = profile::drain();
    let snap = MetricsSnapshot::collect(profile::now_us(), &spans);
    let op_total: u64 = snap
        .ops
        .iter()
        .filter(|o| o.cat == "engine" || o.cat == "plan")
        .map(|o| o.total_us)
        .sum();
    assert_eq!(op_total, snap.busy_us, "per-op totals must account for all busy time");
    assert_eq!(snap.dropped_spans, 0);
}

#[test]
fn snapshot_path_is_sibling_of_trace() {
    assert_eq!(profile::snapshot_path("trace.json"), "metrics_snapshot.json");
    assert_eq!(profile::snapshot_path("/tmp/prof/trace.json"), "/tmp/prof/metrics_snapshot.json");
}

#[test]
fn histogram_reservoir_is_deterministic_and_report_sorted() {
    let _g = lock();
    // Identical observation streams into two reservoirs must agree
    // exactly: the xorshift state is fixed-seeded, not time-seeded.
    let mut h1 = mixnet::metrics::Histogram::new(128);
    let mut h2 = mixnet::metrics::Histogram::new(128);
    for v in 0..50_000u64 {
        let x = v.wrapping_mul(2_654_435_761) % 1_000_003;
        h1.observe(x);
        h2.observe(x);
    }
    assert_eq!(h1.percentiles(&[50.0, 95.0, 99.0]), h2.percentiles(&[50.0, 95.0, 99.0]));
    mixnet::metrics::incr("zz.profile_test", 1);
    mixnet::metrics::incr("aa.profile_test", 1);
    let rep = mixnet::metrics::report();
    let lines: Vec<&str> = rep.lines().collect();
    let mut sorted = lines.clone();
    sorted.sort();
    assert_eq!(lines, sorted, "report() lines must come out sorted");
}

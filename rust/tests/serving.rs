//! End-to-end serving tests (ISSUE 2): train -> checkpoint -> serve
//! roundtrip with the losslessness acceptance criterion — every batched
//! response bitwise-matches a batch-1 forward of the same sample — plus
//! backpressure and graceful-shutdown behavior under concurrency.

use std::collections::HashMap;
use std::sync::Arc;

use mixnet::engine::{create, EngineKind, EngineRef};
use mixnet::io::synth::class_clusters;
use mixnet::io::ArrayDataIter;
use mixnet::models::{mlp, servable_mlp, Model};
use mixnet::module::{Module, UpdateMode};
use mixnet::ndarray::NDArray;
use mixnet::optimizer::Sgd;
use mixnet::serve::{closed_loop, Servable, ServeConfig, Server};
use mixnet::util::Rng;

const IN_DIM: usize = 16;
const CLASSES: usize = 4;

fn model() -> Model {
    mlp(&[32], IN_DIM, CLASSES)
}

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("mixnet_serve_{}_{tag}.bin", std::process::id()))
}

/// Train an MLP a few steps and checkpoint it.
fn train_and_checkpoint(engine: &EngineRef, path: &std::path::Path) {
    let shapes = model().param_shapes(32).unwrap();
    let mut m = Module::new(model().symbol, engine.clone());
    m.bind(32, &[IN_DIM], &shapes, Default::default(), 11).unwrap();
    let ds = class_clusters(256, CLASSES, IN_DIM, 0.3, 21);
    let mut iter = ArrayDataIter::new(ds.features, ds.labels, &[IN_DIM], 32, true, engine.clone());
    m.fit(&mut iter, &UpdateMode::Local(Arc::new(Sgd::new(0.4))), 4).unwrap();
    m.save_params(path).unwrap();
}

fn samples(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n).map(|_| (0..IN_DIM).map(|_| rng.uniform(-1.5, 1.5)).collect()).collect()
}

#[test]
fn checkpoint_roundtrip_serves_bitwise_identical_to_batch1() {
    let engine = create(EngineKind::Threaded, 4);
    let path = tmp("roundtrip");
    train_and_checkpoint(&engine, &path);

    // Batch-1 reference: a fresh inference-bound module loading the same
    // checkpoint, predicting one sample at a time.
    let shapes = model().param_shapes(1).unwrap();
    let mut reference = Module::new(model().symbol, engine.clone());
    reference.bind_inference(1, &[IN_DIM], &shapes, 999).unwrap();
    reference.load_params(&path).unwrap();

    // Server from the same checkpoint, batching across buckets.
    let servable = Servable::from_checkpoint(model(), &path, engine.clone()).unwrap();
    let cfg = ServeConfig {
        max_batch: 8,
        max_delay_us: 800,
        queue_cap: 256,
        workers: 2,
        buckets: vec![1, 4, 8],
    };
    let mut server = Server::start(&servable, &cfg).unwrap();

    let inputs = samples(48, 0xfeed);
    let expected: Vec<Vec<f32>> = inputs
        .iter()
        .map(|s| {
            let x = NDArray::from_vec_on(&[1, IN_DIM], s.clone(), engine.clone());
            reference.predict(&x).unwrap().to_vec()
        })
        .collect();

    // Concurrent submission from several client threads: the batcher is
    // free to coalesce any interleaving into any bucket sizes.
    let got: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let server = &server;
        let inputs = &inputs;
        let handles: Vec<_> = (0..6)
            .map(|c| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    for i in (c..inputs.len()).step_by(6) {
                        out.push((i, server.infer(inputs[i].clone()).unwrap()));
                    }
                    out
                })
            })
            .collect();
        let mut all: Vec<(usize, Vec<f32>)> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_by_key(|(i, _)| *i);
        all.into_iter().map(|(_, v)| v).collect()
    });

    let stats = server.shutdown();
    assert_eq!(stats.requests, 48);
    assert!(stats.batches <= 48, "batching never ran: {stats:?}");
    for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
        assert_eq!(g.len(), CLASSES);
        for (a, b) in g.iter().zip(e) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "sample {i}: batched response {a} != batch-1 forward {b} (bitwise)"
            );
        }
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn checkpoint_quality_survives_serving() {
    // The served probabilities must reflect the trained weights: argmax
    // accuracy over a fresh draw from the same clusters should beat
    // chance by a wide margin.
    let engine = create(EngineKind::Threaded, 4);
    let path = tmp("quality");
    train_and_checkpoint(&engine, &path);
    let servable = Servable::from_checkpoint(model(), &path, engine.clone()).unwrap();
    let mut server = Server::start(&servable, &ServeConfig::default()).unwrap();

    let ds = class_clusters(128, CLASSES, IN_DIM, 0.3, 21);
    let mut correct = 0usize;
    for i in 0..128 {
        let x = ds.features[i * IN_DIM..(i + 1) * IN_DIM].to_vec();
        let probs = server.infer(x).unwrap();
        let pred = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if pred == ds.labels[i] as usize {
            correct += 1;
        }
    }
    let acc = correct as f64 / 128.0;
    assert!(acc > 0.6, "served accuracy {acc} barely beats chance (0.25)");
    server.shutdown();
    std::fs::remove_file(path).ok();
}

#[test]
fn sustained_closed_loop_is_lossless_and_batched() {
    // A 16-client closed loop over a small sample set: every response
    // must still bitwise-match the batch-1 forward, while the server
    // actually coalesces (mean batch > 1 under this concurrency).
    let engine = create(EngineKind::Threaded, 4);
    let m = servable_mlp(IN_DIM, CLASSES);
    let shapes = m.param_shapes(1).unwrap();
    let mut init = Module::new(servable_mlp(IN_DIM, CLASSES).symbol, engine.clone());
    init.bind_inference(1, &[IN_DIM], &shapes, 5).unwrap();
    let params: HashMap<String, NDArray> = init
        .param_names()
        .iter()
        .map(|n| (n.clone(), init.param(n).unwrap().clone()))
        .collect();
    let servable = Servable::new(m, params, engine.clone()).unwrap();

    let cfg = ServeConfig {
        max_batch: 16,
        max_delay_us: 1_500,
        queue_cap: 256,
        workers: 2,
        buckets: vec![],
    };
    let mut server = Server::start(&servable, &cfg).unwrap();
    let inputs = samples(32, 0xabcd);
    let report = closed_loop(&server, 16, 12, &inputs);
    assert_eq!(report.errors, 0);

    // spot-check losslessness after the fact
    let expected: Vec<Vec<f32>> = inputs
        .iter()
        .take(8)
        .map(|s| {
            let x = NDArray::from_vec_on(&[1, IN_DIM], s.clone(), engine.clone());
            init.predict(&x).unwrap().to_vec()
        })
        .collect();
    for (s, e) in inputs.iter().take(8).zip(&expected) {
        let got = server.infer(s.clone()).unwrap();
        assert_eq!(got, *e, "closed-loop response diverged from batch-1");
    }
    let stats = server.shutdown();
    assert!(
        stats.mean_batch > 1.0,
        "16 concurrent clients never coalesced: {stats:?}"
    );
    assert!(stats.p99_us >= stats.p50_us);
}

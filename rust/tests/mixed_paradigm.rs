//! E0 — the system property behind Tables 1/2: imperative NDArray ops and
//! declarative Symbol executions schedule **jointly** on one engine, with
//! correct cross-paradigm dependencies.

use std::collections::HashMap;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::{BindConfig, Executor};
use mixnet::ndarray::NDArray;
use mixnet::symbol::{Act, Symbol};

fn mlp() -> Symbol {
    Symbol::var("data")
        .fully_connected("fc1", 16)
        .activation("relu1", Act::Relu)
        .fully_connected("fc2", 4)
        .softmax_output("softmax")
}

fn args(engine: &mixnet::engine::EngineRef, batch: usize) -> HashMap<String, NDArray> {
    let mut m = HashMap::new();
    m.insert("data".into(), NDArray::randn_on(&[batch, 8], 0.0, 1.0, 1, engine.clone()));
    m.insert("fc1_weight".into(), NDArray::randn_on(&[16, 8], 0.0, 0.3, 2, engine.clone()));
    m.insert("fc1_bias".into(), NDArray::zeros_on(&[16], engine.clone()));
    m.insert("fc2_weight".into(), NDArray::randn_on(&[4, 16], 0.0, 0.3, 3, engine.clone()));
    m.insert("fc2_bias".into(), NDArray::zeros_on(&[4], engine.clone()));
    m.insert(
        "softmax_label".into(),
        NDArray::from_vec_on(&[batch], (0..batch).map(|i| (i % 4) as f32).collect(), engine.clone()),
    );
    m
}

const PARAMS: [&str; 4] = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"];

/// The paper's §2.2 loop: graph backward followed by imperative updates
/// with NO explicit synchronization — the engine must order the update
/// after the gradient write, and the next forward after the update.
#[test]
fn imperative_update_ordered_against_graph_ops() {
    let engine = create(EngineKind::Threaded, 4);
    let a = args(&engine, 8);
    let exec =
        Executor::bind(&mlp(), engine.clone(), a.clone(), &PARAMS, BindConfig::default())
            .unwrap();
    let mut losses = vec![];
    for _ in 0..25 {
        exec.forward_backward().unwrap();
        for p in PARAMS {
            exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), 0.3);
        }
        // no wait_all: loss read itself must observe a consistent state
        losses.push(exec.softmax_xent_loss().unwrap());
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.8),
        "mixed loop failed to optimize: {losses:?}"
    );
}

/// An imperative mutation of a bound argument must be visible to the next
/// symbolic forward (same tag space).
#[test]
fn imperative_write_visible_to_symbolic_forward() {
    let engine = create(EngineKind::Threaded, 4);
    let a = args(&engine, 4);
    let exec = Executor::bind(
        &mlp(),
        engine.clone(),
        a.clone(),
        &[],
        BindConfig { training: false, ..Default::default() },
    )
    .unwrap();
    exec.forward();
    let p1 = exec.outputs()[0].to_vec();
    // zero all weights imperatively -> uniform softmax
    for p in PARAMS {
        let w = a.get(p).unwrap();
        w.mul_scalar_(0.0);
    }
    exec.forward();
    let p2 = exec.outputs()[0].to_vec();
    assert_ne!(p1, p2);
    for row in p2.chunks(4) {
        for v in row {
            assert!((v - 0.25).abs() < 1e-6, "uniform expected, got {row:?}");
        }
    }
}

/// Two executors and raw NDArray chains on ONE engine must not interfere.
#[test]
fn concurrent_executors_and_ndarray_chains() {
    let engine = create(EngineKind::Threaded, 4);
    let e1 = Executor::bind(
        &mlp(),
        engine.clone(),
        args(&engine, 8),
        &PARAMS,
        BindConfig::default(),
    )
    .unwrap();
    let e2 = Executor::bind(
        &mlp(),
        engine.clone(),
        args(&engine, 8),
        &PARAMS,
        BindConfig::default(),
    )
    .unwrap();
    let x = NDArray::full(&[4096], 1.0);
    for _ in 0..10 {
        e1.forward_backward().unwrap();
        e2.forward_backward().unwrap();
        x.add_(&NDArray::full(&[4096], 0.5));
    }
    engine.wait_all();
    let g1 = e1.grad("fc1_weight").unwrap().to_vec();
    let g2 = e2.grad("fc1_weight").unwrap().to_vec();
    assert_eq!(g1, g2, "identical executors must produce identical grads");
    assert!((x.at(0) - 6.0).abs() < 1e-6);
}

/// Naive (concrete) and threaded (lazy) engines are semantically
/// equivalent on the same mixed program.
#[test]
fn execution_models_agree_on_mixed_program() {
    let mut finals = vec![];
    for kind in [EngineKind::Naive, EngineKind::Threaded] {
        let engine = create(kind, 4);
        let a = args(&engine, 8);
        let exec =
            Executor::bind(&mlp(), engine.clone(), a.clone(), &PARAMS, BindConfig::default())
                .unwrap();
        for _ in 0..5 {
            exec.forward_backward().unwrap();
            for p in PARAMS {
                exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), 0.1);
            }
        }
        engine.wait_all();
        finals.push(a.get("fc1_weight").unwrap().to_vec());
    }
    for (x, y) in finals[0].iter().zip(&finals[1]) {
        assert!((x - y).abs() < 1e-5, "engines diverged: {x} vs {y}");
    }
}

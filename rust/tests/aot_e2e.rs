//! E6 smoke: the full three-layer path — load AOT artifacts through PJRT
//! and take real optimization steps from Rust.
//!
//! Skips (with a message) when `artifacts/` has not been built; `make
//! test` always builds it first.

use std::path::Path;

use mixnet::runtime::{Runtime, TensorKind};

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

/// PJRT client, or None in stub builds (no `xla-runtime` feature): the
/// e2e tests then skip even when `artifacts/` exists, instead of
/// panicking on the stub's constructor error.
fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: PJRT unavailable ({e})");
            None
        }
    }
}

fn load_params(dir: &Path, spec: &mixnet::runtime::ModuleSpec) -> Vec<Vec<f32>> {
    let blob = std::fs::read(dir.join("params_init.bin")).unwrap();
    let floats: Vec<f32> =
        blob.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
    let mut out = Vec::new();
    let mut off = 0;
    for ts in &spec.inputs {
        if ts.kind == TensorKind::Param {
            out.push(floats[off..off + ts.size()].to_vec());
            off += ts.size();
        }
    }
    assert_eq!(off, floats.len(), "blob/manifest mismatch");
    out
}

fn batch_inputs(spec: &mixnet::runtime::ModuleSpec, seed: u64) -> (Vec<f32>, Vec<f32>, usize) {
    let d = &spec.inputs[spec.input_indices(TensorKind::Data)[0]];
    let (b, s) = (d.shape[0], d.shape[1]);
    let vocab = spec.inputs[spec.input_indices(TensorKind::Param)[0]].shape[0];
    let mut rng = mixnet::util::Rng::seed_from_u64(seed);
    let data: Vec<f32> = (0..b * s).map(|i| ((i + rng.below(7)) % 16) as f32).collect();
    let labels: Vec<f32> = data.iter().map(|t| (t + 1.0) % 16.0).collect();
    (data, labels, vocab)
}

#[test]
fn sgd_step_reduces_loss_e2e() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let Some(rt) = runtime_or_skip() else {
        return;
    };
    let programs = rt.load_dir(dir).unwrap();
    let step = &programs["sgd_step"];
    let mut params = load_params(dir, step.spec());
    let (data, labels, _vocab) = batch_inputs(step.spec(), 3);
    let mut losses = vec![];
    for _ in 0..5 {
        let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        inputs.push(&data);
        inputs.push(&labels);
        let outs = step.run(&inputs).unwrap();
        losses.push(outs[0][0]);
        for (p, new) in params.iter_mut().zip(outs.into_iter().skip(1)) {
            *p = new;
        }
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "5 fused SGD steps failed to reduce loss: {losses:?}"
    );
}

#[test]
fn train_step_grads_match_sgd_step_update() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let Some(rt) = runtime_or_skip() else {
        return;
    };
    let programs = rt.load_dir(dir).unwrap();
    let train = &programs["train_step"];
    let sgd = &programs["sgd_step"];
    let params = load_params(dir, train.spec());
    let (data, labels, _) = batch_inputs(train.spec(), 9);
    let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    inputs.push(&data);
    inputs.push(&labels);
    let g = train.run(&inputs).unwrap();
    let s = sgd.run(&inputs).unwrap();
    assert!((g[0][0] - s[0][0]).abs() < 1e-5, "losses differ");
    // lr is recorded in the manifest header comment; recover it from the
    // first nonzero gradient element instead (new = old - lr*grad).
    let (pi, ei) = (1..g.len())
        .find_map(|i| g[i].iter().position(|&x| x.abs() > 1e-4).map(|j| (i, j)))
        .expect("no nonzero gradient");
    let lr = (params[pi - 1][ei] - s[pi][ei]) / g[pi][ei];
    assert!(lr > 0.0 && lr < 10.0, "implied lr {lr}");
    // every param must satisfy new = old - lr*grad
    for i in 1..g.len() {
        for j in (0..g[i].len()).step_by((g[i].len() / 7).max(1)) {
            let expect = params[i - 1][j] - lr * g[i][j];
            assert!(
                (expect - s[i][j]).abs() < 1e-4,
                "param {i} elem {j}: {expect} vs {}",
                s[i][j]
            );
        }
    }
}

#[test]
fn eval_step_is_pure() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let Some(rt) = runtime_or_skip() else {
        return;
    };
    let programs = rt.load_dir(dir).unwrap();
    let eval = &programs["eval_step"];
    let params = load_params(dir, eval.spec());
    let (data, labels, vocab) = batch_inputs(eval.spec(), 5);
    let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    inputs.push(&data);
    inputs.push(&labels);
    let l1 = eval.run(&inputs).unwrap()[0][0];
    let l2 = eval.run(&inputs).unwrap()[0][0];
    assert_eq!(l1, l2, "eval must be deterministic");
    // untrained loss should be near ln(vocab)
    assert!((l1 - (vocab as f32).ln()).abs() < 1.5, "loss {l1} vs ln {}", (vocab as f32).ln());
}

//! Figure 6 — "convnet-benchmarks": forward and forward+backward time
//! per batch under the four execution modes of DESIGN E1.
//!
//! The paper compares MXNet / Torch7 / Caffe / TensorFlow on a GTX 980.
//! We hold the compute substrate constant (our native CPU kernels) and
//! vary exactly what the paper credits for the differences:
//!
//! * `mxnet`      — engine-lazy scheduling + fused elementwise ops
//! * `torch-caffe`— concrete (eager) execution + fused ops
//! * `tf-like`    — engine-lazy, unfused
//! * `tf-old`     — concrete, unfused, one extra copy per op, and the
//!                  *reference* (previous-generation) GEMM kernels — the
//!                  stand-in for TensorFlow's older-CUDNN handicap
//!
//! Expected shape: the first two within ~10%, `tf-old` ~2x slower.
//! Inputs are spatially scaled (`@64`, batch 16) to fit a single-core
//! budget — DESIGN §4; ratios, not absolute times, are the claim.
//!
//! ```text
//! cargo bench --bench fig6_convnet            # all workloads
//! FIG6_MODELS=mlp,simple-cnn cargo bench --bench fig6_convnet
//! ```

use std::collections::HashMap;


use mixnet::engine::{create, EngineKind};
use mixnet::executor::{BindConfig, Executor};
use mixnet::graph::{Entry, Graph, Op};
use mixnet::models::by_name;
use mixnet::ndarray::NDArray;
use mixnet::util::bench::{print_table, Bencher};

/// Rebuild `graph` with an Identity node after every compute op — the
/// "extra copy per op" handicap of the `tf-old` mode.
fn insert_copies(graph: &Graph) -> Graph {
    let mut out = Graph::new();
    // old entry -> new entry (post-copy)
    let mut map: HashMap<Entry, Entry> = HashMap::new();
    for (id, node) in graph.nodes.iter().enumerate() {
        let inputs: Vec<Entry> = node.inputs.iter().map(|e| map[e]).collect();
        let new_id = out.add_node(node.op.clone(), node.name.clone(), inputs);
        let n_out = graph.num_outputs_of(id);
        // no copy after the loss head: autodiff seeds from SoftmaxOutput
        if node.op.is_variable() || matches!(node.op, Op::SoftmaxOutput) {
            for o in 0..n_out {
                map.insert(Entry { node: id, out: o }, Entry { node: new_id, out: o });
            }
            continue;
        }
        for o in 0..n_out {
            let copy = out.add_node(
                Op::Identity,
                format!("{}_copy{o}", node.name),
                vec![Entry { node: new_id, out: o }],
            );
            map.insert(Entry { node: id, out: o }, Entry::new(copy));
        }
    }
    out.outputs = graph.outputs.iter().map(|e| map[e]).collect();
    out.num_forward = out.nodes.len();
    out
}

fn bind(
    model: &str,
    batch: usize,
    kind: EngineKind,
    fuse: bool,
    extra_copy: bool,
    training: bool,
) -> Executor {
    let m = by_name(model).unwrap();
    let engine = create(kind, mixnet::engine::default_threads());
    let mut graph = mixnet::symbol::Symbol::to_graph(std::slice::from_ref(&m.symbol));
    if extra_copy {
        graph = insert_copies(&graph);
    }
    let var_shapes = m.var_shapes(batch).unwrap();
    let mut rng_seed = 3u64;
    let args: HashMap<String, NDArray> = var_shapes
        .iter()
        .map(|(name, shape)| {
            rng_seed += 1;
            let arr = if name.ends_with("_label") {
                let v: Vec<f32> =
                    (0..batch).map(|i| (i % m.num_classes) as f32).collect();
                NDArray::from_vec_on(shape, v, engine.clone())
            } else if name.ends_with("_gamma") {
                NDArray::from_vec_on(
                    shape,
                    vec![1.0; shape.iter().product()],
                    engine.clone(),
                )
            } else {
                NDArray::randn_on(shape, 0.0, 0.05, rng_seed, engine.clone())
            };
            (name.clone(), arr)
        })
        .collect();
    let grad_names: Vec<&str> = var_shapes
        .keys()
        .filter(|n| *n != "data" && !n.ends_with("_label"))
        .map(|s| s.as_str())
        .collect();
    Executor::bind_graph(
        graph,
        engine,
        args,
        if training { &grad_names } else { &[] },
        BindConfig { training, fuse, ..Default::default() },
    )
    .unwrap()
}

fn main() {
    let models_env = std::env::var("FIG6_MODELS")
        .unwrap_or_else(|_| "mlp,simple-cnn,alexnet@64".to_string());
    let models: Vec<&str> = models_env.split(',').collect();
    let batch: usize =
        std::env::var("FIG6_BATCH").ok().and_then(|s| s.parse().ok()).unwrap_or(16);
    let modes: [(&str, EngineKind, bool, bool); 4] = [
        ("mxnet", EngineKind::Threaded, true, false),
        ("torch-caffe", EngineKind::Naive, true, false),
        ("tf-like", EngineKind::Threaded, false, false),
        ("tf-old", EngineKind::Naive, false, true),
    ];
    let b = Bencher { warmup: 1, samples: 5, max_total: std::time::Duration::from_secs(30) };

    for training in [false, true] {
        let title = if training { "forward+backward" } else { "forward" };
        let mut rows = Vec::new();
        for model in &models {
            let mut row = vec![model.to_string()];
            let mut base_ms = 0.0;
            for (mode_name, kind, fuse, extra) in modes {
                let exec = bind(model, batch, kind, fuse, extra, training);
                // `extra` marks the old-kernel-library mode
                mixnet::ndarray::kernels::set_reference_kernels(extra);
                let stats = b.run(&format!("{model}/{mode_name}"), || {
                    if training {
                        exec.forward_backward().unwrap();
                    } else {
                        exec.forward();
                    }
                    exec.wait();
                });
                mixnet::ndarray::kernels::set_reference_kernels(false);
                let ms = stats.median_ms();
                if mode_name == "mxnet" {
                    base_ms = ms;
                }
                row.push(format!("{ms:.1} ({:.2}x)", ms / base_ms.max(1e-9)));
            }
            rows.push(row);
        }
        print_table(
            &format!("Figure 6 — {title} ms/batch (batch {batch}, ratio vs mxnet)"),
            &["network", "mxnet", "torch-caffe", "tf-like", "tf-old"],
            &rows,
        );
        println!();
    }
    println!("paper shape: mxnet ~ torch/caffe; tf-old ~2x slower");
}

//! Engine microbenchmarks: scheduling overhead per op, parallelism
//! discovery, the cost of dependency tracking, and (ISSUE 3) the static
//! run-plan replay path vs the dynamic push path plus the storage pool
//! vs the allocator — the substrate numbers behind E1/E4/E5.
//!
//! ```text
//! cargo bench --bench engine_micro
//! BENCH_QUICK=1 cargo bench --bench engine_micro  # CI smoke (fewer samples)
//! BENCH_OUT=/tmp/e.json cargo bench --bench engine_micro
//! ```
//!
//! Emits `BENCH_engine.json` (or `$BENCH_OUT`): per-case records plus
//! top-level meta with `replay_ns_per_op`, `push_ns_per_op`,
//! `replay_speedup_vs_push` (acceptance target: >= 5x),
//! `steady_state_pool_misses_per_step` (target: 0), and (ISSUE 8)
//! `fused_speedup` — geomean of the fused-vs-unfused forward A/Bs on
//! AlexNet and a VGG block (CI fails if fused regresses by > 5%).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use mixnet::engine::{create, EngineKind, EngineRef, PlanOpSpec, RunPlan, VarHandle};
use mixnet::executor::{BindConfig, Executor};
use mixnet::models::{alexnet, mlp, Model};
use mixnet::ndarray::{pool, NDArray};
use mixnet::symbol::{Act, Pool, Symbol};
use mixnet::util::bench::{print_table, standard_meta, write_bench_json, BenchRecord, Bencher};
use mixnet::util::Rng;

/// Per-op (reads, writes) var sets, in program order.
type Deps = Vec<(Vec<VarHandle>, Vec<VarHandle>)>;

/// One VGG-style stage (two 3x3 conv+relu, then a 2x2 max-pool) with a
/// small classifier head — the conv-heavy shape the epilogue-fusion pass
/// targets without the full VGG parameter bill.
fn vgg_block(num_classes: usize, hw: usize) -> Model {
    let sym = Symbol::var("data")
        .convolution("conv1", 32, 3, 1, 1)
        .activation("relu1", Act::Relu)
        .convolution("conv2", 32, 3, 1, 1)
        .activation("relu2", Act::Relu)
        .pooling("pool1", Pool::Max, 2, 2, 0)
        .flatten("flat")
        .fully_connected("fc", num_classes)
        .softmax_output("softmax");
    Model {
        name: format!("vgg-block@{hw}"),
        symbol: sym,
        feat_shape: vec![3, hw, hw],
        num_classes,
    }
}

/// Bind `model` twice (epilogue fusion off / on) with identical weights,
/// time inference forward passes, and return `unfused / fused` median
/// speedup.  Fusion is bitwise lossless (property-tested in
/// `tests/properties.rs`), so this is a pure perf A/B.
fn fused_forward_ab(
    b: &Bencher,
    case: &str,
    model: &Model,
    batch: usize,
    records: &mut Vec<BenchRecord>,
    rows: &mut Vec<Vec<String>>,
) -> f64 {
    let shapes = model.var_shapes(batch).expect("shapes");
    let feat = model
        .feat_shape
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join("x");
    let shape_label = format!("{batch}x{feat}");
    let mut medians = [0.0f64; 2];
    for (i, fuse) in [false, true].into_iter().enumerate() {
        let engine = create(EngineKind::Threaded, 4);
        // Re-seeded per bind and drawn in the same (stable per-map)
        // iteration order, so both sides see identical weights.
        let mut rng = Rng::seed_from_u64(11);
        let args: HashMap<String, NDArray> = shapes
            .iter()
            .map(|(k, shape)| {
                let n: usize = shape.iter().product();
                let data: Vec<f32> = if k.ends_with("_label") {
                    vec![0.0; n]
                } else {
                    (0..n).map(|_| rng.normal_with(0.0, 0.1)).collect()
                };
                (k.clone(), NDArray::from_vec_on(shape, data, engine.clone()))
            })
            .collect();
        let exec = Executor::bind(
            &model.symbol,
            engine.clone(),
            args,
            &[],
            BindConfig { fuse, ..BindConfig::inference() },
        )
        .expect("bind");
        exec.forward();
        engine.wait_all();
        let tag = if fuse { "fused" } else { "unfused" };
        let stats = b.run(&format!("{case}.{tag}"), || {
            exec.forward();
            engine.wait_all();
        });
        medians[i] = stats.median_s();
        records.push(BenchRecord::from_stats(
            &format!("fusion.{case}_fwd_{tag}"),
            &shape_label,
            4,
            &stats,
            0.0,
        ));
        rows.push(vec![
            format!("{case} forward, epilogue fusion {}", if fuse { "on" } else { "off" }),
            format!("{:.2} ms", stats.median_s() * 1e3),
        ]);
    }
    let speedup = medians[0] / medians[1];
    rows.push(vec![
        format!("{case} fused speedup (unfused/fused)"),
        format!("{speedup:.2}x"),
    ]);
    speedup
}

/// A layered dependency DAG shaped like a training step: `layers` levels
/// of `width` ops, every op reading one var of the previous level and
/// writing its own.
fn layered_deps(engine: &EngineRef, layers: usize, width: usize) -> Deps {
    let mut deps = Vec::with_capacity(layers * width);
    let mut prev: Vec<VarHandle> = (0..width).map(|_| engine.new_var()).collect();
    for _ in 0..layers {
        let cur: Vec<VarHandle> = (0..width).map(|_| engine.new_var()).collect();
        for (i, &w) in cur.iter().enumerate() {
            deps.push((vec![prev[i]], vec![w]));
        }
        prev = cur;
    }
    deps
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let b = if quick {
        Bencher { warmup: 2, samples: 10, max_total: std::time::Duration::from_secs(5) }
    } else {
        Bencher::micro()
    };
    let mut rows = Vec::new();
    let mut records: Vec<BenchRecord> = Vec::new();

    // ---- raw push+execute overhead (empty ops) ----------------------
    for kind in [EngineKind::Threaded, EngineKind::Naive] {
        let engine = create(kind, 2);
        let v = engine.new_var();
        let n = 1000usize;
        let stats = b.run("overhead", || {
            let c = Arc::new(AtomicUsize::new(0));
            for _ in 0..n {
                let c = Arc::clone(&c);
                engine.push("noop", vec![], vec![v], Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }));
            }
            engine.wait_all();
        });
        rows.push(vec![
            format!("{kind:?} push+run x1000 (serial chain)"),
            format!("{:.1} us/op", stats.median_s() * 1e6 / n as f64),
        ]);
    }

    // ---- replay vs push: identical layered DAG of noops -------------
    // The scheduling-overhead comparison the ISSUE 3 acceptance names:
    // same ops, same dependency structure; one path pays the dynamic
    // scheduler per op, the other replays the precompiled plan.
    let (layers, width) = if quick { (32, 4) } else { (64, 4) };
    let nops = layers * width;
    let engine = create(EngineKind::Threaded, 2);
    let deps = layered_deps(&engine, layers, width);
    let counter = Arc::new(AtomicUsize::new(0));

    let push_stats = {
        let deps = deps.clone();
        let c0 = Arc::clone(&counter);
        b.run("engine.push DAG", move || {
            for (r, w) in &deps {
                let c = Arc::clone(&c0);
                engine.push("noop", r.clone(), w.clone(), Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }));
            }
            engine.wait_all();
        })
    };
    let push_ns = push_stats.median_s() * 1e9 / nops as f64;
    let dag_shape = format!("{layers}x{width}");
    rows.push(vec![
        format!("dynamic push, {nops}-op layered DAG"),
        format!("{push_ns:.0} ns/op"),
    ]);
    records.push(BenchRecord::from_stats("engine.push_dag", &dag_shape, 2, &push_stats, 0.0));

    // Fresh engine/vars for the replay side so var queues start clean.
    let engine = create(EngineKind::Threaded, 2);
    let deps = layered_deps(&engine, layers, width);
    let specs: Vec<PlanOpSpec> = deps
        .iter()
        .map(|(r, w)| {
            let c = Arc::clone(&counter);
            PlanOpSpec {
                name: "noop",
                reads: r.clone(),
                writes: w.clone(),
                cost: f64::NAN,
                body: Arc::new(move |_step| {
                    c.fetch_add(1, Ordering::Relaxed);
                }),
            }
        })
        .collect();
    let plan = Arc::new(RunPlan::compile(specs));
    let replay_stats = {
        let engine = engine.clone();
        let plan = Arc::clone(&plan);
        b.run("plan.replay DAG", move || {
            engine.run_plan(&plan, 1);
            engine.wait_all();
        })
    };
    let replay_ns = replay_stats.median_s() * 1e9 / nops as f64;
    let speedup = push_ns / replay_ns;
    rows.push(vec![
        format!("run-plan replay, same {nops}-op DAG"),
        format!("{replay_ns:.0} ns/op ({speedup:.1}x vs push)"),
    ]);
    records.push(BenchRecord::from_stats("engine.plan_replay", &dag_shape, 2, &replay_stats, 0.0));

    // ---- storage pool vs allocator ----------------------------------
    let elems = if quick { 1 << 16 } else { 1 << 18 }; // 256 KiB / 1 MiB
    let buf_shape = format!("{elems}");
    let pool_stats = b.run("pool acquire+release", || {
        let mut buf = pool::global().acquire_uninit(elems);
        buf[0] = std::hint::black_box(1.0);
        pool::global().release(buf);
    });
    rows.push(vec![
        format!("pool acquire+release {elems} f32 (steady hit)"),
        format!("{:.0} ns", pool_stats.median_s() * 1e9),
    ]);
    records.push(BenchRecord::from_stats("pool.acquire_release", &buf_shape, 0, &pool_stats, 0.0));
    let raw_stats = b.run("malloc+free", || {
        let mut buf = vec![0.0f32; elems].into_boxed_slice();
        buf[0] = std::hint::black_box(1.0);
        std::hint::black_box(&buf);
    });
    rows.push(vec![
        format!("alloc_zeroed+free {elems} f32 (allocator)"),
        format!("{:.0} ns", raw_stats.median_s() * 1e9),
    ]);
    records.push(BenchRecord::from_stats("pool.malloc_baseline", &buf_shape, 0, &raw_stats, 0.0));

    // ---- allocs per training step (pool miss counter) ---------------
    // Bind a real MLP executor, warm it up, then count pool misses over
    // measured steps: the acceptance criterion is zero.
    let misses_per_step = {
        let engine = create(EngineKind::Threaded, 2);
        let model = mlp(&[64, 32], 32, 8);
        let batch = 16usize;
        let shapes = model.var_shapes(batch).expect("shapes");
        let mut rng = Rng::seed_from_u64(7);
        let args: HashMap<String, NDArray> = shapes
            .iter()
            .map(|(k, shape)| {
                let n: usize = shape.iter().product();
                let data: Vec<f32> = if k.ends_with("_label") {
                    (0..n).map(|j| (j % 8) as f32).collect()
                } else {
                    (0..n).map(|_| rng.normal_with(0.0, 0.1)).collect()
                };
                (k.clone(), NDArray::from_vec_on(shape, data, engine.clone()))
            })
            .collect();
        let params: Vec<String> = shapes
            .keys()
            .filter(|k| k.as_str() != "data" && !k.ends_with("_label"))
            .cloned()
            .collect();
        let grad_names: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
        let exec =
            Executor::bind(&model.symbol, engine.clone(), args, &grad_names, BindConfig::default())
                .expect("bind");
        let step = || {
            exec.forward_backward().expect("fwd/bwd");
            for p in &params {
                exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), 0.05);
            }
        };
        for _ in 0..3 {
            step();
        }
        engine.wait_all();
        let before = pool::global().stats();
        let t = b.run("train step (replay+pool)", || {
            step();
            engine.wait_all();
        });
        let after = pool::global().stats();
        records.push(BenchRecord::from_stats("train.step_mlp", "16x32", 2, &t, 0.0));
        let total_steps = (t.samples.len() + b.warmup) as f64;
        let miss_delta = after.misses - before.misses;
        rows.push(vec![
            "MLP train step, replay + pool (allocs/step)".into(),
            format!(
                "{:.3} ms, {miss_delta} pool misses over {:.0} steps",
                t.median_ms(),
                total_steps
            ),
        ]);
        miss_delta as f64 / total_steps
    };

    // ---- NDArray op through the full lazy path ----------------------
    let x = NDArray::randn(&[256, 256], 0.0, 1.0, 3);
    let stats = b.run("ndarray-lazy", || {
        let y = x.add_scalar(1.0);
        y.wait_to_read();
    });
    rows.push(vec![
        "NDArray add_scalar 256x256 (push+run+wait)".into(),
        format!("{:.1} us", stats.median_s() * 1e6),
    ]);

    // ---- dependency fan-in (diamond) ---------------------------------
    let engine = create(EngineKind::Threaded, 2);
    let stats = b.run("diamond", || {
        let a = engine.new_var();
        let b1 = engine.new_var();
        let b2 = engine.new_var();
        let d = engine.new_var();
        engine.push("a", vec![], vec![a], Box::new(|| {}));
        engine.push("b1", vec![a], vec![b1], Box::new(|| {}));
        engine.push("b2", vec![a], vec![b2], Box::new(|| {}));
        engine.push("d", vec![b1, b2], vec![d], Box::new(|| {}));
        engine.wait_all();
        for v in [a, b1, b2, d] {
            engine.delete_var(v);
        }
    });
    rows.push(vec![
        "diamond a->(b1,b2)->d (4 ops + var lifecycle)".into(),
        format!("{:.1} us", stats.median_s() * 1e6),
    ]);

    // ---- inter- vs intra-op cooperation on heavy GEMMs ---------------
    // One heavy op in flight gets the whole intra-op pool; eight
    // independent heavy ops split it (budget = pool / heavies), so the
    // batch should take well under 8x the single-op time on multi-core
    // hosts while never oversubscribing.
    let bh = Bencher { warmup: 1, samples: 5, max_total: std::time::Duration::from_secs(20) };
    let engine = create(EngineKind::Threaded, 4);
    let sz = if quick { 192 } else { 384 };
    let xs: Vec<NDArray> = (0..8)
        .map(|i| NDArray::randn_on(&[sz, sz], 0.0, 1.0, 20 + i as u64, engine.clone()))
        .collect();
    let w = NDArray::randn_on(&[sz, sz], 0.0, 1.0, 40, engine.clone());
    engine.wait_all();
    let one = bh.run("one-heavy-gemm", || {
        let y = xs[0].dot(&w);
        y.wait_to_read();
    });
    rows.push(vec![
        format!("1 heavy GEMM {sz}^3 (full intra-op pool)"),
        format!("{:.1} ms", one.median_s() * 1e3),
    ]);
    let eight = bh.run("eight-heavy-gemms", || {
        let ys: Vec<NDArray> = xs.iter().map(|x| x.dot(&w)).collect();
        for y in &ys {
            y.wait_to_read();
        }
    });
    rows.push(vec![
        format!("8 independent GEMMs {sz}^3 (budget-shared)"),
        format!(
            "{:.1} ms ({:.2}x one op)",
            eight.median_s() * 1e3,
            eight.median_s() / one.median_s()
        ),
    ]);

    // ---- epilogue fusion: fused vs unfused forward (ISSUE 8) ---------
    // Same weights, same schedule; the only difference is whether the
    // graph compiler folds bias/activation/elementwise chains into the
    // GEMM/conv epilogue (applied while the output tile is cache-hot).
    let alex_batch = if quick { 1 } else { 4 };
    let alex = alexnet(4, 64);
    let alex_speedup = fused_forward_ab(&bh, "alexnet", &alex, alex_batch, &mut records, &mut rows);
    let vggb_batch = if quick { 2 } else { 8 };
    let vggb = vgg_block(8, 32);
    let vggb_speedup =
        fused_forward_ab(&bh, "vgg_block", &vggb, vggb_batch, &mut records, &mut rows);
    let fused_speedup = (alex_speedup * vggb_speedup).sqrt();

    print_table("engine microbenchmarks", &["case", "cost"], &rows);

    let mut meta = standard_meta("engine", quick);
    meta.extend([
        ("dag", format!("{layers}x{width} noop layered DAG")),
        ("push_ns_per_op", format!("{push_ns:.1}")),
        ("replay_ns_per_op", format!("{replay_ns:.1}")),
        ("replay_speedup_vs_push", format!("{speedup:.2}")),
        ("steady_state_pool_misses_per_step", format!("{misses_per_step:.3}")),
        ("alexnet_fused_speedup", format!("{alex_speedup:.3}")),
        ("vgg_block_fused_speedup", format!("{vggb_speedup:.3}")),
        ("fused_speedup", format!("{fused_speedup:.3}")),
    ]);
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_engine.json".to_string());
    if let Err(e) = write_bench_json(&out, &meta, &records) {
        eprintln!("failed to write {out}: {e}");
    }
}

//! Engine microbenchmarks: scheduling overhead per op, parallelism
//! discovery, and the cost of dependency tracking — the substrate
//! numbers behind E1/E4/E5.
//!
//! ```text
//! cargo bench --bench engine_micro
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use mixnet::engine::{create, EngineKind};
use mixnet::ndarray::NDArray;
use mixnet::util::bench::{print_table, Bencher};

fn main() {
    let b = Bencher::micro();
    let mut rows = Vec::new();

    // ---- raw push+execute overhead (empty ops) ----------------------
    for kind in [EngineKind::Threaded, EngineKind::Naive] {
        let engine = create(kind, 2);
        let v = engine.new_var();
        let n = 1000usize;
        let stats = b.run("overhead", || {
            let c = Arc::new(AtomicUsize::new(0));
            for _ in 0..n {
                let c = Arc::clone(&c);
                engine.push("noop", vec![], vec![v], Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }));
            }
            engine.wait_all();
        });
        rows.push(vec![
            format!("{kind:?} push+run x1000 (serial chain)"),
            format!("{:.1} us/op", stats.median_s() * 1e6 / n as f64),
        ]);
    }

    // ---- independent ops: parallelism discovery ---------------------
    let engine = create(EngineKind::Threaded, 2);
    let vars: Vec<_> = (0..64).map(|_| engine.new_var()).collect();
    let stats = b.run("independent", || {
        for v in &vars {
            engine.push("spin", vec![], vec![*v], Box::new(|| {
                std::hint::black_box((0..2000).sum::<u64>());
            }));
        }
        engine.wait_all();
    });
    rows.push(vec![
        "64 independent ops (threaded, 2 workers)".into(),
        format!("{:.1} us total", stats.median_s() * 1e6),
    ]);

    // ---- NDArray op through the full lazy path ----------------------
    let x = NDArray::randn(&[256, 256], 0.0, 1.0, 3);
    let stats = b.run("ndarray-lazy", || {
        let y = x.add_scalar(1.0);
        y.wait_to_read();
    });
    rows.push(vec![
        "NDArray add_scalar 256x256 (push+run+wait)".into(),
        format!("{:.1} us", stats.median_s() * 1e6),
    ]);

    // ---- dependency fan-in (diamond) ---------------------------------
    let engine = create(EngineKind::Threaded, 2);
    let stats = b.run("diamond", || {
        let a = engine.new_var();
        let b1 = engine.new_var();
        let b2 = engine.new_var();
        let d = engine.new_var();
        engine.push("a", vec![], vec![a], Box::new(|| {}));
        engine.push("b1", vec![a], vec![b1], Box::new(|| {}));
        engine.push("b2", vec![a], vec![b2], Box::new(|| {}));
        engine.push("d", vec![b1, b2], vec![d], Box::new(|| {}));
        engine.wait_all();
        for v in [a, b1, b2, d] {
            engine.delete_var(v);
        }
    });
    rows.push(vec![
        "diamond a->(b1,b2)->d (4 ops + var lifecycle)".into(),
        format!("{:.1} us", stats.median_s() * 1e6),
    ]);

    // ---- inter- vs intra-op cooperation on heavy GEMMs ---------------
    // One heavy op in flight gets the whole intra-op pool; eight
    // independent heavy ops split it (budget = pool / heavies), so the
    // batch should take well under 8x the single-op time on multi-core
    // hosts while never oversubscribing.
    let bh = Bencher { warmup: 1, samples: 5, max_total: std::time::Duration::from_secs(20) };
    let engine = create(EngineKind::Threaded, 4);
    let sz = 384;
    let xs: Vec<NDArray> = (0..8)
        .map(|i| NDArray::randn_on(&[sz, sz], 0.0, 1.0, 20 + i as u64, engine.clone()))
        .collect();
    let w = NDArray::randn_on(&[sz, sz], 0.0, 1.0, 40, engine.clone());
    engine.wait_all();
    let one = bh.run("one-heavy-gemm", || {
        let y = xs[0].dot(&w);
        y.wait_to_read();
    });
    rows.push(vec![
        format!("1 heavy GEMM {sz}^3 (full intra-op pool)"),
        format!("{:.1} ms", one.median_s() * 1e3),
    ]);
    let eight = bh.run("eight-heavy-gemms", || {
        let ys: Vec<NDArray> = xs.iter().map(|x| x.dot(&w)).collect();
        for y in &ys {
            y.wait_to_read();
        }
    });
    rows.push(vec![
        format!("8 independent GEMMs {sz}^3 (budget-shared)"),
        format!(
            "{:.1} ms ({:.2}x one op)",
            eight.median_s() * 1e3,
            eight.median_s() / one.median_s()
        ),
    ]);

    print_table("engine microbenchmarks", &["case", "cost"], &rows);
}

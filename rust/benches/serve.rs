//! Closed-loop serving benchmark: dynamic batching (max batch 64) vs
//! batch-1 serving on the servable MLP, 64 concurrent closed-loop
//! clients.  Emits machine-readable `BENCH_serve.json`.
//!
//! Acceptance target (ISSUE 2): dynamic batching delivers >= 4x the
//! batch-1 throughput — each dispatched batch amortizes the per-request
//! engine scheduling and lets the GEMMs run at batched shapes.
//!
//! ```text
//! cargo bench --bench serve                 # full run + JSON
//! BENCH_QUICK=1 cargo bench --bench serve   # CI smoke (fewer requests)
//! BENCH_OUT=/tmp/s.json cargo bench --bench serve
//! ```

use std::collections::HashMap;

use mixnet::engine::{create, default_threads, EngineKind};
use mixnet::models::servable_mlp;
use mixnet::module::Module;
use mixnet::ndarray::NDArray;
use mixnet::serve::{closed_loop, Servable, ServeConfig, Server};
use mixnet::util::bench::{print_table, standard_meta, write_bench_json, BenchRecord};
use mixnet::util::Rng;

const IN_DIM: usize = 784;
const CLASSES: usize = 10;
const CLIENTS: usize = 64;

fn build_servable(engine: &mixnet::engine::EngineRef) -> Servable {
    // Xavier-initialized weights are fine for a throughput benchmark;
    // the tier-1 tests cover the train -> checkpoint -> serve path.
    let model = servable_mlp(IN_DIM, CLASSES);
    let shapes = model.param_shapes(1).unwrap();
    let mut m = Module::new(servable_mlp(IN_DIM, CLASSES).symbol, engine.clone());
    m.bind_inference(1, &[IN_DIM], &shapes, 0x5eed).unwrap();
    let params: HashMap<String, NDArray> = m
        .param_names()
        .iter()
        .map(|n| (n.clone(), m.param(n).unwrap().clone()))
        .collect();
    Servable::new(model, params, engine.clone()).unwrap()
}

struct CaseResult {
    name: &'static str,
    rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
}

fn run_case(
    name: &'static str,
    servable: &Servable,
    cfg: &ServeConfig,
    per_client: usize,
    samples: &[Vec<f32>],
) -> CaseResult {
    let mut server = Server::start(servable, cfg).expect("server start");
    let report = closed_loop(&server, CLIENTS, per_client, samples);
    let stats = server.shutdown();
    assert_eq!(report.errors, 0, "{name}: closed loop saw errors");
    eprintln!(
        "  {name:<16} {:>9.0} req/s   p50 {:>7.3} ms   p95 {:>7.3} ms   \
         p99 {:>7.3} ms   mean batch {:>5.2}",
        report.rps,
        stats.p50_us as f64 / 1e3,
        stats.p95_us as f64 / 1e3,
        stats.p99_us as f64 / 1e3,
        stats.mean_batch
    );
    CaseResult {
        name,
        rps: report.rps,
        p50_ms: stats.p50_us as f64 / 1e3,
        p95_ms: stats.p95_us as f64 / 1e3,
        p99_ms: stats.p99_us as f64 / 1e3,
        mean_batch: stats.mean_batch,
    }
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let per_client = if quick { 40 } else { 250 };
    let engine = create(EngineKind::Threaded, default_threads());
    let servable = build_servable(&engine);

    let mut rng = Rng::seed_from_u64(17);
    let samples: Vec<Vec<f32>> =
        (0..256).map(|_| (0..IN_DIM).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();

    eprintln!("serve bench: {CLIENTS} closed-loop clients x {per_client} requests");
    let batch1 = run_case(
        "batch-1",
        &servable,
        &ServeConfig {
            max_batch: 1,
            max_delay_us: 0,
            queue_cap: 4096,
            workers: 2,
            buckets: vec![1],
        },
        per_client,
        &samples,
    );
    let dynamic = run_case(
        "dynamic-64",
        &servable,
        &ServeConfig {
            max_batch: 64,
            max_delay_us: 2_000,
            queue_cap: 4096,
            workers: 2,
            buckets: vec![], // 1, 4, 16, 64
        },
        per_client,
        &samples,
    );

    let speedup = if batch1.rps > 0.0 { dynamic.rps / batch1.rps } else { f64::NAN };
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for c in [&batch1, &dynamic] {
        rows.push(vec![
            c.name.to_string(),
            format!("{:.0}", c.rps),
            format!("{:.3}", c.p50_ms),
            format!("{:.3}", c.p95_ms),
            format!("{:.3}", c.p99_ms),
            format!("{:.2}", c.mean_batch),
        ]);
        for (metric, ms) in
            [("p50", c.p50_ms), ("p95", c.p95_ms), ("p99", c.p99_ms)]
        {
            records.push(BenchRecord {
                op: format!("serve/{}/{metric}", c.name),
                shape: format!("mlp-{IN_DIM}-c{CLIENTS}"),
                threads: 2,
                median_ms: ms,
                gflops: 0.0,
            });
        }
        // throughput record: median_ms carries the per-request service
        // time (1000/rps), the meta block carries the raw rps
        records.push(BenchRecord {
            op: format!("serve/{}/throughput", c.name),
            shape: format!("mlp-{IN_DIM}-c{CLIENTS}"),
            threads: 2,
            median_ms: if c.rps > 0.0 { 1e3 / c.rps } else { f64::NAN },
            gflops: 0.0,
        });
    }
    rows.push(vec![
        "speedup".into(),
        format!("{speedup:.2}x"),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
    ]);
    print_table(
        "serving throughput: dynamic batching vs batch-1 (64 clients)",
        &["case", "req/s", "p50 ms", "p95 ms", "p99 ms", "mean batch"],
        &rows,
    );
    eprintln!("dynamic/batch-1 speedup: {speedup:.2}x (target >= 4x)");

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let mut meta = standard_meta("serve", quick);
    meta.extend([
        ("model", format!("mlp-{IN_DIM}x128x64x{CLASSES}")),
        ("clients", CLIENTS.to_string()),
        ("per_client", per_client.to_string()),
        ("batch1_rps", format!("{:.1}", batch1.rps)),
        ("dynamic_rps", format!("{:.1}", dynamic.rps)),
        ("speedup_vs_batch1", format!("{speedup:.2}")),
        (
            "note",
            "closed-loop clients; dynamic = max_batch 64, buckets 1/4/16/64, \
             max_delay 2ms; target speedup >= 4x"
                .to_string(),
        ),
    ]);
    if let Err(e) = write_bench_json(&out, &meta, &records) {
        eprintln!("failed to write {out}: {e}");
    }
}

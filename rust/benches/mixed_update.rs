//! §2.2 claim (DESIGN E4): the mixed program — symbolic
//! `forward_backward()` + imperative `w -= eta*g` NDArray updates — is
//! "as efficient as the implementation using a single but often much
//! more complex symbolic expression", because both flow through one
//! engine.
//!
//! Three variants of one SGD step on the Figure 2 MLP:
//!  * `fused-symbolic` — the update is part of the bound graph
//!    (FusedElemwise update ops appended), one executor call.
//!  * `mixed` — forward_backward + imperative sub_scaled_ per param
//!    (the paper's recommended style).
//!  * `mixed-sync` — same, but with a wait_all() barrier between the
//!    backward and the updates (what a non-joint scheduler would do).
//!
//! Expected: mixed within ~5% of fused-symbolic; mixed-sync slower.
//!
//! ```text
//! cargo bench --bench mixed_update
//! ```

use std::collections::HashMap;


use mixnet::engine::{create, EngineKind};
use mixnet::executor::{BindConfig, Executor};
use mixnet::graph::{Entry, FusedStep, Op};
use mixnet::models::mlp;
use mixnet::ndarray::kernels::EwBinary;
use mixnet::ndarray::NDArray;
use mixnet::util::bench::{print_table, Bencher};

const BATCH: usize = 64;
const DIM: usize = 256;
const HIDDEN: usize = 512;
const CLASSES: usize = 16;
const ETA: f32 = 0.01;

fn args(engine: &mixnet::engine::EngineRef) -> HashMap<String, NDArray> {
    let model = mlp(&[HIDDEN], DIM, CLASSES);
    let shapes = model.var_shapes(BATCH).unwrap();
    let mut seed = 5u64;
    shapes
        .iter()
        .map(|(n, s)| {
            seed += 1;
            let a = if n.ends_with("_label") {
                NDArray::from_vec_on(
                    s,
                    (0..BATCH).map(|i| (i % CLASSES) as f32).collect(),
                    engine.clone(),
                )
            } else {
                NDArray::randn_on(s, 0.0, 0.05, seed, engine.clone())
            };
            (n.clone(), a)
        })
        .collect()
}

const PARAMS: [&str; 4] = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"];

/// Bind the MLP, then append `w -= eta * g` as graph nodes so the whole
/// step is one symbolic program.
fn bind_fused(engine: mixnet::engine::EngineRef) -> Executor {
    let model = mlp(&[HIDDEN], DIM, CLASSES);
    let mut graph = mixnet::symbol::Symbol::to_graph(std::slice::from_ref(&model.symbol));
    // autodiff happens inside bind; to fuse the update we instead bind a
    // graph that already contains backward + update. Build it manually:
    let wrt: Vec<_> = graph
        .variables()
        .into_iter()
        .filter(|&v| {
            let n = &graph.nodes[v].name;
            n != "data" && !n.ends_with("_label")
        })
        .collect();
    let gi = mixnet::graph::autodiff::build_backward(&mut graph, &wrt).unwrap();
    // The whole program (fwd+bwd+update) IS the forward pass of this one
    // symbolic program: clear the fwd/bwd split so forward() runs it all.
    graph.num_forward = 0;
    for (&vid, &gentry) in &gi.var_grads {
        let name = format!("{}_sgd", graph.nodes[vid].name);
        // w <- w + (-eta) * g  == FusedElemwise [MulScalar(-eta), Binary(Add)]
        let upd = graph.add_node(
            Op::FusedElemwise {
                steps: vec![FusedStep::MulScalar(-ETA), FusedStep::Binary(EwBinary::Add)],
            },
            name,
            vec![gentry, Entry::new(vid)],
        );
        graph.outputs.push(Entry::new(upd));
    }
    Executor::bind_graph(
        graph,
        engine.clone(),
        args(&engine),
        &[],
        BindConfig { training: false, fuse: false, ..Default::default() },
    )
    .unwrap()
}

fn bind_plain(engine: mixnet::engine::EngineRef) -> Executor {
    let model = mlp(&[HIDDEN], DIM, CLASSES);
    Executor::bind(
        &model.symbol,
        engine.clone(),
        args(&engine),
        &PARAMS,
        BindConfig::default(),
    )
    .unwrap()
}

fn main() {
    let b = Bencher { warmup: 3, samples: 20, max_total: std::time::Duration::from_secs(30) };
    let threads = mixnet::engine::default_threads();

    let engine = create(EngineKind::Threaded, threads);
    let fused = bind_fused(engine);
    let s_fused = b.run("fused-symbolic", || {
        fused.forward();
        fused.wait();
    });

    let engine = create(EngineKind::Threaded, threads);
    let exec = bind_plain(engine.clone());
    let s_mixed = b.run("mixed", || {
        exec.forward_backward().unwrap();
        for p in PARAMS {
            exec.arg(p).unwrap().sub_scaled_(exec.grad(p).unwrap(), ETA);
        }
        engine.wait_all();
    });

    let engine = create(EngineKind::Threaded, threads);
    let exec2 = bind_plain(engine.clone());
    let s_sync = b.run("mixed-sync", || {
        exec2.forward_backward().unwrap();
        engine.wait_all(); // artificial barrier: no joint scheduling
        for p in PARAMS {
            exec2.arg(p).unwrap().sub_scaled_(exec2.grad(p).unwrap(), ETA);
        }
        engine.wait_all();
    });

    let base = s_fused.median_ms();
    print_table(
        "E4 — one SGD step on the Figure 2 MLP (batch 64)",
        &["variant", "median ms", "vs fused"],
        &[
            vec!["fused-symbolic".into(), format!("{base:.3}"), "1.00x".into()],
            vec![
                "mixed (paper §2.2)".into(),
                format!("{:.3}", s_mixed.median_ms()),
                format!("{:.2}x", s_mixed.median_ms() / base),
            ],
            vec![
                "mixed + barrier".into(),
                format!("{:.3}", s_sync.median_ms()),
                format!("{:.2}x", s_sync.median_ms() / base),
            ],
        ],
    );
    println!("\npaper claim: mixed ~ fused (the engine resolves the dependency);");
    println!("the barrier variant shows what is lost without joint scheduling");
}

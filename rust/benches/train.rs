//! Data-parallel training bench (ISSUE 4): images/sec at devices in
//! {1, 2, 4} over a fixed 4-shard decomposition, plus the per-layer
//! push-overlap demonstration — overlap-on vs overlap-off step timing
//! under a serialized "wire" whose per-key transfer latency is injected
//! into the KVStore delivery path.
//!
//! ```text
//! cargo bench --bench train
//! BENCH_QUICK=1 cargo bench --bench train   # CI smoke (fewer samples)
//! BENCH_OUT=/tmp/t.json cargo bench --bench train
//! ```
//!
//! Emits `BENCH_train.json`: per-case records plus meta with
//! `images_per_sec_dev{1,2,4}`, `overlap_on_ms`, `overlap_off_ms` and
//! `overlap_speedup` (expected > 1: overlapped pushes start mid-backward
//! and hide under compute; non-overlapped pushes queue behind the whole
//! pass and pay the wire serially), and the ISSUE 5 straggler case —
//! one slow replica shard under BSP vs `BoundedDelay(2)`
//! (`straggler_bsp_ms`, `straggler_bounded_ms`, `straggler_speedup`;
//! expected > 1: the bounded pipeline hides the straggler's wire tail
//! under the next rounds' compute), and the ISSUE 10 sharded-fleet
//! curve — images/sec at server-shard counts {1, 2, 4} under one
//! serialized wire per shard (`shard_wire_ips_{1,2,4}`; expected to
//! rise with the shard count: the router spreads keys across
//! independent wires).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use mixnet::engine::{create, default_threads, EngineKind, EngineRef};
use mixnet::executor::BindConfig;
use mixnet::io::{synth, ArrayDataIter};
use mixnet::kvstore::shard::ShardRouter;
use mixnet::kvstore::{Consistency, KVStore, LocalKVStore};
use mixnet::models::mlp;
use mixnet::module::{DataParallelTrainer, SyncMode, TrainerConfig};
use mixnet::ndarray::NDArray;
use mixnet::optimizer::Sgd;
use mixnet::util::bench::{print_table, standard_meta, write_bench_json, BenchRecord, Bencher};

const DIM: usize = 256;
const CLASSES: usize = 8;
const SHARDS: usize = 4;
const SHARD_BATCH: usize = 16;

/// Wraps a store with a serialized per-delivery transfer delay — a
/// single "NIC" all gradient transfers must pass through, so the cost of
/// *when* a push starts becomes visible in wall-clock.  With `slow_part`
/// set, only that part's deliveries pay the wire (a straggler replica
/// shard); `None` slows every delivery.
struct SlowWire {
    inner: LocalKVStore,
    wire: Mutex<()>,
    delay: Duration,
    slow_part: Option<usize>,
}

impl KVStore for SlowWire {
    fn init(&self, key: &str, value: &NDArray) -> mixnet::Result<()> {
        self.inner.init(key, value)
    }
    fn push(&self, key: &str, grad: &NDArray, device: usize) -> mixnet::Result<()> {
        self.inner.push(key, grad, device)
    }
    fn push_part(&self, key: &str, grad: &[f32], part: usize) -> mixnet::Result<()> {
        if self.slow_part.is_none() || self.slow_part == Some(part) {
            let _nic = self.wire.lock().unwrap();
            std::thread::sleep(self.delay);
        }
        self.inner.push_part(key, grad, part)
    }
    fn pull(&self, key: &str, out: &NDArray, device: usize) -> mixnet::Result<()> {
        self.inner.pull(key, out, device)
    }
    fn flush(&self) {
        self.inner.flush()
    }
    fn num_devices(&self) -> usize {
        self.inner.num_devices()
    }
    fn consistency(&self) -> Consistency {
        self.inner.consistency()
    }
}

/// The sharded-fleet wire model (ISSUE 10): every gradient transfer
/// routes through its key's home shard "NIC" — one serialized wire per
/// server shard, each delivery paying `delay` while holding that
/// shard's wire lock.  With one shard every key queues behind one NIC
/// (the straggler case); with N shards the router spreads the keys and
/// the transfers overlap.  The math underneath is the same
/// LocalKVStore, so throughput differences are pure wire scheduling.
struct ShardWire {
    inner: LocalKVStore,
    router: ShardRouter,
    wires: Vec<Mutex<()>>,
    delay: Duration,
}

impl KVStore for ShardWire {
    fn init(&self, key: &str, value: &NDArray) -> mixnet::Result<()> {
        self.inner.init(key, value)
    }
    fn push(&self, key: &str, grad: &NDArray, device: usize) -> mixnet::Result<()> {
        self.inner.push(key, grad, device)
    }
    fn push_part(&self, key: &str, grad: &[f32], part: usize) -> mixnet::Result<()> {
        let _nic = self.wires[self.router.home(key)].lock().unwrap();
        std::thread::sleep(self.delay);
        self.inner.push_part(key, grad, part)
    }
    fn pull(&self, key: &str, out: &NDArray, device: usize) -> mixnet::Result<()> {
        self.inner.pull(key, out, device)
    }
    fn flush(&self) {
        self.inner.flush()
    }
    fn num_devices(&self) -> usize {
        self.inner.num_devices()
    }
    fn consistency(&self) -> Consistency {
        self.inner.consistency()
    }
}

fn dataset(examples: usize, engine: &EngineRef) -> ArrayDataIter {
    let ds = synth::class_clusters(examples, CLASSES, DIM, 0.3, 11);
    ArrayDataIter::new(ds.features, ds.labels, &[DIM], SHARDS * SHARD_BATCH, true, engine.clone())
}

fn build_trainer(
    engine: &EngineRef,
    devices: usize,
    overlap: bool,
    sync: SyncMode,
    store: Arc<dyn KVStore>,
) -> DataParallelTrainer {
    let model = mlp(&[256, 128], DIM, CLASSES);
    let shapes = model.param_shapes(SHARD_BATCH).expect("shapes");
    DataParallelTrainer::bind(
        &model.symbol,
        engine.clone(),
        SHARD_BATCH,
        &[DIM],
        &shapes,
        store,
        TrainerConfig {
            devices,
            shards: SHARDS,
            overlap,
            bind: BindConfig::default(),
            seed: 5,
            sync,
            weights: vec![],
        },
    )
    .expect("bind trainer")
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let b = if quick {
        Bencher { warmup: 1, samples: 3, max_total: Duration::from_secs(20) }
    } else {
        Bencher { warmup: 2, samples: 10, max_total: Duration::from_secs(120) }
    };
    let examples = if quick { 512 } else { 2048 };
    let threads = default_threads().max(4);
    let mut rows = Vec::new();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut meta = standard_meta("train", quick);
    meta.extend([
        ("model", format!("mlp 256-256-128-{CLASSES}")),
        ("global_batch", (SHARDS * SHARD_BATCH).to_string()),
        ("shards", SHARDS.to_string()),
    ]);

    // ---- images/sec at devices in {1, 2, 4}, fixed 4-shard math ------
    let mut per_dev: HashMap<usize, f64> = HashMap::new();
    for devices in [1usize, 2, 4] {
        let engine = create(EngineKind::Threaded, threads);
        let store = Arc::new(LocalKVStore::new(
            engine.clone(),
            SHARDS,
            Arc::new(Sgd::new(0.1).rescale(1.0 / SHARDS as f32)),
            Consistency::Sequential,
        ));
        let mut trainer = build_trainer(&engine, devices, true, SyncMode::Bsp, store);
        let mut iter = dataset(examples, &engine);
        let per_epoch =
            (examples / (SHARDS * SHARD_BATCH)) * SHARDS * SHARD_BATCH;
        let stats = b.run(&format!("train dev{devices}"), || {
            trainer.fit(&mut iter, 1).expect("fit");
        });
        let ips = per_epoch as f64 / stats.median_s();
        rows.push(vec![
            format!("{devices} device(s), {SHARDS} shards, epoch of {per_epoch} images"),
            format!("{:.1} ms", stats.median_ms()),
            format!("{ips:.0} img/s"),
        ]);
        records.push(BenchRecord::from_stats(
            "train.mlp_epoch",
            &format!("dev{devices}x{SHARDS}shards"),
            devices,
            &stats,
            0.0,
        ));
        per_dev.insert(devices, ips);
    }
    for devices in [1usize, 2, 4] {
        let key: &'static str = match devices {
            1 => "images_per_sec_dev1",
            2 => "images_per_sec_dev2",
            _ => "images_per_sec_dev4",
        };
        meta.push((key, format!("{:.1}", per_dev[&devices])));
    }

    // ---- overlap-on vs overlap-off under a serialized slow wire ------
    // 500us per gradient transfer through one mutex-held "NIC": with
    // overlap on, transfers start the moment each layer's gradient
    // retires and pipeline under the rest of backward; with overlap off
    // every transfer waits for the whole pass and the wire cost lands
    // serially on the step.
    let delay = Duration::from_micros(500);
    let mut overlap_ms: HashMap<bool, f64> = HashMap::new();
    for overlap in [true, false] {
        let engine = create(EngineKind::Threaded, threads);
        let store = Arc::new(SlowWire {
            inner: LocalKVStore::new(
                engine.clone(),
                SHARDS,
                Arc::new(Sgd::new(0.1).rescale(1.0 / SHARDS as f32)),
                Consistency::Sequential,
            ),
            wire: Mutex::new(()),
            delay,
            slow_part: None,
        });
        let mut trainer = build_trainer(&engine, 2, overlap, SyncMode::Bsp, store);
        let small = if quick { 256 } else { 512 };
        let mut iter = dataset(small, &engine);
        let name = if overlap { "overlap-on" } else { "overlap-off" };
        let stats = b.run(name, || {
            trainer.fit(&mut iter, 1).expect("fit");
        });
        let batches = small / (SHARDS * SHARD_BATCH);
        let step_ms = stats.median_ms() / batches as f64;
        rows.push(vec![
            format!("{name}: per-layer push, 500us/key serialized wire"),
            format!("{step_ms:.2} ms/step"),
            String::new(),
        ]);
        records.push(BenchRecord::from_stats(
            if overlap { "train.overlap_on" } else { "train.overlap_off" },
            "dev2x4shards+wire",
            2,
            &stats,
            0.0,
        ));
        overlap_ms.insert(overlap, step_ms);
    }
    let speedup = overlap_ms[&false] / overlap_ms[&true];
    meta.push(("overlap_on_ms", format!("{:.3}", overlap_ms[&true])));
    meta.push(("overlap_off_ms", format!("{:.3}", overlap_ms[&false])));
    meta.push(("overlap_speedup", format!("{speedup:.2}")));
    rows.push(vec![
        "overlap speedup (off/on step time)".into(),
        format!("{speedup:.2}x"),
        String::new(),
    ]);

    // ---- straggler: BSP vs BoundedDelay(2) under one slow part -------
    // The last part's deliveries (one straggling replica shard) crawl
    // through a 400us/key serialized wire.  BSP's full barrier pays that
    // tail every round; the bounded-delay pipeline leaves up to 2 rounds
    // in flight and hides the tail under the next rounds' compute —
    // ISSUE 5's backpressure-with-a-ceiling demonstration.
    let mut straggler_ms: HashMap<bool, f64> = HashMap::new();
    for bounded in [false, true] {
        let engine = create(EngineKind::Threaded, threads);
        let consistency =
            if bounded { Consistency::BoundedDelay(2) } else { Consistency::Sequential };
        let sync = if bounded { SyncMode::BoundedDelay(2) } else { SyncMode::Bsp };
        let store = Arc::new(SlowWire {
            inner: LocalKVStore::new(
                engine.clone(),
                SHARDS,
                Arc::new(Sgd::new(0.1).rescale(1.0 / SHARDS as f32)),
                consistency,
            ),
            wire: Mutex::new(()),
            delay: Duration::from_micros(400),
            slow_part: Some(SHARDS - 1),
        });
        let mut trainer = build_trainer(&engine, 2, true, sync, store);
        let small = if quick { 256 } else { 512 };
        let mut iter = dataset(small, &engine);
        let name = if bounded { "straggler bounded:2" } else { "straggler bsp" };
        let stats = b.run(name, || {
            trainer.fit(&mut iter, 1).expect("fit");
        });
        let batches = small / (SHARDS * SHARD_BATCH);
        let step_ms = stats.median_ms() / batches as f64;
        rows.push(vec![
            format!("{name}: one slow replica shard, 400us/key wire"),
            format!("{step_ms:.2} ms/step"),
            String::new(),
        ]);
        records.push(BenchRecord::from_stats(
            if bounded { "train.straggler_bounded" } else { "train.straggler_bsp" },
            "dev2x4shards+slow_part",
            2,
            &stats,
            0.0,
        ));
        straggler_ms.insert(bounded, step_ms);
    }
    let s_speedup = straggler_ms[&false] / straggler_ms[&true];
    meta.push(("straggler_bsp_ms", format!("{:.3}", straggler_ms[&false])));
    meta.push(("straggler_bounded_ms", format!("{:.3}", straggler_ms[&true])));
    meta.push(("straggler_speedup", format!("{s_speedup:.2}")));
    rows.push(vec![
        "straggler speedup (bsp/bounded step time)".into(),
        format!("{s_speedup:.2}x"),
        String::new(),
    ]);

    // ---- sharded parameter server: images/sec vs shard count ---------
    // ISSUE 10's serialized-wire curve: 400us per gradient transfer
    // through the owning shard's NIC.  One shard = every key behind one
    // wire (the straggler); 2 and 4 shards spread the keys across
    // independent wires and the per-layer pushes overlap across shards.
    let mut shard_ips: HashMap<usize, f64> = HashMap::new();
    for nsrv in [1usize, 2, 4] {
        let engine = create(EngineKind::Threaded, threads);
        let store = Arc::new(ShardWire {
            inner: LocalKVStore::new(
                engine.clone(),
                SHARDS,
                Arc::new(Sgd::new(0.1).rescale(1.0 / SHARDS as f32)),
                Consistency::Sequential,
            ),
            router: ShardRouter::new(nsrv),
            wires: (0..nsrv).map(|_| Mutex::new(())).collect(),
            delay: Duration::from_micros(400),
        });
        let mut trainer = build_trainer(&engine, 2, true, SyncMode::Bsp, store);
        let small = if quick { 256 } else { 512 };
        let mut iter = dataset(small, &engine);
        let stats = b.run(&format!("shard-wire x{nsrv}"), || {
            trainer.fit(&mut iter, 1).expect("fit");
        });
        let ips = small as f64 / stats.median_s();
        rows.push(vec![
            format!("{nsrv} server shard(s), 400us/key per-shard wire"),
            format!("{:.1} ms", stats.median_ms()),
            format!("{ips:.0} img/s"),
        ]);
        records.push(BenchRecord::from_stats(
            "train.shard_wire",
            &format!("{nsrv}shards+wire"),
            nsrv,
            &stats,
            0.0,
        ));
        shard_ips.insert(nsrv, ips);
    }
    for nsrv in [1usize, 2, 4] {
        let key: &'static str = match nsrv {
            1 => "shard_wire_ips_1",
            2 => "shard_wire_ips_2",
            _ => "shard_wire_ips_4",
        };
        meta.push((key, format!("{:.1}", shard_ips[&nsrv])));
    }
    rows.push(vec![
        "shard-wire speedup (2 shards / 1 shard)".into(),
        format!("{:.2}x", shard_ips[&2] / shard_ips[&1]),
        String::new(),
    ]);

    print_table(
        "data-parallel training (ISSUE 4)",
        &["case", "time", "throughput"],
        &rows,
    );

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_train.json".to_string());
    if let Err(e) = write_bench_json(&out, &meta, &records) {
        eprintln!("failed to write {out}: {e}");
    }
}

//! Figure 7 — internal memory under the allocation strategies, for
//! prediction (forward) and training (forward+backward), batch 64.
//!
//! Unlike the wall-time benches this is exact, not sampled: the planner
//! is deterministic.  Also reports planning *time* per graph (the
//! paper's claim that the heuristics are linear-time).
//!
//! ```text
//! cargo bench --bench fig7_memory           # table + paper deltas
//! FIG7_FULLRES=1 cargo bench --bench fig7_memory   # 224x224 inputs
//! ```

use std::time::Instant;

use mixnet::graph::autodiff::build_backward;
use mixnet::graph::memory::{default_external, plan_memory, validate_plan, AllocStrategy};
use mixnet::graph::{infer_shapes, Entry};
use mixnet::models::by_name;
use mixnet::util::bench::print_table;

fn main() {
    let batch = 64usize;
    let fullres = std::env::var("FIG7_FULLRES").is_ok();
    let models: Vec<String> = ["alexnet", "inception-bn", "vgg-11", "vgg-16"]
        .iter()
        .map(|m| if fullres { m.to_string() } else { format!("{m}@64") })
        .collect();

    for training in [false, true] {
        let title = if training { "training (fwd+bwd)" } else { "prediction (fwd)" };
        let mut rows = Vec::new();
        for name in &models {
            let m = by_name(name).unwrap();
            let (mut graph, vs) = m.graph(batch).unwrap();
            let mut extra: Vec<Entry> = vec![];
            if training {
                let wrt: Vec<_> = graph
                    .variables()
                    .into_iter()
                    .filter(|&v| {
                        let n = &graph.nodes[v].name;
                        n != "data" && !n.ends_with("_label")
                    })
                    .collect();
                let gi = build_backward(&mut graph, &wrt).unwrap();
                extra = gi.var_grads.values().copied().collect();
            }
            let shapes = infer_shapes(&graph, &vs).unwrap();
            let external = default_external(&graph, &extra);
            let mut row = vec![name.clone(), format!("{}", graph.nodes.len())];
            let mut baseline = 0.0f64;
            for strategy in AllocStrategy::all() {
                let t0 = Instant::now();
                let plan = plan_memory(&graph, &shapes, &external, strategy);
                let plan_us = t0.elapsed().as_micros();
                validate_plan(&graph, &shapes, &external, &plan).expect("plan must be sound");
                let mb = plan.bytes_mb();
                if strategy == AllocStrategy::None {
                    baseline = mb;
                }
                row.push(format!("{mb:.0} ({:.1}x, {plan_us}us)", baseline / mb.max(1e-9)));
            }
            rows.push(row);
        }
        print_table(
            &format!("Figure 7 — internal MB, batch {batch}, {title}"),
            &["network", "nodes", "none", "inplace", "co-share", "both"],
            &rows,
        );
        println!();
    }
    println!("paper: combined ~2x reduction for training, ~4x for prediction;");
    println!("planning stays linear: time scales with node count, not node count^2");
}

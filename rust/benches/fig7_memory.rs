//! Figure 7 — internal memory under the allocation strategies, for
//! prediction (forward) and training (forward+backward), batch 64 —
//! plus the ISSUE 9 `Recompute` series: planned peak bytes AND measured
//! pool peak for an MLP, AlexNet, the VGG-11 tower and a uniform-depth
//! conv tower at growing batch sizes, with and without the
//! recompute-on-backward rewrite.
//!
//! The planner tables are exact, not sampled: the planner is
//! deterministic.  The measured section actually binds and trains each
//! model through the storage pool (pool cleared + peak reset per case)
//! so the reported peak is checked-out bytes, not a plan estimate.
//!
//! ```text
//! cargo bench --bench fig7_memory           # tables + BENCH_memory.json
//! BENCH_QUICK=1 cargo bench --bench fig7_memory   # CI smoke (small cases)
//! FIG7_FULLRES=1 cargo bench --bench fig7_memory  # 224x224 planner inputs
//! BENCH_OUT=/tmp/m.json cargo bench --bench fig7_memory
//! ```
//!
//! Emits `BENCH_memory.json`: one record per measured (model, batch,
//! series) case — `median_ms` is the steady-state step time, the shape
//! string carries the measured pool peak and planned peak — plus meta:
//!
//! * `recompute_mem_ratio` / `recompute_step_overhead` for the largest
//!   uniform conv-tower case (CI gates: ratio <= 0.6, overhead <= 1.35
//!   — the sublinear O(sqrt n) claim, measured where its n-uniform-layer
//!   premise holds);
//! * `vgg_mem_ratio` / `vgg_step_overhead` for the largest VGG-11 tower
//!   case (CI gates ratio <= 0.9: pyramid nets carry an irreducible
//!   floor — stage-1's activation plus its gradient coexist during
//!   segment-1 backward, the memopt-off liveness-optimal plan is only
//!   ~2.8x that tensor, and the constant pooled conv-weight gradients
//!   dilute further — so recompute trims rather than halves).

use std::sync::Arc;
use std::time::Instant;

use mixnet::engine::{create, default_threads, EngineKind, EngineRef};
use mixnet::executor::BindConfig;
use mixnet::graph::autodiff::build_backward;
use mixnet::graph::memory::{default_external, plan_memory, validate_plan, AllocStrategy};
use mixnet::graph::recompute::{apply_recompute, segment_boundaries, MemOpt};
use mixnet::graph::{infer_shapes, Entry};
use mixnet::io::{synth, ArrayDataIter};
use mixnet::models::{by_name, conv_tower, mlp, Model};
use mixnet::module::{Module, UpdateMode};
use mixnet::ndarray::pool;
use mixnet::optimizer::Sgd;
use mixnet::util::bench::{print_table, standard_meta, write_bench_json, BenchRecord};

const MB: f64 = 1024.0 * 1024.0;

/// Planner tables: the original Figure-7 strategy sweep, with a
/// `recompute` column (planned *peak* under the rewrite, Both strategy)
/// appended to the training table.
fn planner_tables(batch: usize) {
    let fullres = std::env::var("FIG7_FULLRES").is_ok();
    let models: Vec<String> = ["alexnet", "inception-bn", "vgg-11", "vgg-16"]
        .iter()
        .map(|m| if fullres { m.to_string() } else { format!("{m}@64") })
        .collect();

    for training in [false, true] {
        let title = if training { "training (fwd+bwd)" } else { "prediction (fwd)" };
        let mut rows = Vec::new();
        for name in &models {
            let m = by_name(name).unwrap();
            let (mut graph, vs) = m.graph(batch).unwrap();
            let mut extra: Vec<Entry> = vec![];
            if training {
                let wrt: Vec<_> = graph
                    .variables()
                    .into_iter()
                    .filter(|&v| {
                        let n = &graph.nodes[v].name;
                        n != "data" && !n.ends_with("_label")
                    })
                    .collect();
                let gi = build_backward(&mut graph, &wrt).unwrap();
                extra = gi.var_grads.values().copied().collect();
            }
            let shapes = infer_shapes(&graph, &vs).unwrap();
            let external = default_external(&graph, &extra);
            let mut row = vec![name.clone(), format!("{}", graph.nodes.len())];
            let mut baseline = 0.0f64;
            let mut both_peak = 0.0f64;
            for strategy in AllocStrategy::all() {
                let t0 = Instant::now();
                let plan = plan_memory(&graph, &shapes, &external, strategy);
                let plan_us = t0.elapsed().as_micros();
                validate_plan(&graph, &shapes, &external, &plan).expect("plan must be sound");
                let mb = plan.bytes_mb();
                if strategy == AllocStrategy::None {
                    baseline = mb;
                }
                if strategy == AllocStrategy::Both {
                    both_peak = plan.peak_bytes as f64 / MB;
                }
                row.push(format!("{mb:.0} ({:.1}x, {plan_us}us)", baseline / mb.max(1e-9)));
            }
            if training {
                // Recompute series: rewrite at the default sqrt(n)
                // segmentation, re-plan, report the planned peak.
                let bounds = segment_boundaries(&graph, &shapes, 0);
                let (rg, emap, info) = apply_recompute(&graph, &shapes, &bounds).unwrap();
                let rextra: Vec<Entry> = extra.iter().map(|e| emap[e]).collect();
                let rshapes = infer_shapes(&rg, &vs).unwrap();
                let rext = default_external(&rg, &rextra);
                let rplan = plan_memory(&rg, &rshapes, &rext, AllocStrategy::Both);
                validate_plan(&rg, &rshapes, &rext, &rplan).expect("recompute plan must be sound");
                let rpeak = rplan.peak_bytes as f64 / MB;
                row.push(format!(
                    "{rpeak:.0} peak ({:.2}x of both-peak {both_peak:.0}, {} clones)",
                    rpeak / both_peak.max(1e-9),
                    info.recompute_nodes
                ));
            }
            rows.push(row);
        }
        let mut header = vec!["network", "nodes", "none", "inplace", "co-share", "both"];
        if training {
            header.push("recompute");
        }
        print_table(&format!("Figure 7 — internal MB, batch {batch}, {title}"), &header, &rows);
        println!();
    }
    println!("paper: combined ~2x reduction for training, ~4x for prediction;");
    println!("recompute trades one extra forward segment pass for sublinear activation memory");
    println!();
}

/// One measured training case: clear the pool, bind, train a couple of
/// short epochs, and report (pool peak bytes, planned peak bytes,
/// steady-state step ms).
fn measured_case(
    engine: &EngineRef,
    model: Model,
    batch: usize,
    memopt: MemOpt,
    steps: usize,
) -> (u64, usize, f64) {
    pool::global().clear();
    pool::global().reset_peak();
    let feat_shape = model.feat_shape.clone();
    let classes = model.num_classes;
    let shapes = model.param_shapes(batch).expect("shapes");
    let mut module = Module::new(model.symbol, engine.clone());
    let cfg = BindConfig { memopt, ..Default::default() };
    module.bind(batch, &feat_shape, &shapes, cfg, 42).expect("bind");
    let planned = module.executor().expect("bound").planned_peak_bytes();

    let n = batch * steps;
    let ds = if feat_shape.len() == 1 {
        synth::class_clusters(n, classes, feat_shape[0], 0.3, 11)
    } else {
        synth::images(n, classes, feat_shape[0], feat_shape[1], feat_shape[2], 0.3, 11)
    };
    let mut iter =
        ArrayDataIter::new(ds.features, ds.labels, &feat_shape, batch, false, engine.clone());
    // Epoch 1 warms the pool (misses + JIT-ish first-touch); epoch 2 is
    // the steady-state timing sample.
    let stats = module
        .fit(&mut iter, &UpdateMode::Local(Arc::new(Sgd::new(0.05))), 2)
        .expect("fit");
    let last = stats.last().expect("epoch stats");
    let step_ms = last.seconds / last.batches.max(1) as f64 * 1e3;
    let peak = pool::global().stats().peak_bytes;
    (peak, planned, step_ms)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    planner_tables(64);

    // Measured section: MLP, AlexNet, the VGG-11 tower and the uniform
    // conv tower at growing batch sizes, memopt off vs recompute.  Small
    // spatial inputs keep the bench inside CI budgets; both towers still
    // make activations dominate the pooled footprint.
    let cases: Vec<(&str, Vec<usize>)> = if quick {
        vec![
            ("mlp", vec![64]),
            ("alexnet@64", vec![16]),
            ("vgg11-tower@64", vec![32]),
            ("conv-tower", vec![8]),
        ]
    } else {
        vec![
            ("mlp", vec![64, 256]),
            ("alexnet@64", vec![32, 64]),
            ("vgg11-tower@64", vec![16, 32, 64]),
            ("conv-tower", vec![8, 16]),
        ]
    };
    let steps = if quick { 3 } else { 4 };
    let engine = create(EngineKind::Threaded, default_threads());

    let mut records = Vec::new();
    let mut rows = Vec::new();
    // (pool peak off, pool peak rc, step off, step rc) per gated model =
    // its largest measured batch.  The 0.6 sublinear gate rides on the
    // uniform conv tower; the VGG-11 tower gets the pyramid-floor bound.
    let mut gate: Option<(u64, u64, f64, f64)> = None;
    let mut gate_case = String::new();
    let mut vgg: Option<(u64, u64, f64, f64)> = None;
    let mut vgg_case = String::new();
    for (name, batches) in &cases {
        for &batch in batches {
            let build = |spec: &str| -> Model {
                match spec {
                    "mlp" => mlp(&[512, 256], 784, 10),
                    // Deep enough that sqrt(n) segmentation leaves the
                    // per-segment live set far below the n-layer total.
                    "conv-tower" => conv_tower(24, 64, 10, 32),
                    _ => by_name(spec).unwrap(),
                }
            };
            let (peak_off, planned_off, ms_off) =
                measured_case(&engine, build(name), batch, MemOpt::Off, steps);
            let rc = MemOpt::Recompute { segments: 0 };
            let (peak_rc, planned_rc, ms_rc) =
                measured_case(&engine, build(name), batch, rc, steps);
            for (series, peak, planned, ms) in [
                ("off", peak_off, planned_off, ms_off),
                ("recompute", peak_rc, planned_rc, ms_rc),
            ] {
                records.push(BenchRecord {
                    op: format!("fig7/{name}/{series}"),
                    shape: format!(
                        "b{batch} pool_peak={:.1}mb planned_peak={:.1}mb",
                        peak as f64 / MB,
                        planned as f64 / MB
                    ),
                    threads: default_threads(),
                    median_ms: ms,
                    gflops: 0.0,
                });
            }
            rows.push(vec![
                format!("{name} b{batch}"),
                format!("{:.1}", peak_off as f64 / MB),
                format!("{:.1}", peak_rc as f64 / MB),
                format!("{:.2}x", peak_rc as f64 / (peak_off as f64).max(1.0)),
                format!("{:.1}", planned_off as f64 / MB),
                format!("{:.1}", planned_rc as f64 / MB),
                format!("{:.2}x", ms_rc / ms_off.max(1e-9)),
            ]);
            if *name == "conv-tower" {
                gate = Some((peak_off, peak_rc, ms_off, ms_rc));
                gate_case = format!("{name} b{batch}");
            } else if name.starts_with("vgg11-tower") {
                vgg = Some((peak_off, peak_rc, ms_off, ms_rc));
                vgg_case = format!("{name} b{batch}");
            }
        }
    }
    print_table(
        "Measured pool peak (MB) & step overhead, memopt off vs recompute",
        &["case", "pool off", "pool rc", "ratio", "plan off", "plan rc", "step overhead"],
        &rows,
    );

    let mut meta = standard_meta("memory", quick);
    if let Some((po, pr, so, sr)) = gate {
        let mem_ratio = pr as f64 / (po as f64).max(1.0);
        let overhead = sr / so.max(1e-9);
        meta.push(("gate_case", gate_case.clone()));
        meta.push(("recompute_mem_ratio", format!("{mem_ratio:.3}")));
        meta.push(("recompute_step_overhead", format!("{overhead:.3}")));
        println!();
        println!(
            "gate [{gate_case}]: recompute_mem_ratio={mem_ratio:.3} (<= 0.6 expected), \
             recompute_step_overhead={overhead:.3} (<= 1.35 expected)"
        );
    }
    if let Some((po, pr, so, sr)) = vgg {
        let mem_ratio = pr as f64 / (po as f64).max(1.0);
        let overhead = sr / so.max(1e-9);
        meta.push(("vgg_case", vgg_case.clone()));
        meta.push(("vgg_mem_ratio", format!("{mem_ratio:.3}")));
        meta.push(("vgg_step_overhead", format!("{overhead:.3}")));
        println!(
            "bound [{vgg_case}]: vgg_mem_ratio={mem_ratio:.3} (<= 0.9 expected; \
             pyramid stage-1 floor), vgg_step_overhead={overhead:.3}"
        );
    }
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_memory.json".to_string());
    write_bench_json(&out, &meta, &records).expect("write bench json");
    eprintln!("wrote {out}");
}

//! Figure 8 — distributed scalability: time per data pass and accuracy
//! per pass, 1 vs 10 machines, GoogLeNet-BN on an ILSVRC12-sized corpus.
//!
//! Three stages (DESIGN E3):
//!  1. *Measure* a real fwd+bwd on this host to calibrate the simulator's
//!     compute rate (FLOPs of the measured graph / measured seconds).
//!  2. *Validate* the real two-level-PS code path at small scale (threads
//!     as machines over local TCP), reporting measured wall times.
//!  3. *Replay* the paper's configuration in virtual time.
//!
//! ```text
//! cargo bench --bench fig8_scalability
//! ```

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::{BindConfig, Executor};
use mixnet::graph::infer_shapes;
use mixnet::io::{synth::class_clusters, ArrayDataIter};
use mixnet::kvstore::server::{PsServer, ServerUpdater};
use mixnet::kvstore::{dist::DistKVStore, Consistency};
use mixnet::models::{by_name, mlp};
use mixnet::module::{Module, UpdateMode};
use mixnet::ndarray::NDArray;
use mixnet::sim::{graph_flops, simulate, ClusterConfig, CostModel};
use mixnet::util::bench::print_table;

/// Stage 1: measured compute rate from a real simple-cnn fwd+bwd.
fn calibrate() -> (f64, f64) {
    let m = by_name("simple-cnn").unwrap();
    let batch = 16;
    let engine = create(EngineKind::Threaded, mixnet::engine::default_threads());
    let var_shapes = m.var_shapes(batch).unwrap();
    let mut seed = 1u64;
    let args: HashMap<String, NDArray> = var_shapes
        .iter()
        .map(|(n, s)| {
            seed += 1;
            let a = if n.ends_with("_label") {
                NDArray::from_vec_on(s, vec![0.0; batch], engine.clone())
            } else {
                NDArray::randn_on(s, 0.0, 0.1, seed, engine.clone())
            };
            (n.clone(), a)
        })
        .collect();
    let grads: Vec<&str> = var_shapes
        .keys()
        .filter(|n| *n != "data" && !n.ends_with("_label"))
        .map(|s| s.as_str())
        .collect();
    let exec = Executor::bind_graph(
        mixnet::symbol::Symbol::to_graph(std::slice::from_ref(&m.symbol)),
        engine,
        args,
        &grads,
        BindConfig::default(),
    )
    .unwrap();
    exec.forward_backward().unwrap();
    exec.wait(); // warm
    let iters = 5;
    let t0 = Instant::now();
    for _ in 0..iters {
        exec.forward_backward().unwrap();
        exec.wait();
    }
    let secs = t0.elapsed().as_secs_f64() / iters as f64;
    let flops = graph_flops(exec.graph(), exec.shapes());
    (flops, secs)
}

/// Stage 2: real two-level PS at small scale; returns wall seconds.
fn real_distributed(machines: usize, epochs: usize) -> f64 {
    const DIM: usize = 32;
    let updater = ServerUpdater {
        lr: 0.4 / machines as f32,
        momentum: 0.9,
        weight_decay: 1e-4,
        rescale: 1.0,
    };
    let mut server = PsServer::start(0, machines, updater).unwrap();
    let addr = server.addr();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..machines as u32)
        .map(|mid| {
            std::thread::spawn(move || {
                let engine = create(EngineKind::Threaded, 2);
                let kv = Arc::new(
                    DistKVStore::connect(addr, mid, 1, Consistency::Sequential, engine.clone())
                        .unwrap(),
                );
                let ds = class_clusters(512, 4, DIM, 0.3, 100 + mid as u64);
                let mut iter =
                    ArrayDataIter::new(ds.features, ds.labels, &[DIM], 32, true, engine.clone());
                let model = mlp(&[64], DIM, 4);
                let shapes = model.param_shapes(32).unwrap();
                let mut module = Module::new(model.symbol, engine);
                module.bind(32, &[DIM], &shapes, BindConfig::default(), 7).unwrap();
                module
                    .fit(&mut iter, &UpdateMode::KvStore { store: kv, device: 0 }, epochs)
                    .unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown();
    wall
}

fn main() {
    // ---- stage 1: calibration --------------------------------------
    let (flops, secs) = calibrate();
    let rate = flops / secs;
    println!(
        "calibration: simple-cnn fwd+bwd {:.2} MFLOP in {:.1} ms -> {:.2} GFLOP/s/core\n",
        flops / 1e6,
        secs * 1e3,
        rate / 1e9
    );

    // ---- stage 2: real small-scale distributed path ----------------
    let mut rows = Vec::new();
    for machines in [1usize, 2, 4] {
        let wall = real_distributed(machines, 2);
        rows.push(vec![machines.to_string(), format!("{wall:.2}")]);
    }
    print_table(
        "real two-level PS (threads as machines, local TCP; correctness path)",
        &["machines", "wall s (2 epochs)"],
        &rows,
    );
    println!("(one physical core: no wall-time speedup expected locally — the\n scalability CURVES come from the virtual-time replay below)\n");

    // ---- stage 3: virtual-time paper replay -------------------------
    let inception = by_name("inception-bn").unwrap();
    let (g, vs) = inception.graph(1).unwrap();
    let shapes = infer_shapes(&g, &vs).unwrap();
    let fwd = graph_flops(&g, &shapes);
    let flops_per_image = 3.0 * fwd;
    let grad_bytes = inception.num_params().unwrap() as f64 * 4.0;

    let mut rows = Vec::new();
    let mut curves: Vec<(usize, Vec<f64>)> = Vec::new();
    for machines in [1usize, 10] {
        // paper hardware rates; the GK104 sustained rate is the default
        // CostModel documented against published convnet throughput.
        let mut cfg = ClusterConfig::googlenet_paper(machines, flops_per_image, grad_bytes);
        cfg.cost = CostModel::default();
        cfg.passes = 15;
        let stats = simulate(&cfg);
        rows.push(vec![
            machines.to_string(),
            format!("{:.0}", stats[0].seconds),
            format!("{:.0}", stats.last().unwrap().cumulative_seconds),
            format!("{:.3}", stats.last().unwrap().accuracy),
            format!("{:.1}", stats[0].staleness),
        ]);
        curves.push((machines, stats.iter().map(|s| s.accuracy).collect()));
    }
    print_table(
        "Figure 8 (virtual time) — GoogLeNet-BN, ILSVRC12-size, batch 36/GPU",
        &["machines", "s/pass", "total s (15 passes)", "final acc", "staleness"],
        &rows,
    );
    println!("\naccuracy by pass (paper: dist slower early, crosses over ~pass 10):");
    print!("pass:      ");
    for p in 1..=15 {
        print!("{p:>6}");
    }
    println!();
    for (machines, curve) in &curves {
        print!("{machines:>2} machine ");
        for a in curve {
            print!("{a:>6.3}");
        }
        println!();
    }
    let s1 = &curves[0].1;
    let s10 = &curves[1].1;
    let cross = (0..15).find(|&i| s10[i] > s1[i]);
    println!(
        "\ncrossover at pass {:?} (paper: ~10); speedup {:.1}x (paper: 14K/1.4K = 10x)",
        cross.map(|i| i + 1),
        {
            let r1: f64 = rows[0][1].parse().unwrap();
            let r10: f64 = rows[1][1].parse().unwrap();
            r1 / r10
        }
    );
}

//! §2.3 claim (DESIGN E5): KVStore pull/push scheduled by the engine
//! overlap with compute, so the mixed data-parallel loop costs the same
//! as a hand-fused one; a barrier-synchronized store does not.
//!
//! One worker trains the Figure 2 MLP through a `LocalKVStore` whose
//! updater runs artificial "network latency" per merge (simulating the
//! level-2 hop).  Variants:
//!  * `overlapped` — paper loop: pull; forward_backward; push — all
//!    engine ops, comm hides under compute.
//!  * `barrier` — flush() after every pull and push (lock-step).
//!
//! ```text
//! cargo bench --bench kvstore_overlap
//! ```

use std::sync::Arc;
use std::time::Duration;

use mixnet::engine::{create, EngineKind};
use mixnet::executor::{BindConfig, Executor};
use mixnet::kvstore::{Consistency, KVStore, LocalKVStore};
use mixnet::models::mlp;
use mixnet::ndarray::NDArray;
use mixnet::optimizer::{Optimizer, Sgd};
use mixnet::util::bench::{print_table, Bencher};

const BATCH: usize = 64;
const DIM: usize = 256;
const CLASSES: usize = 16;

/// SGD updater that sleeps first: a stand-in for level-2 wire time.
struct SlowSgd {
    inner: Sgd,
    delay: Duration,
}

impl Optimizer for SlowSgd {
    fn update(&self, key: &str, weight: &NDArray, grad: &NDArray) {
        std::thread::sleep(self.delay);
        self.inner.update(key, weight, grad);
    }
    fn learning_rate(&self) -> f32 {
        self.inner.learning_rate()
    }
    fn set_learning_rate(&self, lr: f32) {
        self.inner.set_learning_rate(lr);
    }
}

fn setup(engine: &mixnet::engine::EngineRef) -> (Executor, Vec<String>) {
    let model = mlp(&[512], DIM, CLASSES);
    let shapes = model.var_shapes(BATCH).unwrap();
    let mut seed = 5u64;
    let args: std::collections::HashMap<String, NDArray> = shapes
        .iter()
        .map(|(n, s)| {
            seed += 1;
            let a = if n.ends_with("_label") {
                NDArray::from_vec_on(
                    s,
                    (0..BATCH).map(|i| (i % CLASSES) as f32).collect(),
                    engine.clone(),
                )
            } else {
                NDArray::randn_on(s, 0.0, 0.05, seed, engine.clone())
            };
            (n.clone(), a)
        })
        .collect();
    let params: Vec<String> = shapes
        .keys()
        .filter(|n| *n != "data" && !n.ends_with("_label"))
        .cloned()
        .collect();
    let grad_refs: Vec<&str> = params.iter().map(|s| s.as_str()).collect();
    let exec = Executor::bind(
        &model.symbol,
        engine.clone(),
        args,
        &grad_refs,
        BindConfig::default(),
    )
    .unwrap();
    (exec, params)
}

fn main() {
    let delay = Duration::from_micros(1500); // per-key merge latency (>> 1-core scheduling noise)
    let b = Bencher { warmup: 3, samples: 25, max_total: Duration::from_secs(40) };
    let threads = mixnet::engine::default_threads().max(4);
    let mut rows = Vec::new();
    let mut base = 0.0f64;

    for (name, barrier) in [("overlapped (paper)", false), ("barrier-synchronized", true)] {
        let engine = create(EngineKind::Threaded, threads);
        let (exec, params) = setup(&engine);
        let kv = LocalKVStore::new(
            engine.clone(),
            1,
            Arc::new(SlowSgd { inner: Sgd::new(0.01), delay }),
            Consistency::Sequential,
        );
        for p in &params {
            kv.init(p, exec.arg(p).unwrap()).unwrap();
        }
        let stats = b.run(name, || {
            for p in &params {
                kv.pull(p, exec.arg(p).unwrap(), 0).unwrap();
                if barrier {
                    kv.flush();
                }
            }
            exec.forward_backward().unwrap();
            for p in &params {
                kv.push(p, exec.grad(p).unwrap(), 0).unwrap();
                if barrier {
                    kv.flush();
                }
            }
            kv.flush();
        });
        let ms = stats.median_ms();
        if base == 0.0 {
            base = ms;
        }
        rows.push(vec![name.into(), format!("{ms:.3}"), format!("{:.2}x", ms / base)]);
    }
    print_table(
        "E5 — data-parallel step, 1.5ms simulated wire latency per key merge",
        &["variant", "median ms", "vs overlapped"],
        &rows,
    );
    println!("\npaper claim: engine-scheduled KVStore ops hide under compute;");
    println!("barrier-synchronized stores pay the full wire latency serially");
}

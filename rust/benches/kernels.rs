//! Kernel-level before/after benchmark for the blocked, intra-op-parallel
//! GEMM backend, emitting machine-readable `BENCH_kernels.json`.
//!
//! Cases:
//! * `gemm-ikj-seed`    — the seed generation's single-threaded i-k-j loop
//!                        (the "before" baseline)
//! * `gemm-reference`   — the deliberately slow j-i-p reference kernel
//! * `gemm@T`           — the blocked/packed kernel pinned to T intra-op
//!                        threads (T = 1 shows pure blocking gains;
//!                        higher T shows intra-op scaling)
//! * `gemm_nt@T` / `gemm_tn@T` — transpose variants at the FC shapes
//! * `conv2d@T`         — batched im2col convolution forward
//!
//! Acceptance targets (ISSUE 1): blocked 1-thread >= 2x `gemm-ikj-seed`
//! at 512x512x512, and 4-thread >= 2.5x over 1-thread.
//!
//! ```text
//! cargo bench --bench kernels                # full sweep + JSON
//! BENCH_QUICK=1 cargo bench --bench kernels  # CI smoke (fewer samples)
//! BENCH_OUT=/tmp/k.json cargo bench --bench kernels
//! ```

use mixnet::ndarray::kernels as k;
use mixnet::util::bench::{print_table, standard_meta, write_bench_json, BenchRecord, Bencher};
use mixnet::util::{intra_pool, with_intra_budget, Rng};

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let b = if quick {
        Bencher { warmup: 1, samples: 3, max_total: std::time::Duration::from_secs(8) }
    } else {
        Bencher { warmup: 2, samples: 7, max_total: std::time::Duration::from_secs(25) }
    };
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rows = Vec::new();
    let pool_threads = intra_pool().threads();
    // Pinned thread counts to sweep (dedup keeps the table tidy on small
    // hosts); 0 threads available never happens (pool clamps to 1).
    let mut sweeps = vec![1usize, 2, 4, pool_threads];
    sweeps.sort_unstable();
    sweeps.dedup();
    sweeps.retain(|&t| t <= pool_threads);

    // ---- square GEMM: the acceptance-criteria shape ------------------
    let (m, kk, n) = (512, 512, 512);
    let flops = 2.0 * (m * kk * n) as f64;
    let a = randv(m * kk, 1);
    let bb = randv(kk * n, 2);
    let mut c = vec![0.0f32; m * n];
    let shape = format!("{m}x{kk}x{n}");

    let stats = b.run("gemm-ikj-seed", || k::gemm_ikj(&a, &bb, &mut c, m, kk, n, 0.0));
    let seed_ms = stats.median_ms();
    records.push(BenchRecord::from_stats("gemm-ikj-seed", &shape, 1, &stats, flops));
    rows.push(vec!["gemm-ikj-seed".into(), shape.clone(), "1".into(), format!("{seed_ms:.1} ms")]);

    let stats = b.run("gemm-reference", || {
        k::gemm_reference(&a, &bb, &mut c, m, kk, n, 0.0, false, false)
    });
    records.push(BenchRecord::from_stats("gemm-reference", &shape, 1, &stats, flops));
    rows.push(vec![
        "gemm-reference".into(),
        shape.clone(),
        "1".into(),
        format!("{:.1} ms", stats.median_ms()),
    ]);

    let mut blocked_1t_ms = f64::NAN;
    for &t in &sweeps {
        let stats = with_intra_budget(t, || {
            b.run(&format!("gemm@{t}"), || k::gemm(&a, &bb, &mut c, m, kk, n, 0.0))
        });
        if t == 1 {
            blocked_1t_ms = stats.median_ms();
        }
        records.push(BenchRecord::from_stats("gemm", &shape, t, &stats, flops));
        rows.push(vec![
            "gemm-blocked".into(),
            shape.clone(),
            format!("{t}"),
            format!(
                "{:.1} ms ({:.2}x seed, {:.2}x 1t)",
                stats.median_ms(),
                seed_ms / stats.median_ms(),
                blocked_1t_ms / stats.median_ms()
            ),
        ]);
    }

    // ---- transpose variants at FC-training shapes --------------------
    for (name, tm, tk, tn) in
        [("gemm_nt", 256usize, 1024usize, 256usize), ("gemm_tn", 256, 1024, 256)]
    {
        let vflops = 2.0 * (tm * tk * tn) as f64;
        let vshape = format!("{tm}x{tk}x{tn}");
        let (x, w) = (randv(tm * tk, 3), randv(tn * tk, 4));
        let mut y = vec![0.0f32; tm * tn];
        for &t in &sweeps {
            let stats = with_intra_budget(t, || {
                b.run(&format!("{name}@{t}"), || {
                    if name == "gemm_nt" {
                        k::gemm_nt(&x, &w, &mut y, tm, tk, tn, 0.0);
                    } else {
                        // a^T is [k,m]: reuse x as [tk, tm] layout
                        k::gemm_tn(&x, &w[..tk * tn], &mut y, tm, tk, tn, 0.0);
                    }
                })
            });
            records.push(BenchRecord::from_stats(name, &vshape, t, &stats, vflops));
        }
    }

    // ---- batched conv forward (fig6's hot op) ------------------------
    let (cn, cc, ch, cw, cf, ck) = (16, 16, 32, 32, 32, 3);
    let (oh, ow) = (k::conv_out(ch, ck, 1, 1), k::conv_out(cw, ck, 1, 1));
    let cflops = 2.0 * (cn * cf * oh * ow * cc * ck * ck) as f64;
    let cshape = format!("{cn}x{cc}x{ch}x{cw}-f{cf}k{ck}");
    let x = randv(cn * cc * ch * cw, 5);
    let wt = randv(cf * cc * ck * ck, 6);
    let bias = randv(cf, 7);
    let mut y = vec![0.0f32; cn * cf * oh * ow];
    for &t in &sweeps {
        let stats = with_intra_budget(t, || {
            b.run(&format!("conv2d@{t}"), || {
                k::conv2d_forward(&x, &wt, &bias, &mut y, cn, cc, ch, cw, cf, ck, 1, 1);
            })
        });
        records.push(BenchRecord::from_stats("conv2d", &cshape, t, &stats, cflops));
    }

    print_table(
        "kernel benchmarks (see BENCH_kernels.json for the full sweep)",
        &["case", "shape", "threads", "result"],
        &rows,
    );

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    let mut meta = standard_meta("kernels", quick);
    meta.extend([
        ("pool_threads", pool_threads.to_string()),
        (
            "note",
            "blocked GEMM vs seed i-k-j baseline; threads = pinned intra-op budget".to_string(),
        ),
    ]);
    if let Err(e) = write_bench_json(&out, &meta, &records) {
        eprintln!("failed to write {out}: {e}");
    }
}

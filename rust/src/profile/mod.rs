//! Process-wide tracing and profiling: near-zero overhead when off.
//!
//! The dependency engine's whole pitch is keeping heterogeneous
//! resources saturated; this layer makes that *visible*. Every
//! instrumented site pays exactly one relaxed atomic load when
//! profiling is disabled (see [`SpanTimer::start`]); when enabled,
//! completed spans go into per-thread lock-free ring buffers
//! ([`SpanRecorder`]) so the hot path takes no locks and touches no
//! shared cache lines beyond its own ring.
//!
//! Span taxonomy (the `cat` field in the chrome trace):
//!
//! - `engine`  — dynamically pushed engine ops (schedule→dispatch→
//!   complete; `queue_us` is the time between push and dispatch)
//! - `plan`    — compiled [`RunPlan`](crate::engine::RunPlan) replay
//!   ops (`a` = replay step, `b` = op index within the plan)
//! - `kernel`  — BLAS-level regions (GEMM variants, conv2d fwd/bwd)
//! - `kv_client` — one client RPC incl. every retry/redial (`a` =
//!   attempts taken)
//! - `kv_server` — one server-side optimizer round application
//! - `serve`   — batch lifecycle: queue-wait, scatter, forward, gather
//! - `io`      — data-iterator prefetch waits
//!
//! Lifecycle: [`set_enabled`]`(true)` → run the workload → quiesce
//! (e.g. `engine.wait_all()`) → [`set_enabled`]`(false)` → [`drain`] →
//! [`chrome_trace`] / [`MetricsSnapshot::collect`]. Draining while
//! producers are still recording is memory-safe (only committed spans
//! are read) but may miss in-flight spans.

pub mod json;

use std::cell::{OnceCell, UnsafeCell};
use std::collections::HashMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::kvstore::dist::{ClientStats, ServerStats};
use crate::kvstore::PullStats;
use crate::ndarray::pool::PoolStats;
use crate::serve::ServeStats;
use json::{escape, Json};

/// Default per-thread span-ring capacity (`PALLAS_PROFILE_CAP` overrides).
pub const DEFAULT_RING_CAP: usize = 65_536;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the (lazily initialized) process trace epoch.
#[inline]
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Is span recording currently on? One relaxed load — this is the
/// entire disabled-path cost at an instrumented site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on or off (also pins the trace epoch on first
/// enable so timestamps are small).
pub fn set_enabled(on: bool) {
    if on {
        epoch();
    }
    ENABLED.store(on, Ordering::SeqCst);
}

/// Trace output path from the `PALLAS_PROFILE` knob (unset, empty or
/// `0` mean disabled).
pub fn env_trace_path() -> Option<String> {
    match std::env::var("PALLAS_PROFILE") {
        Ok(v) if !v.is_empty() && v != "0" => Some(v),
        _ => None,
    }
}

fn ring_cap() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("PALLAS_PROFILE_CAP")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(DEFAULT_RING_CAP)
    })
}

/// What subsystem a span came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Category {
    Engine,
    Plan,
    Kernel,
    KvClient,
    KvServer,
    Serve,
    Io,
}

impl Category {
    pub fn as_str(self) -> &'static str {
        match self {
            Category::Engine => "engine",
            Category::Plan => "plan",
            Category::Kernel => "kernel",
            Category::KvClient => "kv_client",
            Category::KvServer => "kv_server",
            Category::Serve => "serve",
            Category::Io => "io",
        }
    }
}

/// One completed region. `a`/`b` are span-kind-specific payloads (cost
/// hint, RPC attempts, replay step / op index, batch size — see the
/// module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub cat: Category,
    pub name: &'static str,
    pub start_us: u64,
    pub dur_us: u64,
    /// Wait before the work started (push→dispatch for engine ops,
    /// enqueue→dispatch for serve batches); 0 where not applicable.
    pub queue_us: u64,
    /// Recorder thread id (chrome-trace lane). Assigned per thread at
    /// first record; engine worker threads therefore get stable lanes.
    pub tid: u32,
    pub a: u64,
    pub b: u64,
}

const EMPTY_SPAN: Span = Span {
    cat: Category::Engine,
    name: "",
    start_us: 0,
    dur_us: 0,
    queue_us: 0,
    tid: 0,
    a: 0,
    b: 0,
};

/// A single-producer span ring. The owning thread appends; [`drain`]
/// reads the committed prefix from any thread. `len` is the commit
/// marker: the slot is fully written before the release store, so an
/// acquire load on the reader side never observes a torn span.
pub struct SpanRecorder {
    slots: Box<[UnsafeCell<Span>]>,
    len: AtomicUsize,
    dropped: AtomicU64,
    tid: u32,
}

// SAFETY: only the owning thread writes `slots`, and only at indexes
// >= the committed `len`; readers only dereference indexes < `len`
// (Acquire), which the Release store in `push` has fully initialized.
unsafe impl Sync for SpanRecorder {}
unsafe impl Send for SpanRecorder {}

impl SpanRecorder {
    fn new(cap: usize, tid: u32) -> Self {
        let slots: Vec<UnsafeCell<Span>> = (0..cap).map(|_| UnsafeCell::new(EMPTY_SPAN)).collect();
        SpanRecorder {
            slots: slots.into_boxed_slice(),
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
            tid,
        }
    }

    #[inline]
    fn push(&self, mut span: Span) {
        let i = self.len.load(Ordering::Relaxed);
        if i >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        span.tid = self.tid;
        // SAFETY: single producer; slot `i` is not yet committed, so no
        // reader dereferences it until the release store below.
        unsafe { *self.slots[i].get() = span };
        self.len.store(i + 1, Ordering::Release);
    }
}

fn registry() -> &'static Mutex<Vec<Arc<SpanRecorder>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<SpanRecorder>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static RECORDER: OnceCell<Arc<SpanRecorder>> = const { OnceCell::new() };
}

fn with_recorder(f: impl FnOnce(&SpanRecorder)) {
    RECORDER.with(|cell| {
        let rec = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let rec = Arc::new(SpanRecorder::new(ring_cap(), tid));
            registry().lock().unwrap().push(rec.clone());
            rec
        });
        f(rec);
    });
}

/// The calling thread's trace lane id (registers the thread's ring on
/// first use). Doubles as the "worker id" in engine spans.
pub fn current_tid() -> u32 {
    let mut tid = 0;
    with_recorder(|r| tid = r.tid);
    tid
}

/// Record one completed span ending now (timestamps from [`now_us`]).
#[inline]
pub fn record(cat: Category, name: &'static str, start_us: u64, queue_us: u64, a: u64, b: u64) {
    let end = now_us();
    let span = Span {
        cat,
        name,
        start_us,
        dur_us: end.saturating_sub(start_us),
        queue_us,
        tid: 0,
        a,
        b,
    };
    with_recorder(|r| r.push(span));
}

/// Capture-once span helper: checks [`enabled`] exactly once at
/// construction (the disabled path's single atomic load) and records on
/// [`finish`](SpanTimer::finish) only if profiling was on at the start.
#[derive(Debug, Clone, Copy)]
pub struct SpanTimer {
    start_us: u64,
    on: bool,
}

impl SpanTimer {
    #[inline]
    pub fn start() -> Self {
        let on = enabled();
        SpanTimer { start_us: if on { now_us() } else { 0 }, on }
    }

    /// Whether this timer will record (profiling was on at start).
    #[inline]
    pub fn on(&self) -> bool {
        self.on
    }

    /// Start timestamp (0 when not recording).
    #[inline]
    pub fn start_us(&self) -> u64 {
        self.start_us
    }

    #[inline]
    pub fn finish(self, cat: Category, name: &'static str, queue_us: u64, a: u64, b: u64) {
        if self.on {
            record(cat, name, self.start_us, queue_us, a, b);
        }
    }
}

/// Move every committed span out of every registered ring (sorted by
/// thread, then start time) and reset the rings. Call only after the
/// workload has quiesced; concurrent producers are memory-safe but
/// their in-flight spans may land in the next drain.
pub fn drain() -> Vec<Span> {
    let regs = registry().lock().unwrap();
    let mut out = Vec::new();
    for rec in regs.iter() {
        let n = rec.len.load(Ordering::Acquire).min(rec.slots.len());
        for slot in rec.slots.iter().take(n) {
            // SAFETY: indexes < the acquired `len` are committed and no
            // longer written by the producer.
            out.push(unsafe { *slot.get() });
        }
        rec.len.store(0, Ordering::Release);
    }
    out.sort_by_key(|s| (s.tid, s.start_us, s.start_us + s.dur_us));
    out
}

/// Spans lost to ring overflow since the last [`reset`].
pub fn dropped() -> u64 {
    registry().lock().unwrap().iter().map(|r| r.dropped.load(Ordering::Relaxed)).sum()
}

/// Discard all recorded spans and overflow counts (tests / phase reuse).
pub fn reset() {
    let regs = registry().lock().unwrap();
    for rec in regs.iter() {
        rec.len.store(0, Ordering::Release);
        rec.dropped.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Exporter 1: chrome://tracing JSON
// ---------------------------------------------------------------------------

/// Render spans as a chrome://tracing / Perfetto "trace event" document
/// (complete events, `ph:"X"`; `ts`/`dur` in microseconds).
pub fn chrome_trace(spans: &[Span]) -> String {
    let mut out = String::with_capacity(64 + spans.len() * 96);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"ph\":\"X\",\"name\":\"{}\",\"cat\":\"{}\",\"pid\":1,\"tid\":{},\"ts\":{},\
             \"dur\":{},\"args\":{{\"queue_us\":{},\"a\":{},\"b\":{}}}}}",
            escape(s.name),
            s.cat.as_str(),
            s.tid,
            s.start_us,
            s.dur_us,
            s.queue_us,
            s.a,
            s.b
        );
    }
    out.push_str("]}");
    out
}

/// Write [`chrome_trace`] output to a file.
pub fn write_chrome_trace(path: &str, spans: &[Span]) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace(spans))
}

// ---------------------------------------------------------------------------
// Exporter 2: aggregated per-op table + unified MetricsSnapshot
// ---------------------------------------------------------------------------

/// Per-op aggregate over one drained trace.
#[derive(Debug, Clone, PartialEq)]
pub struct OpAgg {
    pub cat: String,
    pub name: String,
    pub count: u64,
    pub total_us: u64,
    pub mean_us: f64,
    pub p95_us: u64,
    /// Total scheduling/queue wait attributed to this op.
    pub queue_us: u64,
}

/// Group spans by (category, name); sorted by descending total time.
pub fn aggregate(spans: &[Span]) -> Vec<OpAgg> {
    let mut groups: HashMap<(Category, &'static str), Vec<&Span>> = HashMap::new();
    for s in spans {
        groups.entry((s.cat, s.name)).or_default().push(s);
    }
    let mut out: Vec<OpAgg> = groups
        .into_iter()
        .map(|((cat, name), ss)| {
            let count = ss.len() as u64;
            let total_us: u64 = ss.iter().map(|s| s.dur_us).sum();
            let queue_us: u64 = ss.iter().map(|s| s.queue_us).sum();
            let mut durs: Vec<u64> = ss.iter().map(|s| s.dur_us).collect();
            durs.sort_unstable();
            let rank = ((0.95 * durs.len() as f64).ceil() as usize).clamp(1, durs.len());
            OpAgg {
                cat: cat.as_str().to_string(),
                name: name.to_string(),
                count,
                total_us,
                mean_us: total_us as f64 / count as f64,
                p95_us: durs[rank - 1],
                queue_us,
            }
        })
        .collect();
    out.sort_by(|x, y| y.total_us.cmp(&x.total_us).then_with(|| x.name.cmp(&y.name)));
    out
}

/// Aggregated histogram line carried by the snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct HistAgg {
    pub name: String,
    pub count: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
}

/// One JSON artifact answering "where did the time go" — unifies the
/// span aggregates with `metrics.rs` counters/timers/histograms, the
/// storage-pool counters, and (when present) kvstore pull stats, serve
/// stats, and dist client/server stats.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Snapshot schema version (bump on breaking field changes).
    pub schema: u64,
    /// Wall-clock span of the profiled window, microseconds.
    pub wall_us: u64,
    /// Distinct threads that executed engine/plan/kernel work.
    pub workers: u64,
    /// Total engine+plan execution time across workers, microseconds.
    pub busy_us: u64,
    /// Total engine-op queue wait, microseconds.
    pub queue_us: u64,
    /// busy / (wall × workers) — how saturated the worker pool was.
    pub utilization: f64,
    /// queue / (queue + busy) — share of op lifetime spent waiting.
    pub queue_share: f64,
    /// Spans lost to ring overflow (0 means the trace is complete).
    pub dropped_spans: u64,
    pub ops: Vec<OpAgg>,
    pub counters: Vec<(String, u64)>,
    pub timers_s: Vec<(String, f64)>,
    pub hists: Vec<HistAgg>,
    pub pool: PoolStats,
    pub pull: Option<PullStats>,
    pub serve: Option<ServeStats>,
    pub kv_client: Option<ClientStats>,
    pub kv_server: Option<ServerStats>,
}

impl MetricsSnapshot {
    /// Build a snapshot from a drained trace plus every process-global
    /// stats source (metrics registry, storage pool). Subsystem stats
    /// that live on instances are attached with the `with_*` builders.
    pub fn collect(wall_us: u64, spans: &[Span]) -> Self {
        let exec = |s: &&Span| matches!(s.cat, Category::Engine | Category::Plan);
        let busy_us: u64 = spans.iter().filter(exec).map(|s| s.dur_us).sum();
        let queue_us: u64 = spans.iter().filter(exec).map(|s| s.queue_us).sum();
        let mut tids: Vec<u32> = spans
            .iter()
            .filter(|s| matches!(s.cat, Category::Engine | Category::Plan | Category::Kernel))
            .map(|s| s.tid)
            .collect();
        tids.sort_unstable();
        tids.dedup();
        let workers = tids.len() as u64;
        let denom = wall_us.saturating_mul(workers);
        MetricsSnapshot {
            // v2: pool gained the live_bytes/peak_bytes gauges (ISSUE 9).
            schema: 2,
            wall_us,
            workers,
            busy_us,
            queue_us,
            utilization: if denom > 0 { busy_us as f64 / denom as f64 } else { 0.0 },
            queue_share: if busy_us + queue_us > 0 {
                queue_us as f64 / (busy_us + queue_us) as f64
            } else {
                0.0
            },
            dropped_spans: dropped(),
            ops: aggregate(spans),
            counters: crate::metrics::counters_sorted(),
            timers_s: crate::metrics::timers_sorted(),
            hists: crate::metrics::histograms_sorted()
                .into_iter()
                .map(|(name, count, p)| HistAgg {
                    name,
                    count,
                    p50_us: p[0],
                    p95_us: p[1],
                    p99_us: p[2],
                })
                .collect(),
            pool: crate::ndarray::pool::global().stats(),
            ..Default::default()
        }
    }

    pub fn with_pull(mut self, s: PullStats) -> Self {
        self.pull = Some(s);
        self
    }

    pub fn with_serve(mut self, s: ServeStats) -> Self {
        self.serve = Some(s);
        self
    }

    pub fn with_kv_client(mut self, s: ClientStats) -> Self {
        self.kv_client = Some(s);
        self
    }

    pub fn with_kv_server(mut self, s: ServerStats) -> Self {
        self.kv_server = Some(s);
        self
    }

    /// Serialize to JSON (hand-rolled; schema documented in README).
    pub fn to_json(&self) -> String {
        let mut o = String::with_capacity(1024);
        o.push('{');
        let _ = write!(
            o,
            "\"schema\":{},\"wall_us\":{},\"workers\":{},\"busy_us\":{},\"queue_us\":{},\
             \"utilization\":{:.4},\"queue_share\":{:.4},\"dropped_spans\":{}",
            self.schema,
            self.wall_us,
            self.workers,
            self.busy_us,
            self.queue_us,
            self.utilization,
            self.queue_share,
            self.dropped_spans
        );
        o.push_str(",\"ops\":[");
        for (i, op) in self.ops.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            let _ = write!(
                o,
                "{{\"cat\":\"{}\",\"name\":\"{}\",\"count\":{},\"total_us\":{},\
                 \"mean_us\":{:.3},\"p95_us\":{},\"queue_us\":{}}}",
                escape(&op.cat),
                escape(&op.name),
                op.count,
                op.total_us,
                op.mean_us,
                op.p95_us,
                op.queue_us
            );
        }
        o.push_str("],\"counters\":{");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            let _ = write!(o, "\"{}\":{v}", escape(k));
        }
        o.push_str("},\"timers_s\":{");
        for (i, (k, v)) in self.timers_s.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            let _ = write!(o, "\"{}\":{v:.6}", escape(k));
        }
        o.push_str("},\"histograms\":[");
        for (i, h) in self.hists.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            let _ = write!(
                o,
                "{{\"name\":\"{}\",\"count\":{},\"p50_us\":{},\"p95_us\":{},\"p99_us\":{}}}",
                escape(&h.name),
                h.count,
                h.p50_us,
                h.p95_us,
                h.p99_us
            );
        }
        o.push_str("],");
        let _ = write!(
            o,
            "\"pool\":{{\"hits\":{},\"misses\":{},\"releases\":{},\"evictions\":{},\
             \"pooled_buffers\":{},\"pooled_bytes\":{},\"live_bytes\":{},\"peak_bytes\":{}}}",
            self.pool.hits,
            self.pool.misses,
            self.pool.releases,
            self.pool.evictions,
            self.pool.pooled_buffers,
            self.pool.pooled_bytes,
            self.pool.live_bytes,
            self.pool.peak_bytes
        );
        match &self.pull {
            None => o.push_str(",\"pull\":null"),
            Some(p) => {
                let _ = write!(
                    o,
                    ",\"pull\":{{\"copies\":{},\"skips\":{},\"last_snap_age\":{},\
                     \"max_snap_age\":{}}}",
                    p.copies, p.skips, p.last_snap_age, p.max_snap_age
                );
            }
        }
        match &self.serve {
            None => o.push_str(",\"serve\":null"),
            Some(s) => {
                let _ = write!(
                    o,
                    ",\"serve\":{{\"requests\":{},\"batches\":{},\"rejected\":{},\
                     \"mean_batch\":{:.3},\"p50_us\":{},\"p95_us\":{},\"p99_us\":{},\
                     \"uptime_s\":{:.3},\"rps\":{:.3}}}",
                    s.requests,
                    s.batches,
                    s.rejected,
                    s.mean_batch,
                    s.p50_us,
                    s.p95_us,
                    s.p99_us,
                    s.uptime_s,
                    s.rps
                );
            }
        }
        match &self.kv_client {
            None => o.push_str(",\"kv_client\":null"),
            Some(c) => {
                let _ = write!(
                    o,
                    ",\"kv_client\":{{\"retries\":{},\"reconnects\":{}}}",
                    c.retries, c.reconnects
                );
            }
        }
        match &self.kv_server {
            None => o.push_str(",\"kv_server\":null"),
            Some(s) => {
                let _ = write!(
                    o,
                    ",\"kv_server\":{{\"msgs\":{},\"bytes\":{},\"dedup_hits\":{},\
                     \"lease_expiries\":{},\"applies\":{}}}",
                    s.msgs, s.bytes, s.dedup_hits, s.lease_expiries, s.applies
                );
            }
        }
        o.push('}');
        o
    }

    /// Parse a snapshot back from [`to_json`] output.
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = Json::parse(text)?;
        let mut snap = MetricsSnapshot {
            schema: req_u64(&v, "schema", "top")?,
            wall_us: req_u64(&v, "wall_us", "top")?,
            workers: req_u64(&v, "workers", "top")?,
            busy_us: req_u64(&v, "busy_us", "top")?,
            queue_us: req_u64(&v, "queue_us", "top")?,
            utilization: req_f64(&v, "utilization", "top")?,
            queue_share: req_f64(&v, "queue_share", "top")?,
            dropped_spans: req_u64(&v, "dropped_spans", "top")?,
            ..Default::default()
        };
        for op in v.get("ops").ok_or("missing ops")?.items() {
            snap.ops.push(OpAgg {
                cat: req_str(op, "cat", "op")?.to_string(),
                name: req_str(op, "name", "op")?.to_string(),
                count: req_u64(op, "count", "op")?,
                total_us: req_u64(op, "total_us", "op")?,
                mean_us: req_f64(op, "mean_us", "op")?,
                p95_us: req_u64(op, "p95_us", "op")?,
                queue_us: req_u64(op, "queue_us", "op")?,
            });
        }
        if let Some(Json::Obj(m)) = v.get("counters") {
            for (k, val) in m {
                snap.counters.push((k.clone(), val.as_u64().ok_or("counter value")?));
            }
        }
        if let Some(Json::Obj(m)) = v.get("timers_s") {
            for (k, val) in m {
                snap.timers_s.push((k.clone(), val.as_f64().ok_or("timer value")?));
            }
        }
        for h in v.get("histograms").ok_or("missing histograms")?.items() {
            snap.hists.push(HistAgg {
                name: req_str(h, "name", "hist")?.to_string(),
                count: req_u64(h, "count", "hist")?,
                p50_us: req_u64(h, "p50_us", "hist")?,
                p95_us: req_u64(h, "p95_us", "hist")?,
                p99_us: req_u64(h, "p99_us", "hist")?,
            });
        }
        let p = v.get("pool").ok_or("missing pool")?;
        snap.pool = PoolStats {
            hits: req_u64(p, "hits", "pool")?,
            misses: req_u64(p, "misses", "pool")?,
            releases: req_u64(p, "releases", "pool")?,
            evictions: req_u64(p, "evictions", "pool")?,
            pooled_buffers: req_u64(p, "pooled_buffers", "pool")?,
            pooled_bytes: req_u64(p, "pooled_bytes", "pool")?,
            // Schema-1 snapshots predate the live/peak gauges.
            live_bytes: req_u64(p, "live_bytes", "pool").unwrap_or(0),
            peak_bytes: req_u64(p, "peak_bytes", "pool").unwrap_or(0),
        };
        if let Some(p @ Json::Obj(_)) = v.get("pull") {
            snap.pull = Some(PullStats {
                copies: req_u64(p, "copies", "pull")?,
                skips: req_u64(p, "skips", "pull")?,
                last_snap_age: req_u64(p, "last_snap_age", "pull")?,
                max_snap_age: req_u64(p, "max_snap_age", "pull")?,
            });
        }
        if let Some(s @ Json::Obj(_)) = v.get("serve") {
            snap.serve = Some(ServeStats {
                requests: req_u64(s, "requests", "serve")?,
                batches: req_u64(s, "batches", "serve")?,
                rejected: req_u64(s, "rejected", "serve")?,
                mean_batch: req_f64(s, "mean_batch", "serve")?,
                p50_us: req_u64(s, "p50_us", "serve")?,
                p95_us: req_u64(s, "p95_us", "serve")?,
                p99_us: req_u64(s, "p99_us", "serve")?,
                uptime_s: req_f64(s, "uptime_s", "serve")?,
                rps: req_f64(s, "rps", "serve")?,
            });
        }
        if let Some(c @ Json::Obj(_)) = v.get("kv_client") {
            snap.kv_client = Some(ClientStats {
                retries: req_u64(c, "retries", "kv_client")?,
                reconnects: req_u64(c, "reconnects", "kv_client")?,
                // The per-shard breakdown is live-only diagnostics; the
                // persisted snapshot keeps the fleet sums (and stays
                // byte-identical across a roundtrip).
                shards: Vec::new(),
            });
        }
        if let Some(s @ Json::Obj(_)) = v.get("kv_server") {
            snap.kv_server = Some(ServerStats {
                msgs: req_u64(s, "msgs", "kv_server")?,
                bytes: req_u64(s, "bytes", "kv_server")?,
                dedup_hits: req_u64(s, "dedup_hits", "kv_server")?,
                lease_expiries: req_u64(s, "lease_expiries", "kv_server")?,
                applies: req_u64(s, "applies", "kv_server")?,
            });
        }
        Ok(snap)
    }

    /// Human-readable per-op table (stdout companion to the JSON dump).
    pub fn ops_table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<11} {:<26} {:>8} {:>12} {:>10} {:>10} {:>12}",
            "cat", "op", "count", "total_us", "mean_us", "p95_us", "queue_us"
        );
        for op in &self.ops {
            let _ = writeln!(
                out,
                "{:<11} {:<26} {:>8} {:>12} {:>10.1} {:>10} {:>12}",
                op.cat, op.name, op.count, op.total_us, op.mean_us, op.p95_us, op.queue_us
            );
        }
        let _ = write!(
            out,
            "workers={} busy={}us queue={}us wall={}us utilization={:.1}% queue_share={:.1}%",
            self.workers,
            self.busy_us,
            self.queue_us,
            self.wall_us,
            self.utilization * 100.0,
            self.queue_share * 100.0
        );
        if self.dropped_spans > 0 {
            let _ = write!(out, " DROPPED_SPANS={}", self.dropped_spans);
        }
        out
    }

    /// One-line delta vs a previous snapshot — what `--metrics-every`
    /// prints. Only counters that moved are shown.
    pub fn brief_line(&self, prev: Option<&MetricsSnapshot>) -> String {
        let mut parts = vec![format!("wall={:.1}s", self.wall_us as f64 / 1e6)];
        for (k, v) in &self.counters {
            let d = v.saturating_sub(prev_counter(prev, k));
            if d > 0 {
                parts.push(format!("{k}=+{d}"));
            }
        }
        let ph = prev.map(|p| p.pool.hits).unwrap_or(0);
        let pm = prev.map(|p| p.pool.misses).unwrap_or(0);
        let dh = self.pool.hits.saturating_sub(ph);
        let dm = self.pool.misses.saturating_sub(pm);
        if dh + dm > 0 {
            parts.push(format!("pool=+{dh}h/+{dm}m"));
        }
        if self.pool.peak_bytes > 0 {
            parts.push(format!(
                "pool_peak={:.1}mb",
                self.pool.peak_bytes as f64 / (1024.0 * 1024.0)
            ));
        }
        if let Some(s) = &self.serve {
            let prev_s = prev.and_then(|p| p.serve.as_ref());
            let dr = s.requests.saturating_sub(prev_s.map(|x| x.requests).unwrap_or(0));
            let db = s.batches.saturating_sub(prev_s.map(|x| x.batches).unwrap_or(0));
            parts.push(format!("serve=+{dr}req/+{db}batch"));
        }
        parts.truncate(12);
        parts.join(" ")
    }
}

fn req_u64(v: &Json, key: &str, ctx: &str) -> Result<u64, String> {
    v.get(key).and_then(Json::as_u64).ok_or_else(|| format!("missing {ctx}.{key}"))
}

fn req_f64(v: &Json, key: &str, ctx: &str) -> Result<f64, String> {
    v.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing {ctx}.{key}"))
}

fn req_str<'a>(v: &'a Json, key: &str, ctx: &str) -> Result<&'a str, String> {
    v.get(key).and_then(Json::as_str).ok_or_else(|| format!("missing {ctx}.{key}"))
}

fn prev_counter(prev: Option<&MetricsSnapshot>, key: &str) -> u64 {
    let Some(prev) = prev else { return 0 };
    prev.counters.iter().find(|(k, _)| k.as_str() == key).map(|(_, v)| *v).unwrap_or(0)
}

/// Finish a profiled phase: disable recording, drain the rings, write
/// the chrome trace to `trace_path` and the snapshot JSON to
/// `metrics_snapshot.json` next to it. Returns the snapshot for the
/// caller to print or extend.
pub fn export(trace_path: &str, wall_us: u64) -> std::io::Result<(MetricsSnapshot, Vec<Span>)> {
    set_enabled(false);
    let spans = drain();
    write_chrome_trace(trace_path, &spans)?;
    let snap = MetricsSnapshot::collect(wall_us, &spans);
    Ok((snap, spans))
}

/// Sibling path where the snapshot JSON for `trace_path` is written.
pub fn snapshot_path(trace_path: &str) -> String {
    let p = std::path::Path::new(trace_path);
    match p.parent() {
        Some(dir) if !dir.as_os_str().is_empty() => {
            dir.join("metrics_snapshot.json").to_string_lossy().into_owned()
        }
        _ => "metrics_snapshot.json".to_string(),
    }
}

//! Minimal hand-rolled JSON value + recursive-descent parser.
//!
//! The crate vendors no dependencies, so everything that emits JSON
//! (bench records, the chrome trace, `MetricsSnapshot`) hand-rolls its
//! serialization. This module is the matching *reader*: just enough of
//! RFC 8259 to parse back what we emit (and what jq validates in CI) so
//! tests can assert roundtrips and trace shape without serde. Object
//! keys keep insertion order — emission order is deterministic, so
//! parse→emit→parse is stable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object as sorted map: emission sites sort keys, so a BTreeMap
    /// both matches the wire order and makes equality structural.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing bytes at offset {pos}"));
        }
        Ok(v)
    }

    /// Object member lookup (None for non-objects or absent keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array elements (empty slice for non-arrays).
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON document (no surrounding
/// quotes). Shared by every hand-rolled emitter in the profile layer.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, ch: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == ch {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at offset {}", ch as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => parse_object(b, pos),
        Some(b'[') => parse_array(b, pos),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b't') => parse_lit(b, pos, "true").map(|_| Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false").map(|_| Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null").map(|_| Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected '{lit}' at offset {}", *pos))
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'{')?;
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(format!("expected ',' or '}}' at offset {}", *pos)),
        }
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at offset {}", *pos)),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| "truncated \\u escape".to_string())?;
                        let hex = std::str::from_utf8(hex).map_err(|e| e.to_string())?;
                        let code = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        // Surrogate pairs don't occur in our emitters;
                        // map lone surrogates to the replacement char.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at offset {}", *pos)),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (input is a &str, so byte
                // boundaries are valid).
                let start = *pos;
                *pos += 1;
                while *pos < b.len() && (b[*pos] & 0xC0) == 0x80 {
                    *pos += 1;
                }
                out.push_str(std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?);
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{text}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let doc = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e1}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_u64), Some(1));
        assert_eq!(v.get("b").unwrap().items().len(), 3);
        assert_eq!(v.get("b").unwrap().items()[2].as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().get("d").and_then(Json::as_f64), Some(-25.0));
    }

    #[test]
    fn escape_roundtrips_through_parse() {
        let raw = "quote\" slash\\ nl\n tab\t ctl\u{1}";
        let doc = format!("{{\"k\": \"{}\"}}", escape(raw));
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.get("k").and_then(Json::as_str), Some(raw));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }
}

//! Discrete-event simulation of data-parallel training through the
//! two-level parameter server, in virtual time.
//!
//! Each machine repeats the paper's §2.3 loop: compute a batch
//! (fwd+bwd on its devices in parallel), aggregate device gradients at
//! the level-1 server (PCIe), exchange the merged gradient with the
//! level-2 server (NIC).  The level-2 server's NIC is a shared resource:
//! transfers from different machines serialize, which is what makes
//! sequential consistency expensive at scale and why the paper runs
//! inter-machine synchronization with *eventual* consistency.
//!
//! Wall-time per pass comes out of the event loop; the accuracy
//! trajectory uses a calibrated phenomenological law (documented on
//! [`ClusterConfig`]) because the simulator does not run real gradients.

use super::cost::CostModel;

/// Virtual cluster configuration.
///
/// **Accuracy law.**  Validation accuracy after cumulative progress `P`
/// is `a(P) = a_inf * (1 - exp(-rate * P))`, where one unit of progress
/// is one parameter update at the single-machine reference batch size.
/// An update at effective batch `B` contributes `(B/B_ref)^kappa` units
/// (`kappa < 1`: large batches help sublinearly — the reason Figure 8's
/// distributed run converges *slower per pass* early), degraded by
/// `1 / (1 + staleness_penalty * staleness)` under eventual consistency.
/// Large-batch runs get a slightly higher asymptote `a_inf + batch_gain`
/// (lower gradient noise at fixed lr), which is what makes the
/// distributed curve *cross over* after ~10 passes, as in the paper.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of machines.
    pub machines: usize,
    /// Hardware rates.
    pub cost: CostModel,
    /// fwd+bwd FLOPs for one image.
    pub flops_per_image: f64,
    /// Model size in bytes (gradient = weight size).
    pub grad_bytes: f64,
    /// Images per device per batch (paper: 36).
    pub images_per_device: usize,
    /// Dataset size in images (ILSVRC12: 1.281M).
    pub dataset_images: usize,
    /// Data passes (epochs) to simulate.
    pub passes: usize,
    /// Inter-machine consistency: `true` = eventual (overlapped comm),
    /// `false` = sequential (blocking round trip).
    pub eventual: bool,
    /// Staleness ceiling for eventual mode (bounded-delay consistency,
    /// paper §2.3 footnote): at most this many server rounds may be
    /// outstanding per machine before it blocks, and the modeled
    /// staleness is clamped to it.  `None` = unbounded (one outstanding
    /// comm, the classic double-buffered model); values below 1 are
    /// treated as 1.
    pub max_staleness: Option<usize>,
    /// Asymptotic accuracy of the single-machine reference.
    pub acc_inf: f64,
    /// Convergence rate per unit progress.
    pub acc_rate: f64,
    /// Batch-size efficiency exponent (kappa).
    pub batch_kappa: f64,
    /// Extra asymptote for large effective batches.
    pub batch_gain: f64,
    /// Accuracy-progress penalty per update of staleness.
    pub staleness_penalty: f64,
}

impl ClusterConfig {
    /// The paper's Figure 8 setting: GoogLeNet-BN-class model on an
    /// ILSVRC12-sized dataset, g2.8x machines.  `flops_per_image` and
    /// `grad_bytes` should come from the real model
    /// ([`crate::models::inception_bn`] via
    /// [`graph_flops`](super::cost::graph_flops)).
    pub fn googlenet_paper(machines: usize, flops_per_image: f64, grad_bytes: f64) -> Self {
        ClusterConfig {
            machines,
            cost: CostModel::default(),
            flops_per_image,
            grad_bytes,
            images_per_device: 36,
            dataset_images: 1_281_167,
            passes: 15,
            eventual: machines > 1,
            max_staleness: None,
            acc_inf: 0.66,
            acc_rate: 0.32,
            batch_kappa: 0.85,
            batch_gain: 0.04,
            staleness_penalty: 0.03,
        }
    }

    fn images_per_machine_batch(&self) -> usize {
        self.images_per_device * self.cost.devices_per_machine
    }
}

/// Simulated statistics of one data pass.
#[derive(Debug, Clone)]
pub struct PassStat {
    /// Pass index (1-based, matching the paper's x-axis).
    pub pass: usize,
    /// Virtual seconds this pass took.
    pub seconds: f64,
    /// Virtual seconds since training started.
    pub cumulative_seconds: f64,
    /// Server updates applied during this pass (all machines).
    pub updates: usize,
    /// Modeled validation accuracy at the end of the pass.
    pub accuracy: f64,
    /// Mean staleness (updates behind) observed by workers this pass.
    pub staleness: f64,
}

/// Run the virtual cluster; returns one [`PassStat`] per data pass.
pub fn simulate(cfg: &ClusterConfig) -> Vec<PassStat> {
    assert!(cfg.machines >= 1);
    let per_batch_images = cfg.images_per_machine_batch();
    let batches_per_pass_per_machine =
        (cfg.dataset_images / cfg.machines / per_batch_images).max(1);

    // Per-device compute time for its share of the machine batch
    // (devices run in parallel; level-1 aggregation follows).
    let compute_s = cfg.cost.compute_time(cfg.flops_per_image * cfg.images_per_device as f64);
    let l1_s = cfg.cost.level1_time(cfg.grad_bytes);
    // One machine's push (or pull) occupies the server NIC for:
    let wire_s = cfg.grad_bytes / cfg.cost.nic_bytes_per_s;
    let update_s = cfg.cost.server_update_time(cfg.grad_bytes);

    // Event state: per-machine clock & outstanding-comm completions (a
    // queue of up to `comm_cap` in-flight server round trips); the
    // level-2 server NIC frees at `server_free`.
    let comm_cap = cfg.max_staleness.map(|k| k.max(1)).unwrap_or(1);
    let mut machine_clock = vec![0.0f64; cfg.machines];
    let mut comm_q: Vec<std::collections::VecDeque<f64>> =
        vec![std::collections::VecDeque::new(); cfg.machines];
    let mut server_free = 0.0f64;

    // Progress accumulator for the accuracy law.
    let ref_batch = per_batch_images as f64; // single-machine reference
    let eff_batch = (per_batch_images * cfg.machines) as f64;
    let per_update_progress = (eff_batch / ref_batch).powf(cfg.batch_kappa);
    let acc_inf = if cfg.machines > 1 {
        cfg.acc_inf + cfg.batch_gain * (eff_batch / ref_batch).ln() / 10.0f64.ln()
    } else {
        cfg.acc_inf
    };

    let mut progress = 0.0f64;
    let mut stats = Vec::with_capacity(cfg.passes);
    let mut prev_end = 0.0f64;

    for pass in 1..=cfg.passes {
        let mut staleness_sum = 0.0f64;
        let mut staleness_n = 0usize;
        for _batch in 0..batches_per_pass_per_machine {
            for m in 0..cfg.machines {
                // devices compute in parallel, then level-1 aggregates
                let compute_end = machine_clock[m] + compute_s + l1_s;
                // server round trip: push transfer + update + pull
                // transfer, serialized on the server NIC.
                let start = compute_end.max(server_free);
                let push_end = start + wire_s + cfg.net_latency();
                let updated = push_end + update_s;
                let pull_end = updated + wire_s + cfg.net_latency();
                server_free = pull_end;
                if cfg.eventual {
                    // Worker proceeds after local compute; up to
                    // `comm_cap` comms may be outstanding (bounded-delay
                    // pipeline; cap 1 = classic double-buffered weights).
                    let raw_stale = ((pull_end - compute_end)
                        / (compute_s + l1_s).max(1e-9))
                        .max(0.0);
                    // A bounded run never *observes* staleness past its
                    // ceiling — the blocking below is what enforces it.
                    let stale_updates = match cfg.max_staleness {
                        Some(k) => raw_stale.min(k.max(1) as f64),
                        None => raw_stale,
                    };
                    staleness_sum += stale_updates;
                    staleness_n += 1;
                    comm_q[m].push_back(pull_end);
                    while comm_q[m].len() > comm_cap {
                        let done = comm_q[m].pop_front().unwrap();
                        machine_clock[m] = machine_clock[m].max(done);
                    }
                    machine_clock[m] = machine_clock[m].max(compute_end);
                } else {
                    // Sequential: block until the fresh weights arrive.
                    machine_clock[m] = pull_end;
                    staleness_n += 1;
                }
            }
        }
        // A pass ends when the slowest machine finishes (and, for the
        // sequential model, its last pull has landed).
        let end = machine_clock
            .iter()
            .zip(&comm_q)
            .map(|(c, q)| q.iter().copied().fold(*c, f64::max))
            .fold(0.0f64, f64::max);
        let staleness =
            if staleness_n > 0 { staleness_sum / staleness_n as f64 } else { 0.0 };
        let updates = batches_per_pass_per_machine * cfg.machines;
        progress += updates as f64 / cfg.machines as f64 // server updates per pass
            * per_update_progress
            / (1.0 + cfg.staleness_penalty * staleness);
        // Normalize progress so one single-machine pass is ~1 unit.
        let unit = cfg.dataset_images as f64 / per_batch_images as f64;
        let accuracy = acc_inf * (1.0 - (-cfg.acc_rate * progress / unit).exp());
        stats.push(PassStat {
            pass,
            seconds: end - prev_end,
            cumulative_seconds: end,
            updates,
            accuracy,
            staleness,
        });
        prev_end = end;
    }
    stats
}

impl ClusterConfig {
    fn net_latency(&self) -> f64 {
        self.cost.net_latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_cfg(machines: usize) -> ClusterConfig {
        // GoogLeNet-BN-class, as measured on our inception graph:
        // ~12.3 GFLOP fwd+bwd per image, ~11.3M params (45 MB grads).
        ClusterConfig::googlenet_paper(machines, 12.3e9, 45.2e6)
    }

    #[test]
    fn ten_machines_near_linear_speedup() {
        let one = simulate(&paper_cfg(1));
        let ten = simulate(&paper_cfg(10));
        let ratio = one[0].seconds / ten[0].seconds;
        assert!(
            (6.0..=12.0).contains(&ratio),
            "speedup {ratio:.2} outside the paper's ~10x"
        );
    }

    #[test]
    fn accuracy_crossover_around_ten_passes() {
        let mut c1 = paper_cfg(1);
        let mut c10 = paper_cfg(10);
        c1.passes = 30;
        c10.passes = 30;
        let a1 = simulate(&c1);
        let a10 = simulate(&c10);
        // early: distributed behind; late: ahead (paper Figure 8)
        assert!(a10[2].accuracy < a1[2].accuracy, "early passes should favor 1 machine");
        let cross = a1
            .iter()
            .zip(&a10)
            .find(|(s1, s10)| s10.accuracy > s1.accuracy)
            .map(|(s, _)| s.pass);
        let cross = cross.expect("no crossover within 30 passes");
        assert!(
            (5..=20).contains(&cross),
            "crossover at pass {cross}, paper shows ~10"
        );
    }

    #[test]
    fn sequential_consistency_is_slower() {
        // At 10 machines the server NIC saturates and both modes converge
        // to the wire bound; the consistency gap shows where compute
        // dominates, so compare at 4 machines (compute-bound regime).
        let mut seq = paper_cfg(4);
        seq.eventual = false;
        let mut evt = paper_cfg(4);
        evt.eventual = true;
        let sequential = simulate(&seq);
        let eventual = simulate(&evt);
        assert!(
            sequential[0].seconds > 1.05 * eventual[0].seconds,
            "seq {} vs evt {}",
            sequential[0].seconds,
            eventual[0].seconds
        );
    }

    #[test]
    fn deterministic() {
        let a = simulate(&paper_cfg(10));
        let b = simulate(&paper_cfg(10));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seconds, y.seconds);
            assert_eq!(x.accuracy, y.accuracy);
        }
    }

    #[test]
    fn pass_seconds_in_paper_ballpark() {
        // Paper: 14k s/pass on one machine, 1.4k on ten. Our defaults
        // should land within ~3x of those magnitudes.
        let one = simulate(&paper_cfg(1));
        assert!(
            (4_000.0..45_000.0).contains(&one[0].seconds),
            "1-machine pass {:.0}s",
            one[0].seconds
        );
        let ten = simulate(&paper_cfg(10));
        assert!(
            (400.0..4_500.0).contains(&ten[0].seconds),
            "10-machine pass {:.0}s",
            ten[0].seconds
        );
    }

    #[test]
    fn bounded_staleness_never_exceeds_its_ceiling() {
        let mut cfg = paper_cfg(10);
        cfg.eventual = true;
        cfg.max_staleness = Some(2);
        let stats = simulate(&cfg);
        assert!(
            stats.iter().all(|s| s.staleness <= 2.0 + 1e-9),
            "staleness {:?}",
            stats.iter().map(|s| s.staleness).collect::<Vec<_>>()
        );
        // deterministic like everything else in the simulator
        let again = simulate(&cfg);
        for (a, b) in stats.iter().zip(&again) {
            assert_eq!(a.seconds, b.seconds);
            assert_eq!(a.staleness, b.staleness);
        }
    }

    #[test]
    fn bounded_delay_sits_between_sequential_and_eventual() {
        // Compute-bound regime (4 machines): sequential pays the full
        // blocking round trip, unbounded eventual pipelines it away, and
        // a deeper bounded window can only shorten (never lengthen) the
        // pass relative to the cap-1 eventual default.
        let mut seq = paper_cfg(4);
        seq.eventual = false;
        let mut bounded = paper_cfg(4);
        bounded.eventual = true;
        bounded.max_staleness = Some(4);
        let mut evt = paper_cfg(4);
        evt.eventual = true;
        let s = simulate(&seq)[0].seconds;
        let b = simulate(&bounded)[0].seconds;
        let e = simulate(&evt)[0].seconds;
        assert!(b < s, "bounded {b} should beat sequential {s}");
        assert!(b <= e + 1e-9, "bounded {b} should not lose to cap-1 eventual {e}");
    }

    #[test]
    fn staleness_zero_when_sequential() {
        let mut cfg = paper_cfg(4);
        cfg.eventual = false;
        let stats = simulate(&cfg);
        assert!(stats.iter().all(|s| s.staleness == 0.0));
    }
}

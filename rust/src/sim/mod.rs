//! Virtual-time cluster simulator (DESIGN S13b).
//!
//! The paper's Figure 8 trains GoogLeNet-BN on ILSVRC12 across 10 EC2
//! g2.8x machines (4 GPUs each, 10 GbE).  This host has a single CPU
//! core, so paper-scale wall-clock curves cannot be measured directly;
//! instead we (a) run the *real* two-level KVStore path at small scale to
//! validate correctness and calibrate per-op costs, then (b) replay the
//! paper's configuration in virtual time with this discrete-event
//! simulator.  DESIGN §4 documents the substitution.
//!
//! * [`cost`] — FLOP counting over computation graphs and the calibrated
//!   [`CostModel`](cost::CostModel) (compute rate, NIC bandwidth, PCIe).
//! * [`cluster`] — the event-driven simulation of data-parallel SGD
//!   through a two-level parameter server, producing per-pass wall time
//!   and a phenomenological accuracy trajectory.

pub mod cluster;
pub mod cost;

pub use cluster::{simulate, ClusterConfig, PassStat};
pub use cost::{graph_flops, op_flops, CostModel};

//! Cost model for the cluster simulator: analytic FLOP counts per graph
//! plus calibrated hardware rates.

use crate::graph::{Graph, Op, ShapeMap};

/// Analytic FLOP estimate for one execution of `op` given its input and
/// output shapes.  Convolutions and matmuls dominate; elementwise ops are
/// counted at one FLOP per output element.
///
/// This is the *cost hint* the executor attaches to every engine op
/// (`Engine::push_costed`): the threaded engine uses it to decide
/// serial-vs-parallel dispatch, dividing the intra-op pool among heavy
/// ops in flight.
pub fn op_flops(op: &Op, in_shapes: &[Vec<usize>], out_shapes: &[Vec<usize>]) -> f64 {
    let out_elems =
        |o: usize| out_shapes.get(o).map_or(0.0, |s| s.iter().product::<usize>() as f64);
    match op {
        Op::Variable => 0.0,
        Op::FullyConnected { num_hidden, epilogue } => {
            let x = &in_shapes[0];
            let in_dim: f64 = x[1..].iter().product::<usize>() as f64;
            // GEMM + bias + one FLOP per epilogue step per element, so
            // the engine's thread budgeting sees the fused node as at
            // least as heavy as the unfused producer.
            2.0 * x[0] as f64 * in_dim * *num_hidden as f64
                + out_elems(0) * (1 + epilogue.len()) as f64
        }
        Op::FullyConnectedBackward => {
            // dx = dy.W, dw = dy^T.x, db = sum(dy): ~2x forward matmul
            let dy = &in_shapes[0];
            let w = &in_shapes[2];
            4.0 * dy[0] as f64 * dy[1] as f64 * w[1] as f64
        }
        Op::Convolution { kernel, epilogue, .. } => {
            let x = &in_shapes[0];
            2.0 * out_elems(0) * (x[1] * kernel * kernel) as f64
                + out_elems(0) * (1 + epilogue.len()) as f64
        }
        Op::ConvolutionBackward { kernel, .. } => {
            let x = &in_shapes[1];
            let dy = &in_shapes[0];
            4.0 * dy.iter().product::<usize>() as f64 * (x[1] * kernel * kernel) as f64
        }
        Op::BatchNorm { .. } | Op::BatchNormBackward => 5.0 * out_elems(0),
        Op::Pooling { kernel, .. } => out_elems(0) * (kernel * kernel) as f64,
        Op::PoolingBackward { kernel, .. } => out_elems(0) * (kernel * kernel) as f64,
        Op::SoftmaxOutput | Op::SoftmaxOutputBackward => 4.0 * out_elems(0),
        Op::FusedElemwise { steps } => out_elems(0) * steps.len().max(1) as f64,
        // elementwise family: 1 FLOP per element
        _ => (0..out_shapes.len()).map(out_elems).sum::<f64>(),
    }
}

/// Floating-point operations of one execution of `graph` (both passes if
/// the graph contains backward nodes).  Sums [`op_flops`] over every node.
pub fn graph_flops(graph: &Graph, shapes: &ShapeMap) -> f64 {
    let mut total = 0.0f64;
    for (id, node) in graph.nodes.iter().enumerate() {
        let in_shapes: Vec<Vec<usize>> =
            node.inputs.iter().map(|e| shapes[e.node][e.out].clone()).collect();
        let out_shapes: Vec<Vec<usize>> =
            (0..graph.num_outputs_of(id)).map(|o| shapes[id][o].clone()).collect();
        total += op_flops(&node.op, &in_shapes, &out_shapes);
    }
    total
}

/// Calibrated hardware rates for the virtual cluster.
///
/// Defaults model the paper's testbed (EC2 g2.8x: 4x GK104, 10 GbE);
/// [`CostModel::calibrate_compute`] replaces the compute rate with one
/// measured on this host so that simulated magnitudes derive from real
/// observations where possible.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Sustained FLOP/s of one device (GPU) on this workload.
    pub device_flops: f64,
    /// Devices (GPUs) per machine, aggregated by the level-1 server.
    pub devices_per_machine: usize,
    /// Inter-machine NIC bandwidth, bytes/s (10 GbE = 1.25e9).
    pub nic_bytes_per_s: f64,
    /// Intra-machine (PCIe) bandwidth, bytes/s, for level-1 aggregation.
    pub pcie_bytes_per_s: f64,
    /// Fixed per-message latency, seconds.
    pub net_latency_s: f64,
    /// Level-2 server update cost per byte (SGD merge), seconds/byte.
    pub server_update_s_per_byte: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            // GK104 ~ 3 TFLOP/s peak; convnets sustain ~25-30%.
            device_flops: 0.8e12,
            devices_per_machine: 4,
            nic_bytes_per_s: 1.25e9,
            pcie_bytes_per_s: 8.0e9,
            net_latency_s: 0.5e-3,
            server_update_s_per_byte: 2.0e-11,
        }
    }
}

impl CostModel {
    /// Replace the device compute rate with a measured one: `flops` of a
    /// real graph executed in `seconds` on this host (the calibration run
    /// of `cargo bench --bench fig8_scalability`).
    pub fn calibrate_compute(mut self, flops: f64, seconds: f64) -> Self {
        assert!(seconds > 0.0 && flops > 0.0);
        self.device_flops = flops / seconds;
        self
    }

    /// Seconds for one device to compute fwd+bwd of `flops`.
    pub fn compute_time(&self, flops: f64) -> f64 {
        flops / self.device_flops
    }

    /// Seconds for the level-1 server to aggregate `bytes` of gradient
    /// from its devices over PCIe (tree reduction: each device's copy
    /// crosses the bus once).
    pub fn level1_time(&self, bytes: f64) -> f64 {
        self.devices_per_machine as f64 * bytes / self.pcie_bytes_per_s
    }

    /// Seconds for one machine's merged gradient to reach the level-2
    /// server and for updated weights to return, given `sharing` machines
    /// contending for the server NIC (push + pull).
    pub fn level2_time(&self, bytes: f64, sharing: usize) -> f64 {
        2.0 * self.net_latency_s
            + 2.0 * bytes * sharing as f64 / self.nic_bytes_per_s
    }

    /// Seconds for the level-2 server to apply a `bytes`-sized update.
    pub fn server_update_time(&self, bytes: f64) -> f64 {
        bytes * self.server_update_s_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;
    use crate::models::by_name;

    #[test]
    fn flops_scale_with_batch() {
        let m = by_name("simple-cnn").unwrap();
        let (g1, s1) = m.graph(8).unwrap();
        let (g2, s2) = m.graph(16).unwrap();
        let f1 = graph_flops(&g1, &infer_shapes(&g1, &s1).unwrap());
        let f2 = graph_flops(&g2, &infer_shapes(&g2, &s2).unwrap());
        assert!(f2 > 1.8 * f1 && f2 < 2.2 * f1, "f1={f1} f2={f2}");
    }

    #[test]
    fn inception_flops_in_published_range() {
        // GoogLeNet-class forward ~1.6 GFLOP/image at 224x224 (published
        // ~1.5-2 depending on variant); ours adds BN everywhere.
        let m = by_name("inception-bn").unwrap();
        let (g, vs) = m.graph(1).unwrap();
        let f = graph_flops(&g, &infer_shapes(&g, &vs).unwrap());
        assert!(
            (1.0e9..8.0e9).contains(&f),
            "inception fwd flops {f:.2e} outside sanity range"
        );
    }

    #[test]
    fn epilogue_fused_cost_at_least_unfused_producer() {
        use crate::graph::FusedStep;
        use crate::ndarray::kernels::ActKind;
        // FC: [32, 256] @ [128, 256]^T
        let ins = vec![vec![32, 256], vec![128, 256], vec![128]];
        let outs = vec![vec![32, 128]];
        let plain = Op::FullyConnected { num_hidden: 128, epilogue: vec![] };
        let fused = Op::FullyConnected {
            num_hidden: 128,
            epilogue: vec![FusedStep::Act(ActKind::Relu), FusedStep::AddScalar(1.0)],
        };
        let fp = op_flops(&plain, &ins, &outs);
        let ff = op_flops(&fused, &ins, &outs);
        assert!(ff >= fp, "fused {ff} < unfused {fp}");
        // ... and covers the absorbed elementwise work too
        let act_cost = op_flops(&Op::Activation { kind: ActKind::Relu }, &outs, &outs);
        assert!(ff >= fp + act_cost, "fused {ff} under-counts epilogue");

        // Conv: [4, 3, 32, 32] -> [4, 8, 32, 32], k=3
        let cins = vec![vec![4, 3, 32, 32], vec![8, 3, 3, 3], vec![8]];
        let couts = vec![vec![4, 8, 32, 32]];
        let cplain =
            Op::Convolution { num_filter: 8, kernel: 3, stride: 1, pad: 1, epilogue: vec![] };
        let cfused = Op::Convolution {
            num_filter: 8,
            kernel: 3,
            stride: 1,
            pad: 1,
            epilogue: vec![FusedStep::Act(ActKind::Tanh)],
        };
        let cp = op_flops(&cplain, &cins, &couts);
        let cf = op_flops(&cfused, &cins, &couts);
        assert!(cf > cp, "conv fused {cf} <= unfused {cp}");
    }

    #[test]
    fn calibration_replaces_rate() {
        let cm = CostModel::default().calibrate_compute(1e9, 0.5);
        assert_eq!(cm.device_flops, 2e9);
        assert!((cm.compute_time(4e9) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn level2_scales_with_contention() {
        let cm = CostModel::default();
        let t1 = cm.level2_time(1e8, 1);
        let t10 = cm.level2_time(1e8, 10);
        assert!(t10 > 9.0 * (t1 - 2.0 * cm.net_latency_s));
    }
}

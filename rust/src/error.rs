//! Crate-wide error type.
//!
//! Hand-implemented `Display`/`Error` (no `thiserror`): the crate is
//! deliberately dependency-light, matching the paper's "no other
//! dependency" stance.

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Error type covering every subsystem.
#[derive(Debug)]
pub enum Error {
    /// Shape inference or shape mismatch failure.
    Shape(String),

    /// Graph construction / binding errors (unknown argument, cycle, ...).
    Graph(String),

    /// Executor binding errors.
    Bind(String),

    /// KVStore errors (unknown key, wire protocol, ...).
    KvStore(String),

    /// Data I/O errors (RecordIO corruption, ...).
    DataIo(String),

    /// PJRT runtime errors.
    Runtime(String),

    /// Inference-serving errors (queue overflow, shutdown, bad request).
    Serve(String),

    /// Configuration / CLI errors.
    Config(String),

    /// Underlying std::io error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Bind(m) => write!(f, "bind error: {m}"),
            Error::KvStore(m) => write!(f, "kvstore error: {m}"),
            Error::DataIo(m) => write!(f, "io error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Serve(m) => write!(f, "serve error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor for a shape error.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Shorthand constructor for a graph error.
    pub fn graph(msg: impl Into<String>) -> Self {
        Error::Graph(msg.into())
    }
    /// Shorthand constructor for a kvstore error.
    pub fn kv(msg: impl Into<String>) -> Self {
        Error::KvStore(msg.into())
    }
    /// Shorthand constructor for a serving error.
    pub fn serve(msg: impl Into<String>) -> Self {
        Error::Serve(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(format!("{}", Error::shape("bad")), "shape error: bad");
        assert_eq!(format!("{}", Error::graph("cyc")), "graph error: cyc");
        assert_eq!(format!("{}", Error::kv("key")), "kvstore error: key");
        assert_eq!(format!("{}", Error::Runtime("x".into())), "runtime error: x");
        assert_eq!(format!("{}", Error::serve("full")), "serve error: full");
    }

    #[test]
    fn io_error_wraps_transparently() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}

//! Crate-wide error type.

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Error type covering every subsystem.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Shape inference or shape mismatch failure.
    #[error("shape error: {0}")]
    Shape(String),

    /// Graph construction / binding errors (unknown argument, cycle, ...).
    #[error("graph error: {0}")]
    Graph(String),

    /// Executor binding errors.
    #[error("bind error: {0}")]
    Bind(String),

    /// KVStore errors (unknown key, wire protocol, ...).
    #[error("kvstore error: {0}")]
    KvStore(String),

    /// Data I/O errors (RecordIO corruption, ...).
    #[error("io error: {0}")]
    DataIo(String),

    /// PJRT runtime errors.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Configuration / CLI errors.
    #[error("config error: {0}")]
    Config(String),

    /// Underlying std::io error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand constructor for a shape error.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Shorthand constructor for a graph error.
    pub fn graph(msg: impl Into<String>) -> Self {
        Error::Graph(msg.into())
    }
    /// Shorthand constructor for a kvstore error.
    pub fn kv(msg: impl Into<String>) -> Self {
        Error::KvStore(msg.into())
    }
}

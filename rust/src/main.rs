//! `mixnet` — the command-line launcher.
//!
//! Roles mirror MXNet's launcher: a level-2 parameter **server**, a
//! distributed **worker**, single-process **train**, the AOT
//! **transformer** driver (three-layer path), the memory **memplan**
//! inspector, and the Figure 8 **sim**.
//!
//! ```text
//! mixnet train --model mlp --epochs 4 --batch 32
//! mixnet serve --model mlp --checkpoint model.bin --clients 16
//! mixnet server --port 9700 --machines 2
//! mixnet worker --server 127.0.0.1:9700 --machine 0 --machines 2
//! mixnet transformer --steps 100 --artifacts artifacts
//! mixnet memplan --model vgg-11@64 --batch 64
//! mixnet sim --machines 10 --passes 12
//! ```

use std::sync::Arc;

use mixnet::engine::{create, default_threads, EngineKind};
use mixnet::executor::BindConfig;
use mixnet::graph::infer_shapes;
use mixnet::graph::memory::{default_external, plan_memory, AllocStrategy};
use mixnet::io::{synth, ArrayDataIter, DataIter, PrefetchIter};
use mixnet::kvstore::server::{ExpiryPolicy, PsServer, ServerConfig, ServerUpdater};
use mixnet::kvstore::{dist::DistKVStore, Consistency, LocalKVStore};
use mixnet::models::by_name;
use mixnet::module::{DataParallelTrainer, Module, SyncMode, TrainerConfig, UpdateMode};
use mixnet::optimizer::Sgd;
use mixnet::serve::{closed_loop, Servable, ServeConfig, Server};
use mixnet::sim::{graph_flops, simulate, ClusterConfig};
use mixnet::util::Args;
use mixnet::{Error, Result};

const USAGE: &str = "\
mixnet — a Rust+JAX+Pallas reproduction of MXNet (2015)

USAGE: mixnet <command> [options]

COMMANDS:
  train        data-parallel training of a zoo model on synthetic data
                 --model NAME  --epochs N  --batch N  --lr F  --seed N
                 --classes N   --examples N  --devices N
                 --kv local|dist  --consistency seq|bounded:K|eventual
                 --weights W0,W1,...  --no-overlap  --no-fuse
                 --memopt off|recompute[:K]  --checkpoint FILE
                 (--memopt recompute drops interior activations after
                  forward and recomputes them during backward — sublinear
                  activation memory, bitwise-identical results; K picks
                  the segment count, default √n; PALLAS_MEMOPT sets the
                  same knob when the flag is absent)
                 (--kv dist needs --server ADDR[,ADDR...] — one address
                  per server shard, shard i at position i; --kv-shards N
                  asserts the expected shard count; --batch is the global
                  batch, split over --devices replica shards; bounded:K
                  lets replicas run K rounds ahead of delivery; --weights
                  sizes each replica's share of the round — elastic sync;
                  --checkpoint saves train state per epoch and resumes
                  from FILE when it exists — local kv only)
  serve        dynamic-batching inference server + closed-loop demo
                 --model NAME  --checkpoint FILE  --clients N  --requests N
                 --max-batch N  --max-delay-us N  --workers N  --seed N
                 --no-fuse  (bind bucket executors without graph fusion;
                  fusion is lossless, so this is a perf A/B knob)
                 --live  (train and serve concurrently: the server answers
                  from the training store's committed snapshots)
                 (no --checkpoint: quick-trains/initializes weights first)
  server       run the level-2 parameter server (one shard of it)
                 --port N  --machines N  --lr F  --momentum F
                 --shard I/N  --lease-ms N  --lease-policy fail|degrade
                 (--shard I/N marks this process as shard I of an N-way
                  sharded key space; workers must list all N addresses
                  in shard order. Lease knobs also read
                  PALLAS_KV_LEASE_MS / PALLAS_KV_LEASE_POLICY; --shard
                  reads PALLAS_KV_SHARD; see README 'Sharded parameter
                  server' and 'Fault tolerance')
  worker       join distributed training as one machine (same Trainer as
               `train`, N local devices aggregated before the wire)
                 --server ADDR[,ADDR...]  --kv-shards N  --machine ID
                 --machines N  --devices N  [train opts]
  transformer  run the AOT three-layer transformer driver
                 --steps N  --artifacts DIR  --mode sgd|kvstore  --workers N
  memplan      print the Figure 7 memory table for one model
                 --model NAME  --batch N  [--training]
                 (with --training, also prints the sublinear-memory
                  recompute row: planned peak vs the memopt-off peak)
  sim          virtual-time Figure 8 replay
                 --machines N  --passes N
  info         version and backend information

OBSERVABILITY (see README 'Observability'):
  --profile FILE       (train/serve/worker) record a chrome://tracing
                       timeline to FILE and a metrics snapshot next to it
                       (PALLAS_PROFILE=FILE does the same)
  --metrics-every SEC  (train/serve/worker) print a one-line metrics
                       delta every SEC seconds
  --stats-every SEC    (server) poll the wire Stats RPC every SEC seconds
                       and print the server counters
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    match run(argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

const VALUE_KEYS: &[&str] = &[
    "model", "epochs", "batch", "lr", "seed", "classes", "examples", "port", "machines",
    "momentum", "server", "machine", "steps", "artifacts", "mode", "workers", "passes",
    "checkpoint", "clients", "requests", "max-batch", "max-delay-us", "devices", "kv",
    "consistency", "weights", "lease-ms", "lease-policy", "profile", "metrics-every",
    "stats-every", "memopt", "shard", "kv-shards",
];

fn run(argv: Vec<String>) -> Result<()> {
    let cmd = argv[0].clone();
    let args = Args::parse(argv.into_iter().skip(1), VALUE_KEYS)?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "server" => cmd_server(&args),
        "worker" => cmd_worker(&args),
        "transformer" => cmd_transformer(&args),
        "memplan" => cmd_memplan(&args),
        "sim" => cmd_sim(&args),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command '{other}' (try `mixnet help`)"))),
    }
}

/// Build model + global-batch iterator for a zoo model over synthetic
/// data; returns the per-shard batch (`--batch / shards`).
fn build_training(
    args: &Args,
    engine: mixnet::engine::EngineRef,
    shard_seed: u64,
    shards: usize,
) -> Result<(mixnet::models::Model, PrefetchIter, usize)> {
    let model_name = args.get_str("model", "mlp");
    let batch: usize = args.get("batch", 32)?;
    if shards == 0 || batch % shards != 0 {
        return Err(Error::Config(format!(
            "--batch {batch} must be divisible by the {shards} shards per round \
             (one per device, or the sum of --weights)"
        )));
    }
    let classes: usize = args.get("classes", 4)?;
    let examples: usize = args.get("examples", 2048)?;

    let m = by_name(&model_name)?;
    let feat: usize = m.feat_shape.iter().product();
    let ds = if m.feat_shape.len() == 3 {
        synth::images(
            examples,
            classes.min(m.num_classes),
            m.feat_shape[0],
            m.feat_shape[1],
            m.feat_shape[2],
            0.3,
            shard_seed,
        )
    } else {
        synth::class_clusters(examples, classes.min(m.num_classes), feat, 0.3, shard_seed)
    };
    let inner = ArrayDataIter::new(
        ds.features,
        ds.labels,
        &m.feat_shape.clone(),
        batch,
        true,
        engine.clone(),
    );
    // §2.4 multi-threaded prefetch on the training path; in-flight depth
    // comes from the PALLAS_PREFETCH_DEPTH knob (default 3).
    let iter = PrefetchIter::with_default_depth(Box::new(inner));
    Ok((m, iter, batch / shards))
}

/// Store parts per round for the CLI trainer: with `--weights`, the sum
/// of the weights (each weight unit is one shard, so a weight-3 host
/// runs three micro-steps per round for a weight-1 straggler's one);
/// otherwise one shard per device.
fn trainer_shards(args: &Args, devices: usize) -> Result<usize> {
    Ok(match parse_weights(args, devices)? {
        Some(w) => (w.iter().map(|&x| x as usize).sum::<usize>()).max(1),
        None => devices,
    })
}

/// `--weights W0,W1,...` (one entry per device; selects elastic sync).
fn parse_weights(args: &Args, devices: usize) -> Result<Option<Vec<u32>>> {
    let Some(s) = args.options.get("weights") else { return Ok(None) };
    let w = s
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<u32>()
                .map_err(|_| Error::Config(format!("--weights: bad entry '{t}'")))
        })
        .collect::<Result<Vec<u32>>>()?;
    if w.len() != devices {
        return Err(Error::Config(format!(
            "--weights has {} entries for --devices {devices}",
            w.len()
        )));
    }
    Ok(Some(w))
}

/// Bind the data-parallel trainer both `train` and `worker` share:
/// `shards` parts per round ([`trainer_shards`]), overlap unless
/// `--no-overlap`, seed from `--seed`, sync policy derived from
/// `--consistency` / `--weights`.
fn bind_trainer(
    args: &Args,
    engine: mixnet::engine::EngineRef,
    model: &mixnet::models::Model,
    shard_batch: usize,
    devices: usize,
    shards: usize,
    store: Arc<dyn mixnet::kvstore::KVStore>,
) -> Result<DataParallelTrainer> {
    let seed: u64 = args.get("seed", 7)?;
    let memopt = parse_memopt(args)?;
    let weights = parse_weights(args, devices)?;
    let sync = match (&weights, parse_consistency(args)?) {
        (Some(_), Consistency::BoundedDelay(_)) => {
            return Err(Error::Config(
                "--weights needs --consistency seq|eventual (elastic sync runs BSP \
                 barriers)"
                    .into(),
            ));
        }
        (Some(_), _) => SyncMode::Elastic,
        (None, Consistency::BoundedDelay(k)) => SyncMode::BoundedDelay(k),
        (None, _) => SyncMode::Bsp,
    };
    let shapes = model.param_shapes(shard_batch)?;
    DataParallelTrainer::bind(
        &model.symbol,
        engine,
        shard_batch,
        &model.feat_shape,
        &shapes,
        store,
        TrainerConfig {
            devices,
            shards,
            overlap: !args.has("no-overlap"),
            bind: BindConfig { fuse: !args.has("no-fuse"), memopt, ..Default::default() },
            seed,
            sync,
            weights: weights.unwrap_or_default(),
        },
    )
}

/// `--memopt off|recompute[:K]`, falling back to the `PALLAS_MEMOPT`
/// env knob when the flag is absent.
fn parse_memopt(args: &Args) -> Result<mixnet::graph::recompute::MemOpt> {
    use mixnet::graph::recompute::MemOpt;
    let spec = args.get_str("memopt", "");
    if spec.is_empty() {
        return Ok(MemOpt::from_env().unwrap_or(MemOpt::Off));
    }
    MemOpt::parse(&spec)
}

/// Connect a distributed store for `shards` local parts per round,
/// shipping the global-batch mean (mirrors the local path's updater
/// rescale).  `addrs` lists every server shard in shard order (shard i
/// at position i); one address is the classic unsharded setup.
fn dist_store(
    addrs: &[std::net::SocketAddr],
    machine: u32,
    shards: usize,
    consistency: Consistency,
    engine: mixnet::engine::EngineRef,
) -> Result<DistKVStore> {
    Ok(DistKVStore::connect_multi(addrs, machine, shards, consistency, engine)?
        .with_grad_rescale(1.0 / shards as f32))
}

/// `--server ADDR[,ADDR...]` — the ordered server-shard address list.
/// `--kv-shards N`, when present, asserts the list length so a
/// mistyped list fails before any connection is attempted.
fn parse_server_addrs(args: &Args) -> Result<Vec<std::net::SocketAddr>> {
    let spec = args
        .options
        .get("server")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:9700".into());
    let mut addrs = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let addr: std::net::SocketAddr = part
            .parse()
            .map_err(|_| Error::Config(format!("bad --server '{part}'")))?;
        addrs.push(addr);
    }
    if addrs.is_empty() {
        return Err(Error::Config(format!("--server '{spec}': no addresses")));
    }
    if let Some(n) = args.options.get("kv-shards") {
        let n: usize = n
            .parse()
            .map_err(|_| Error::Config(format!("--kv-shards: bad value '{n}'")))?;
        if n != addrs.len() {
            return Err(Error::Config(format!(
                "--kv-shards {n} but --server lists {} address(es)",
                addrs.len()
            )));
        }
    }
    Ok(addrs)
}

/// `--consistency seq|bounded:K|eventual` (with `--eventual` kept as an
/// alias).  `bounded` alone means `bounded:1`.
fn parse_consistency(args: &Args) -> Result<Consistency> {
    if args.has("eventual") {
        return Ok(Consistency::Eventual);
    }
    let spec = args.get_str("consistency", "seq");
    match spec.as_str() {
        "seq" | "sequential" => Ok(Consistency::Sequential),
        "eventual" => Ok(Consistency::Eventual),
        "bounded" => Ok(Consistency::BoundedDelay(1)),
        other => match other.strip_prefix("bounded:") {
            Some(k) => k
                .parse::<u64>()
                .map(Consistency::BoundedDelay)
                .map_err(|_| Error::Config(format!("--consistency bounded:K: bad K '{k}'"))),
            None => Err(Error::Config(format!(
                "--consistency must be seq|bounded:K|eventual, got '{other}'"
            ))),
        },
    }
}

/// Trace destination: `--profile FILE` wins over `PALLAS_PROFILE`.
/// A `Some` return means profiling was switched on for this run.
fn trace_path(args: &Args) -> Option<String> {
    let path = args.options.get("profile").cloned().or_else(mixnet::profile::env_trace_path);
    if path.is_some() {
        mixnet::profile::set_enabled(true);
    }
    path
}

/// Write the metrics snapshot next to the trace and print the per-op
/// aggregate table (the human-readable half of the snapshot).
fn write_snapshot(trace: &str, snap: &mixnet::profile::MetricsSnapshot) -> Result<()> {
    let out = mixnet::profile::snapshot_path(trace);
    std::fs::write(&out, snap.to_json())?;
    print!("{}", snap.ops_table());
    println!("profile: trace {trace}, snapshot {out}");
    Ok(())
}

/// Background `--metrics-every SEC` printer.  Each tick collects a
/// process-wide [`mixnet::profile::MetricsSnapshot`] (counters, storage
/// pool, histograms) and prints the delta since the previous tick; the
/// thread stops when the ticker is dropped.
struct MetricsTicker {
    stop: Option<std::sync::mpsc::Sender<()>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsTicker {
    fn start(args: &Args) -> Result<Option<MetricsTicker>> {
        let every: u64 = args.get("metrics-every", 0)?;
        if every == 0 {
            return Ok(None);
        }
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let t0 = std::time::Instant::now();
        let handle = std::thread::spawn(move || {
            let mut prev: Option<mixnet::profile::MetricsSnapshot> = None;
            loop {
                match rx.recv_timeout(std::time::Duration::from_secs(every)) {
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    _ => return, // dropped (or an explicit stop): exit
                }
                let wall = t0.elapsed().as_micros() as u64;
                let snap = mixnet::profile::MetricsSnapshot::collect(wall, &[]);
                println!("[metrics] {}", snap.brief_line(prev.as_ref()));
                prev = Some(snap);
            }
        });
        Ok(Some(MetricsTicker { stop: Some(tx), handle: Some(handle) }))
    }
}

impl Drop for MetricsTicker {
    fn drop(&mut self) {
        drop(self.stop.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn report(stats: &[mixnet::module::EpochStats]) {
    println!("{:>5} {:>9} {:>9} {:>8} {:>8}", "epoch", "loss", "acc", "sec", "batches");
    for s in stats {
        println!(
            "{:>5} {:>9.4} {:>9.3} {:>8.2} {:>8}",
            s.epoch, s.loss, s.accuracy, s.seconds, s.batches
        );
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let epochs: usize = args.get("epochs", 4)?;
    let lr: f32 = args.get("lr", 0.2)?;
    let devices: usize = args.get("devices", 1)?;
    let consistency = parse_consistency(args)?;
    let default_kv = if args.options.contains_key("server") { "dist" } else { "local" };
    let kv_kind = args.get_str("kv", default_kv);
    let shards = trainer_shards(args, devices)?;
    let trace = trace_path(args);
    let _ticker = MetricsTicker::start(args)?;
    let t0 = std::time::Instant::now();
    let engine = create(EngineKind::Threaded, default_threads());
    let (model, mut iter, shard_batch) = build_training(args, engine.clone(), 0x5eed, shards)?;
    // Concrete handles survive the trait-object coercion so the final
    // metrics snapshot can fold in pull/client/server statistics.
    let mut local_kv: Option<Arc<LocalKVStore>> = None;
    let mut dist_kv: Option<Arc<DistKVStore>> = None;
    let store: Arc<dyn mixnet::kvstore::KVStore> = match kv_kind.as_str() {
        "local" => {
            // local level-1 store with a registered SGD updater (§2.3);
            // the merged gradient is a sum of per-shard means, so rescale
            // by 1/shards to keep global-batch-mean semantics.
            let s = Arc::new(LocalKVStore::new(
                engine.clone(),
                shards,
                Arc::new(Sgd::with_momentum(lr, 0.9, 1e-4).rescale(1.0 / shards as f32)),
                consistency,
            ));
            local_kv = Some(s.clone());
            s
        }
        "dist" => {
            if !args.options.contains_key("server") {
                return Err(Error::Config("--kv dist needs --server ADDR[,ADDR...]".into()));
            }
            let addrs = parse_server_addrs(args)?;
            let machine: u32 = args.get("machine", 0)?;
            let s = Arc::new(dist_store(&addrs, machine, shards, consistency, engine.clone())?);
            dist_kv = Some(s.clone());
            s
        }
        other => {
            return Err(Error::Config(format!("--kv must be local|dist, got '{other}'")));
        }
    };
    let ckpt = args.options.get("checkpoint").cloned();
    if ckpt.is_some() && kv_kind != "local" {
        return Err(Error::Config(
            "--checkpoint resume needs --kv local (the level-2 server owns distributed \
             state)"
                .into(),
        ));
    }
    let mut trainer = bind_trainer(args, engine, &model, shard_batch, devices, shards, store)?;
    println!(
        "data-parallel: {devices} device(s), {shards} shard(s) of {shard_batch} rows, \
         kv {kv_kind}, {:?}",
        consistency
    );
    let stats = match &ckpt {
        None => trainer.fit(&mut iter, epochs)?,
        Some(path) => {
            // Crash-elastic resume: per-epoch checkpoints; an existing
            // file fast-forwards the run (iterator resets replay the
            // shuffle schedule so the resumed run matches bitwise).  An
            // unreadable checkpoint (e.g. disk corruption) falls back to
            // fresh training; validation happens before `resume_from` so
            // the trainer is never left half-restored.
            let mut done = 0u64;
            if std::path::Path::new(path).exists() {
                match mixnet::io::checkpoint::load_train_state(path) {
                    Ok(_) => {
                        done = trainer.resume_from(path)?;
                        println!("resumed {path}: {done} epoch(s) already done");
                    }
                    Err(e) => {
                        eprintln!(
                            "warning: checkpoint {path} unreadable ({e}); starting fresh"
                        );
                    }
                }
            }
            for _ in 0..done {
                iter.reset();
            }
            let mut stats = Vec::new();
            for e in (done as usize)..epochs {
                stats.extend(trainer.fit(&mut iter, 1)?);
                trainer.save_checkpoint(path, e as u64 + 1)?;
            }
            stats
        }
    };
    report(&stats);
    if let Some(path) = &trace {
        let wall = t0.elapsed().as_micros() as u64;
        let mut snap = mixnet::profile::export(path, wall)?.0;
        if let Some(kv) = &local_kv {
            snap = snap.with_pull(kv.pull_stats());
        }
        if let Some(kv) = &dist_kv {
            snap = snap.with_kv_client(kv.client_stats());
            if let Ok(s) = kv.server_stats() {
                snap = snap.with_kv_server(s);
            }
        }
        write_snapshot(path, &snap)?;
    }
    Ok(())
}

/// Dynamic-batching inference serving demo: load (or quick-train)
/// weights, start the server, drive a closed-loop client fleet, print
/// latency percentiles and throughput.
fn cmd_serve(args: &Args) -> Result<()> {
    if args.has("live") {
        return cmd_serve_live(args);
    }
    let model_spec = args.get_str("model", "mlp");
    let clients: usize = args.get("clients", 16)?;
    let requests: usize = args.get("requests", 64)?;
    let seed: u64 = args.get("seed", 7)?;
    let mut cfg = ServeConfig::from_env();
    cfg.max_batch = args.get("max-batch", cfg.max_batch)?;
    cfg.max_delay_us = args.get("max-delay-us", cfg.max_delay_us)?;
    cfg.workers = args.get("workers", cfg.workers)?;
    let trace = trace_path(args);
    let _ticker = MetricsTicker::start(args)?;
    let t0 = std::time::Instant::now();

    let engine = create(EngineKind::Threaded, default_threads());
    let m = by_name(&model_spec)?;
    let feat_shape = m.feat_shape.clone();
    let feat_len: usize = feat_shape.iter().product();

    let servable = match args.options.get("checkpoint") {
        Some(path) => Servable::from_checkpoint(m, path, engine.clone())?,
        None => {
            // No checkpoint: initialize (and, for flat-feature models,
            // quick-train) weights so the demo serves something real.
            let init = by_name(&model_spec)?;
            // conv models only need initialized weights; keep the
            // throwaway training bind small for them
            let bind_batch = if feat_shape.len() == 1 { 32 } else { 4 };
            let shapes = init.param_shapes(bind_batch)?;
            let mut module = Module::new(init.symbol, engine.clone());
            let bind = BindConfig { fuse: !args.has("no-fuse"), ..Default::default() };
            module.bind(bind_batch, &feat_shape, &shapes, bind, seed)?;
            if feat_shape.len() == 1 {
                let classes = m.num_classes.min(4);
                let ds = synth::class_clusters(1024, classes, feat_len, 0.3, seed);
                let mut iter = ArrayDataIter::new(
                    ds.features,
                    ds.labels,
                    &feat_shape,
                    32,
                    true,
                    engine.clone(),
                );
                let stats =
                    module.fit(&mut iter, &UpdateMode::Local(Arc::new(Sgd::new(0.3))), 2)?;
                println!(
                    "quick-trained {model_spec}: acc {:.3}",
                    stats.last().map(|s| s.accuracy).unwrap_or(0.0)
                );
            }
            let params = module
                .param_names()
                .iter()
                .map(|n| (n.clone(), module.param(n).unwrap().clone()))
                .collect();
            Servable::new(m, params, engine.clone())?
        }
    };
    let mut servable = servable;
    servable.set_fuse(!args.has("no-fuse"));

    let mut server = Server::start(&servable, &cfg)?;
    println!(
        "serving {model_spec}: max_batch {}, max_delay {}us, {} worker(s), queue {}",
        cfg.max_batch, cfg.max_delay_us, cfg.workers, cfg.queue_cap
    );
    let samples: Vec<Vec<f32>> = (0..256)
        .map(|i| {
            let mut rng = mixnet::util::Rng::seed_from_u64(seed ^ ((i as u64) << 8));
            (0..feat_len).map(|_| rng.uniform(-1.0, 1.0)).collect()
        })
        .collect();
    let report = closed_loop(&server, clients, requests, &samples);
    let stats = server.shutdown();
    println!(
        "{:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "requests", "rps", "p50 ms", "p95 ms", "p99 ms", "batches", "mean batch"
    );
    println!(
        "{:>10} {:>10.0} {:>10.3} {:>10.3} {:>10.3} {:>10} {:>10.2}",
        stats.requests,
        report.rps,
        stats.p50_us as f64 / 1e3,
        stats.p95_us as f64 / 1e3,
        stats.p99_us as f64 / 1e3,
        stats.batches,
        stats.mean_batch
    );
    if report.errors > 0 {
        println!("({} request(s) errored)", report.errors);
    }
    if let Some(path) = &trace {
        let wall = t0.elapsed().as_micros() as u64;
        let snap = mixnet::profile::export(path, wall)?.0.with_serve(stats.clone());
        write_snapshot(path, &snap)?;
    }
    Ok(())
}

/// `serve --live`: serving + training co-location (online learning).
/// A trainer thread fits the model through a `LocalKVStore` while the
/// server answers traffic from the store's committed snapshots
/// ([`Servable::attach_live`]) — responses pick up newly committed
/// rounds between batches, and never read a torn parameter.
fn cmd_serve_live(args: &Args) -> Result<()> {
    let model_spec = args.get_str("model", "mlp");
    let clients: usize = args.get("clients", 16)?;
    let requests: usize = args.get("requests", 64)?;
    let seed: u64 = args.get("seed", 7)?;
    let epochs: usize = args.get("epochs", 8)?;
    let lr: f32 = args.get("lr", 0.3)?;
    let examples: usize = args.get("examples", 1024)?;
    let mut cfg = ServeConfig::from_env();
    cfg.max_batch = args.get("max-batch", cfg.max_batch)?;
    cfg.max_delay_us = args.get("max-delay-us", cfg.max_delay_us)?;
    cfg.workers = args.get("workers", cfg.workers)?;
    let trace = trace_path(args);
    let _ticker = MetricsTicker::start(args)?;
    let t0 = std::time::Instant::now();

    let engine = create(EngineKind::Threaded, default_threads());
    let m = by_name(&model_spec)?;
    if m.feat_shape.len() != 1 {
        return Err(Error::Config(
            "serve --live quick-trains in-process and supports flat-feature models (mlp)"
                .into(),
        ));
    }
    let feat_shape = m.feat_shape.clone();
    let feat_len: usize = feat_shape.iter().product();
    let classes = m.num_classes.min(4);
    let batch = 32usize;
    let shapes = m.param_shapes(batch)?;

    // Seed the store with the initial weights; the servable holds its
    // own arrays and follows the store's committed snapshots.
    let fuse = !args.has("no-fuse");
    let mut module = Module::new(by_name(&model_spec)?.symbol, engine.clone());
    module.bind(
        batch,
        &feat_shape,
        &shapes,
        BindConfig { fuse, ..Default::default() },
        seed,
    )?;
    let store = Arc::new(LocalKVStore::new(
        engine.clone(),
        1,
        Arc::new(Sgd::new(lr)),
        Consistency::Sequential,
    ));
    for name in module.param_names() {
        store.init(name, module.param(name).unwrap())?;
    }
    let mut sparams = std::collections::HashMap::new();
    for name in module.param_names() {
        let src = module.param(name).unwrap();
        let dst = mixnet::ndarray::NDArray::zeros_on(src.shape(), engine.clone());
        dst.copy_from_(src);
        sparams.insert(name.clone(), dst);
    }
    drop(module); // the trainer thread binds its own executor
    let mut servable = Servable::new(m, sparams, engine.clone())?;
    servable.set_fuse(fuse);
    servable.attach_live(&store)?;

    // Trainer thread: the paper's §2.3 loop pushing into the same store
    // the server snapshots from.
    let t_engine = engine.clone();
    let t_store: Arc<dyn mixnet::kvstore::KVStore> = store.clone();
    let t_spec = model_spec.clone();
    let trainer = std::thread::spawn(move || -> Result<f32> {
        let tm = by_name(&t_spec)?;
        let shapes = tm.param_shapes(batch)?;
        let mut module = Module::new(tm.symbol, t_engine.clone());
        module.bind(
            batch,
            &tm.feat_shape.clone(),
            &shapes,
            BindConfig { fuse, ..Default::default() },
            seed,
        )?;
        let ds = synth::class_clusters(examples, classes, feat_len, 0.3, seed);
        let mut iter = ArrayDataIter::new(
            ds.features,
            ds.labels,
            &tm.feat_shape.clone(),
            batch,
            true,
            t_engine,
        );
        let stats = module.fit(
            &mut iter,
            &UpdateMode::KvStore { store: t_store, device: 0 },
            epochs,
        )?;
        Ok(stats.last().map(|s| s.accuracy).unwrap_or(0.0))
    });

    let mut server = Server::start(&servable, &cfg)?;
    println!(
        "live-serving {model_spec}: trainer running concurrently, max_batch {}, \
         {} worker(s)",
        cfg.max_batch, cfg.workers
    );
    let samples: Vec<Vec<f32>> = (0..256)
        .map(|i| {
            let mut rng = mixnet::util::Rng::seed_from_u64(seed ^ ((i as u64) << 8));
            (0..feat_len).map(|_| rng.uniform(-1.0, 1.0)).collect()
        })
        .collect();
    let report = closed_loop(&server, clients, requests, &samples);
    let train_acc = trainer
        .join()
        .map_err(|_| Error::Runtime("trainer thread panicked".into()))??;
    let stats = server.shutdown();
    println!(
        "{:>10} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "requests", "rps", "p50 ms", "p99 ms", "batches", "train acc"
    );
    println!(
        "{:>10} {:>10.0} {:>10.3} {:>10.3} {:>10} {:>12.3}",
        stats.requests,
        report.rps,
        stats.p50_us as f64 / 1e3,
        stats.p99_us as f64 / 1e3,
        stats.batches,
        train_acc
    );
    if report.errors > 0 {
        println!("({} request(s) errored)", report.errors);
    }
    if let Some(path) = &trace {
        let wall = t0.elapsed().as_micros() as u64;
        let mut snap = mixnet::profile::export(path, wall)?.0.with_serve(stats.clone());
        snap = snap.with_pull(store.pull_stats());
        write_snapshot(path, &snap)?;
    }
    Ok(())
}

fn cmd_server(args: &Args) -> Result<()> {
    let port: u16 = args.get("port", 9700)?;
    let machines: usize = args.get("machines", 1)?;
    let lr: f32 = args.get("lr", 0.2)?;
    let momentum: f32 = args.get("momentum", 0.9)?;
    let updater = ServerUpdater {
        lr: lr / machines as f32,
        momentum,
        weight_decay: 1e-4,
        rescale: 1.0,
    };
    let mut cfg = ServerConfig::from_env();
    if let Some(spec) = args.options.get("shard") {
        cfg.shard = Some(mixnet::kvstore::server::parse_shard(spec)?);
    }
    if let Some(ms) = args.options.get("lease-ms") {
        let ms: u64 = ms
            .parse()
            .map_err(|_| Error::Config(format!("--lease-ms: bad value '{ms}'")))?;
        cfg.lease = Some(std::time::Duration::from_millis(ms));
    }
    if let Some(p) = args.options.get("lease-policy") {
        cfg.expiry = match p.as_str() {
            "fail" | "fail-round" => ExpiryPolicy::FailRound,
            "degrade" => ExpiryPolicy::Degrade,
            other => {
                return Err(Error::Config(format!(
                    "--lease-policy must be fail|degrade, got '{other}'"
                )));
            }
        };
    }
    let server = PsServer::start_with(port, machines, updater, cfg.clone())?;
    match cfg.shard {
        Some((i, n)) => println!(
            "level-2 parameter server shard {i}/{n} on {} for {machines} machine(s)",
            server.addr()
        ),
        None => println!(
            "level-2 parameter server on {} for {machines} machine(s)",
            server.addr()
        ),
    }
    match cfg.lease {
        Some(l) => println!("lease {}ms, expiry {:?}", l.as_millis(), cfg.expiry),
        None => println!("leases disabled (set PALLAS_KV_LEASE_MS or --lease-ms)"),
    }
    let every: u64 = args.get("stats-every", 0)?;
    if every > 0 {
        // Poll our own wire endpoint with the Stats RPC — the same
        // message a worker's `server_stats()` sends — and print the
        // counters as one line per tick.
        let addr = server.addr();
        std::thread::spawn(move || {
            use mixnet::kvstore::wire::{read_msg, write_msg, Msg};
            loop {
                std::thread::sleep(std::time::Duration::from_secs(every));
                let Ok(mut s) = std::net::TcpStream::connect(addr) else { continue };
                if write_msg(&mut s, &Msg::Stats).is_err() {
                    continue;
                }
                if let Ok(Msg::StatsReply { msgs, bytes, dedup_hits, lease_expiries, applies }) =
                    read_msg(&mut s)
                {
                    println!(
                        "[stats] msgs={msgs} bytes={bytes} dedup={dedup_hits} \
                         lease_expiries={lease_expiries} applies={applies}"
                    );
                }
            }
        });
    }
    println!("(ctrl-c to stop)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_worker(args: &Args) -> Result<()> {
    let addrs = parse_server_addrs(args)?;
    let machine: u32 = args.get("machine", 0)?;
    let epochs: usize = args.get("epochs", 4)?;
    let devices: usize = args.get("devices", 1)?;
    let consistency = parse_consistency(args)?;
    let shards = trainer_shards(args, devices)?;
    let trace = trace_path(args);
    let _ticker = MetricsTicker::start(args)?;
    let t0 = std::time::Instant::now();
    let engine = create(EngineKind::Threaded, default_threads());
    let (model, mut iter, shard_batch) =
        build_training(args, engine.clone(), 0x5eed + machine as u64, shards)?;
    // The same Trainer as `mixnet train`: N local device shards, level-1
    // aggregated by the DistKVStore before one wire message per round.
    let kv = Arc::new(dist_store(&addrs, machine, shards, consistency, engine.clone())?);
    let store: Arc<dyn mixnet::kvstore::KVStore> = kv.clone();
    let mut trainer = bind_trainer(args, engine, &model, shard_batch, devices, shards, store)?;
    let stats = trainer.fit(&mut iter, epochs)?;
    kv.barrier()?;
    report(&stats);
    if let Some(path) = &trace {
        let wall = t0.elapsed().as_micros() as u64;
        let mut snap = mixnet::profile::export(path, wall)?.0.with_kv_client(kv.client_stats());
        if let Ok(s) = kv.server_stats() {
            snap = snap.with_kv_server(s);
        }
        write_snapshot(path, &snap)?;
    }
    Ok(())
}

fn cmd_transformer(args: &Args) -> Result<()> {
    // Thin wrapper over the example binary's logic: keep one source of
    // truth by delegating to it.
    let steps: usize = args.get("steps", 100)?;
    let mode = args.get_str("mode", "sgd");
    let workers: usize = args.get("workers", 2)?;
    let exe = std::env::current_exe()?;
    let example = exe
        .parent()
        .and_then(|p| Some(p.join("examples").join("train_transformer")))
        .filter(|p| p.exists());
    match example {
        Some(path) => {
            let status = std::process::Command::new(path)
                .args([steps.to_string(), mode, workers.to_string()])
                .status()?;
            if !status.success() {
                return Err(Error::Runtime("transformer driver failed".into()));
            }
            Ok(())
        }
        None => Err(Error::Config(
            "build the driver first: cargo build --release --example train_transformer".into(),
        )),
    }
}

fn cmd_memplan(args: &Args) -> Result<()> {
    let model = args.get_str("model", "inception-bn@64");
    let batch: usize = args.get("batch", 64)?;
    let m = by_name(&model)?;
    let (mut graph, vs) = m.graph(batch)?;
    let mut extra = vec![];
    if args.has("training") {
        let wrt: Vec<_> = graph
            .variables()
            .into_iter()
            .filter(|&v| {
                let n = &graph.nodes[v].name;
                n != "data" && !n.ends_with("_label")
            })
            .collect();
        let gi = mixnet::graph::autodiff::build_backward(&mut graph, &wrt)?;
        extra = gi.var_grads.values().copied().collect();
    }
    let shapes = infer_shapes(&graph, &vs)?;
    let external = default_external(&graph, &extra);
    println!("{model} batch {batch}: {} nodes", graph.nodes.len());
    for strategy in AllocStrategy::all() {
        let plan = plan_memory(&graph, &shapes, &external, strategy);
        println!("  {strategy:>8}: {:>8.1} MB internal", plan.bytes_mb());
    }
    if args.has("training") {
        // Sublinear-memory row: the recompute rewrite at auto √n segments.
        use mixnet::graph::recompute::{apply_recompute, segment_boundaries};
        let base = plan_memory(&graph, &shapes, &external, AllocStrategy::Both);
        let bounds = segment_boundaries(&graph, &shapes, 0);
        let (rg, emap, info) = apply_recompute(&graph, &shapes, &bounds)?;
        let extra2: Vec<_> = extra.iter().map(|e| emap[e]).collect();
        let shapes2 = infer_shapes(&rg, &vs)?;
        let ext2 = default_external(&rg, &extra2);
        let plan = plan_memory(&rg, &shapes2, &ext2, AllocStrategy::Both);
        println!(
            "  recompute: {:>7.1} MB peak vs {:.1} MB off-peak ({} segments, {} clones, {:.1} MB dropped)",
            mixnet::util::mb(plan.peak_bytes),
            mixnet::util::mb(base.peak_bytes),
            info.segments,
            info.recompute_nodes,
            mixnet::util::mb(info.dropped_bytes)
        );
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let machines: usize = args.get("machines", 10)?;
    let passes: usize = args.get("passes", 12)?;
    let m = by_name("inception-bn")?;
    let (g, vs) = m.graph(1)?;
    let shapes = infer_shapes(&g, &vs)?;
    let flops = 3.0 * graph_flops(&g, &shapes);
    let grad_bytes = m.num_params()? as f64 * 4.0;
    let mut cfg = ClusterConfig::googlenet_paper(machines, flops, grad_bytes);
    cfg.passes = passes;
    println!(
        "{:>5} {:>10} {:>12} {:>8} {:>10}",
        "pass", "sec/pass", "cum sec", "acc", "staleness"
    );
    for s in simulate(&cfg) {
        println!(
            "{:>5} {:>10.0} {:>12.0} {:>8.3} {:>10.2}",
            s.pass, s.seconds, s.cumulative_seconds, s.accuracy, s.staleness
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("mixnet {} — MXNet (2015) reproduction", env!("CARGO_PKG_VERSION"));
    println!("engine: threaded, {} default workers", default_threads());
    match mixnet::runtime::Runtime::cpu() {
        Ok(rt) => println!("pjrt: {} backend available", rt.platform()),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    println!(
        "models: mlp, simple-cnn, alexnet, vgg-11, vgg11-tower, vgg-16, conv-tower, \
         inception-bn (@HW scales input)"
    );
    Ok(())
}

//! The dependency engine (paper §3.2).
//!
//! Every *source unit* — an `NDArray`'s storage, a random-number seed, a
//! slice of temporal workspace — is registered with the engine as a
//! [`VarHandle`] (the paper's "unique tag").  Any operation (tensor math,
//! KVStore communication, a whole graph-executor node) is pushed with the
//! sets of variables it will **read** and the variables it will **write**
//! (mutate).  The engine continuously schedules pushed operations whose
//! dependencies are resolved onto a worker thread pool.
//!
//! Tracking *mutation* (write) in addition to read is the distinguishing
//! design point vs. pure dataflow engines (the paper contrasts with
//! Minerva): it lets parameter updates mutate arrays in place, lets two
//! users of one RNG seed be serialized for reproducibility, and makes the
//! imperative `NDArray` layer and the declarative graph layer schedulable
//! *jointly* — they are just ops on the same tag space.
//!
//! Two implementations share the [`Engine`] trait:
//!
//! * [`ThreadedEngine`](threaded::ThreadedEngine) — the real one: lazy,
//!   multi-threaded, out-of-order within dependency constraints.
//! * [`NaiveEngine`](naive::NaiveEngine) — executes each op inline at
//!   `push` (the *concrete execution* model of Torch7/Caffe in Table 1);
//!   it is both the correctness oracle for engine tests and the baseline
//!   for the Figure 6 execution-model comparison.

pub mod naive;
pub mod plan;
pub mod threaded;

use std::sync::Arc;

pub use naive::NaiveEngine;
pub use plan::{PlanBody, PlanOpSpec, RunPlan};
pub use threaded::ThreadedEngine;

/// FLOP estimate above which an op counts as "heavy": it gets a share of
/// the intra-op pool instead of running on one thread (~0.5 ms of serial
/// compute at a 2 GFLOP/s single-core floor).  Shared by the dynamic
/// dispatch path and the run-plan replay path so both budget intra-op
/// parallelism identically.
pub(crate) const HEAVY_FLOPS: f64 = 1e6;

/// Report a caught op panic (shared by the dynamic dispatch path and the
/// run-plan replay path).  A panicking op must still complete — its
/// dependents and every `wait_all` would block forever otherwise — so
/// both paths catch, report through here, and carry on, matching MXNet
/// where a failed kernel logs and the engine keeps serving other ops.
pub(crate) fn report_op_panic(path: &str, op: &str, payload: &(dyn std::any::Any + Send)) {
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic".into());
    eprintln!("mixnet {path}: op '{op}' panicked: {msg}");
}

/// Identifier for a registered resource unit ("tag").
pub type VarId = u64;

/// Process-wide var-id allocator.  Ids are unique across *all* engines so
/// that an array accidentally shared between two engines can never alias
/// another array's tag (cross-engine scheduling is still unordered — ops
/// must stay on one engine — but collisions would turn that logic error
/// into silent corruption).  The threaded engine's slab enforces this
/// explicitly: a handle whose slot/generation/id does not match a live
/// local variable (a *foreign* or *stale* handle) contributes no ordering
/// at all.  The one sanctioned cross-engine pattern is a single
/// synchronized copy out of a quiescent array (`KVStore::init`); anything
/// concurrent must keep all operands on one engine.
pub(crate) fn alloc_var_id() -> VarId {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Handle to an engine variable.  Cheap to copy; owned state lives inside
/// the engine that created it.
///
/// Besides the globally-unique id, a handle carries the owning engine's
/// slab coordinates (`slot`, `gen`) so the threaded engine resolves
/// per-var scheduling state by direct Vec index — no hashing on the
/// grant/notify path.  The generation (plus an id cross-check in the
/// slab) detects handles that outlived their variable: they simply
/// impose no ordering.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VarHandle {
    pub(crate) id: VarId,
    /// Slab slot in the owning engine (`u32::MAX` for engines that keep
    /// no per-var state, e.g. the naive engine).
    pub(crate) slot: u32,
    /// Slot generation at creation time.
    pub(crate) gen: u32,
}

impl VarHandle {
    /// Raw id (stable for the lifetime of the variable).
    pub fn id(&self) -> VarId {
        self.id
    }
}

/// An operation body. Runs exactly once on a worker thread.
pub type OpFn = Box<dyn FnOnce() + Send + 'static>;

/// The scheduling interface shared by all engines.
pub trait Engine: Send + Sync {
    /// Which implementation this is.
    fn kind(&self) -> EngineKind;

    /// Register a new resource unit and return its tag.
    fn new_var(&self) -> VarHandle;

    /// Push an operation that reads `read` and mutates `write`.
    ///
    /// Duplicates are tolerated; a variable listed in both sets is treated
    /// as write-only (a write dependency subsumes a read).  The op may run
    /// at any later time once every dependency is resolved; `push` itself
    /// never blocks on execution.
    fn push(&self, name: &'static str, read: Vec<VarHandle>, write: Vec<VarHandle>, func: OpFn);

    /// Like [`Engine::push`], but with an estimated cost in FLOPs so the
    /// engine can budget *intra*-op parallelism against *inter*-op
    /// parallelism (many cheap independent ops → run each serially; one
    /// big GEMM → let it fan out over the intra-op pool).  Engines that do
    /// not track cost fall back to plain `push`; pass [`f64::NAN`] when
    /// the cost is unknown.
    fn push_costed(
        &self,
        name: &'static str,
        read: Vec<VarHandle>,
        write: Vec<VarHandle>,
        cost_flops: f64,
        func: OpFn,
    ) {
        let _ = cost_flops;
        self.push(name, read, write, func);
    }

    /// Execute a compiled [`RunPlan`] (ISSUE 3).  Ordering contract: the
    /// plan behaves exactly like pushing each of its ops through
    /// [`Engine::push_costed`] in program order — later pushes touching
    /// the plan's vars are ordered after it, earlier ones before it —
    /// which is precisely what this default implementation does.
    ///
    /// Engines with a native replay path (the threaded engine) instead
    /// synchronize the plan's *boundary* var sets once and replay the
    /// precompiled DAG with lock-free countdowns, skipping the per-op
    /// scheduling machinery entirely.
    fn run_plan(&self, plan: &Arc<RunPlan>, step: u64) {
        push_plan_ops(self, plan, step);
    }

    /// Block until all ops pushed so far that touch `var` have completed.
    fn wait_for_var(&self, var: VarHandle);

    /// Block until every pushed op has completed.
    fn wait_all(&self);

    /// Schedule the variable for removal once its pending ops finish.
    fn delete_var(&self, var: VarHandle);

    /// Number of worker threads (1 for the naive engine).
    fn num_workers(&self) -> usize {
        1
    }
}

/// Normalize a dependency list pair: dedupe each side and drop reads
/// that are also writes (a write subsumes a read).  The single source of
/// truth for both scheduling paths — `ThreadedEngine::push_costed` and
/// [`RunPlan::compile`] must classify identically or replay-vs-push
/// bitwise equivalence breaks.
pub(crate) fn normalize_deps(
    read: &[VarHandle],
    write: &[VarHandle],
) -> (Vec<VarHandle>, Vec<VarHandle>) {
    let mut writes = write.to_vec();
    writes.sort_unstable();
    writes.dedup();
    let mut reads: Vec<VarHandle> = read
        .iter()
        .copied()
        .filter(|v| writes.binary_search(v).is_err())
        .collect();
    reads.sort_unstable();
    reads.dedup();
    (reads, writes)
}

/// Push every op of `plan` through the dynamic per-op path: the shared
/// fallback used by [`Engine::run_plan`]'s default implementation and by
/// the threaded engine for write-free plans (which lack the boundary
/// write grant that serializes native replays).
pub fn push_plan_ops<E: Engine + ?Sized>(engine: &E, plan: &RunPlan, step: u64) {
    for i in 0..plan.len() {
        let (name, reads, writes, cost, func) = plan.push_parts(i, step);
        engine.push_costed(name, reads, writes, cost, func);
    }
}

/// Engine implementation selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Lazy multi-threaded dependency scheduling (the paper's engine).
    Threaded,
    /// Eager inline execution (concrete-execution baseline).
    Naive,
}

/// Shared reference to an engine.
pub type EngineRef = Arc<dyn Engine>;

/// Create an engine of the given kind. `threads` is ignored by
/// [`EngineKind::Naive`].
pub fn create(kind: EngineKind, threads: usize) -> EngineRef {
    match kind {
        EngineKind::Threaded => Arc::new(ThreadedEngine::new(threads)),
        EngineKind::Naive => Arc::new(NaiveEngine::new()),
    }
}

/// Default worker count: one per hardware thread, minimum 2 so that
/// compute can overlap communication even on a single-core host.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).max(2)
}

/// The process-wide default engine used when callers do not pass one
/// (mirrors MXNet's global `Engine::Get()`).
pub fn default_engine() -> EngineRef {
    use std::sync::OnceLock;
    static GLOBAL: OnceLock<EngineRef> = OnceLock::new();
    Arc::clone(GLOBAL.get_or_init(|| create(EngineKind::Threaded, default_threads())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn engines() -> Vec<EngineRef> {
        vec![create(EngineKind::Threaded, 4), create(EngineKind::Naive, 1)]
    }

    #[test]
    fn push_and_wait_all_runs_everything() {
        for eng in engines() {
            let v = eng.new_var();
            let counter = Arc::new(AtomicUsize::new(0));
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                eng.push("inc", vec![], vec![v], Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }));
            }
            eng.wait_all();
            assert_eq!(counter.load(Ordering::SeqCst), 100, "{:?}", eng.kind());
        }
    }

    #[test]
    fn writes_to_same_var_are_serialized() {
        // Two ops writing one var must never overlap (paper: same-seed RNG
        // ops are serialized for reproducibility).
        let eng = create(EngineKind::Threaded, 4);
        let v = eng.new_var();
        let active = Arc::new(AtomicUsize::new(0));
        let overlap = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let active = Arc::clone(&active);
            let overlap = Arc::clone(&overlap);
            eng.push("w", vec![], vec![v], Box::new(move || {
                if active.fetch_add(1, Ordering::SeqCst) > 0 {
                    overlap.fetch_add(1, Ordering::SeqCst);
                }
                std::thread::sleep(std::time::Duration::from_micros(50));
                active.fetch_sub(1, Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        assert_eq!(overlap.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn reader_sees_prior_write() {
        for eng in engines() {
            let v = eng.new_var();
            let cell = Arc::new(AtomicUsize::new(0));
            {
                let c = Arc::clone(&cell);
                eng.push("write", vec![], vec![v], Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    c.store(42, Ordering::SeqCst);
                }));
            }
            let observed = Arc::new(AtomicUsize::new(0));
            {
                let c = Arc::clone(&cell);
                let o = Arc::clone(&observed);
                eng.push("read", vec![v], vec![], Box::new(move || {
                    o.store(c.load(Ordering::SeqCst), Ordering::SeqCst);
                }));
            }
            eng.wait_for_var(v);
            assert_eq!(observed.load(Ordering::SeqCst), 42, "{:?}", eng.kind());
        }
    }

    #[test]
    fn wait_for_var_only_waits_that_var() {
        let eng = create(EngineKind::Threaded, 4);
        let fast = eng.new_var();
        let slow = eng.new_var();
        let slow_done = Arc::new(AtomicUsize::new(0));
        {
            let d = Arc::clone(&slow_done);
            eng.push("slow", vec![], vec![slow], Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(100));
                d.store(1, Ordering::SeqCst);
            }));
        }
        eng.push("fast", vec![], vec![fast], Box::new(|| {}));
        eng.wait_for_var(fast);
        // `slow` is very likely still running; we only assert we did not
        // block on it for its full duration.
        eng.wait_all();
        assert_eq!(slow_done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn var_in_read_and_write_is_treated_as_write() {
        let eng = create(EngineKind::Threaded, 4);
        let v = eng.new_var();
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let h = Arc::clone(&hits);
            eng.push("rw", vec![v], vec![v], Box::new(move || {
                h.fetch_add(1, Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn delete_var_after_pending_ops() {
        let eng = create(EngineKind::Threaded, 2);
        let v = eng.new_var();
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        eng.push("op", vec![], vec![v], Box::new(move || {
            d.store(7, Ordering::SeqCst);
        }));
        eng.delete_var(v);
        eng.wait_all();
        assert_eq!(done.load(Ordering::SeqCst), 7);
    }
}

//! The dependency engine (paper §3.2).
//!
//! Every *source unit* — an `NDArray`'s storage, a random-number seed, a
//! slice of temporal workspace — is registered with the engine as a
//! [`VarHandle`] (the paper's "unique tag").  Any operation (tensor math,
//! KVStore communication, a whole graph-executor node) is pushed with the
//! sets of variables it will **read** and the variables it will **write**
//! (mutate).  The engine continuously schedules pushed operations whose
//! dependencies are resolved onto a worker thread pool.
//!
//! Tracking *mutation* (write) in addition to read is the distinguishing
//! design point vs. pure dataflow engines (the paper contrasts with
//! Minerva): it lets parameter updates mutate arrays in place, lets two
//! users of one RNG seed be serialized for reproducibility, and makes the
//! imperative `NDArray` layer and the declarative graph layer schedulable
//! *jointly* — they are just ops on the same tag space.
//!
//! Two implementations share the [`Engine`] trait:
//!
//! * [`ThreadedEngine`](threaded::ThreadedEngine) — the real one: lazy,
//!   multi-threaded, out-of-order within dependency constraints.
//! * [`NaiveEngine`](naive::NaiveEngine) — executes each op inline at
//!   `push` (the *concrete execution* model of Torch7/Caffe in Table 1);
//!   it is both the correctness oracle for engine tests and the baseline
//!   for the Figure 6 execution-model comparison.

pub mod naive;
pub mod threaded;

use std::sync::Arc;

pub use naive::NaiveEngine;
pub use threaded::ThreadedEngine;

/// Identifier for a registered resource unit ("tag").
pub type VarId = u64;

/// Process-wide var-id allocator.  Ids are unique across *all* engines so
/// that an array accidentally shared between two engines can never alias
/// another array's tag (cross-engine scheduling is still unordered — ops
/// must stay on one engine — but collisions would turn that logic error
/// into silent corruption).
pub(crate) fn alloc_var_id() -> VarId {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Handle to an engine variable.  Cheap to copy; owned state lives inside
/// the engine that created it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VarHandle(pub(crate) VarId);

impl VarHandle {
    /// Raw id (stable for the lifetime of the variable).
    pub fn id(&self) -> VarId {
        self.0
    }
}

/// An operation body. Runs exactly once on a worker thread.
pub type OpFn = Box<dyn FnOnce() + Send + 'static>;

/// The scheduling interface shared by all engines.
pub trait Engine: Send + Sync {
    /// Which implementation this is.
    fn kind(&self) -> EngineKind;

    /// Register a new resource unit and return its tag.
    fn new_var(&self) -> VarHandle;

    /// Push an operation that reads `read` and mutates `write`.
    ///
    /// Duplicates are tolerated; a variable listed in both sets is treated
    /// as write-only (a write dependency subsumes a read).  The op may run
    /// at any later time once every dependency is resolved; `push` itself
    /// never blocks on execution.
    fn push(&self, name: &'static str, read: Vec<VarHandle>, write: Vec<VarHandle>, func: OpFn);

    /// Like [`Engine::push`], but with an estimated cost in FLOPs so the
    /// engine can budget *intra*-op parallelism against *inter*-op
    /// parallelism (many cheap independent ops → run each serially; one
    /// big GEMM → let it fan out over the intra-op pool).  Engines that do
    /// not track cost fall back to plain `push`; pass [`f64::NAN`] when
    /// the cost is unknown.
    fn push_costed(
        &self,
        name: &'static str,
        read: Vec<VarHandle>,
        write: Vec<VarHandle>,
        cost_flops: f64,
        func: OpFn,
    ) {
        let _ = cost_flops;
        self.push(name, read, write, func);
    }

    /// Block until all ops pushed so far that touch `var` have completed.
    fn wait_for_var(&self, var: VarHandle);

    /// Block until every pushed op has completed.
    fn wait_all(&self);

    /// Schedule the variable for removal once its pending ops finish.
    fn delete_var(&self, var: VarHandle);

    /// Number of worker threads (1 for the naive engine).
    fn num_workers(&self) -> usize {
        1
    }
}

/// Engine implementation selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Lazy multi-threaded dependency scheduling (the paper's engine).
    Threaded,
    /// Eager inline execution (concrete-execution baseline).
    Naive,
}

/// Shared reference to an engine.
pub type EngineRef = Arc<dyn Engine>;

/// Create an engine of the given kind. `threads` is ignored by
/// [`EngineKind::Naive`].
pub fn create(kind: EngineKind, threads: usize) -> EngineRef {
    match kind {
        EngineKind::Threaded => Arc::new(ThreadedEngine::new(threads)),
        EngineKind::Naive => Arc::new(NaiveEngine::new()),
    }
}

/// Default worker count: one per hardware thread, minimum 2 so that
/// compute can overlap communication even on a single-core host.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).max(2)
}

/// The process-wide default engine used when callers do not pass one
/// (mirrors MXNet's global `Engine::Get()`).
pub fn default_engine() -> EngineRef {
    use std::sync::OnceLock;
    static GLOBAL: OnceLock<EngineRef> = OnceLock::new();
    Arc::clone(GLOBAL.get_or_init(|| create(EngineKind::Threaded, default_threads())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn engines() -> Vec<EngineRef> {
        vec![create(EngineKind::Threaded, 4), create(EngineKind::Naive, 1)]
    }

    #[test]
    fn push_and_wait_all_runs_everything() {
        for eng in engines() {
            let v = eng.new_var();
            let counter = Arc::new(AtomicUsize::new(0));
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                eng.push("inc", vec![], vec![v], Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }));
            }
            eng.wait_all();
            assert_eq!(counter.load(Ordering::SeqCst), 100, "{:?}", eng.kind());
        }
    }

    #[test]
    fn writes_to_same_var_are_serialized() {
        // Two ops writing one var must never overlap (paper: same-seed RNG
        // ops are serialized for reproducibility).
        let eng = create(EngineKind::Threaded, 4);
        let v = eng.new_var();
        let active = Arc::new(AtomicUsize::new(0));
        let overlap = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let active = Arc::clone(&active);
            let overlap = Arc::clone(&overlap);
            eng.push("w", vec![], vec![v], Box::new(move || {
                if active.fetch_add(1, Ordering::SeqCst) > 0 {
                    overlap.fetch_add(1, Ordering::SeqCst);
                }
                std::thread::sleep(std::time::Duration::from_micros(50));
                active.fetch_sub(1, Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        assert_eq!(overlap.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn reader_sees_prior_write() {
        for eng in engines() {
            let v = eng.new_var();
            let cell = Arc::new(AtomicUsize::new(0));
            {
                let c = Arc::clone(&cell);
                eng.push("write", vec![], vec![v], Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    c.store(42, Ordering::SeqCst);
                }));
            }
            let observed = Arc::new(AtomicUsize::new(0));
            {
                let c = Arc::clone(&cell);
                let o = Arc::clone(&observed);
                eng.push("read", vec![v], vec![], Box::new(move || {
                    o.store(c.load(Ordering::SeqCst), Ordering::SeqCst);
                }));
            }
            eng.wait_for_var(v);
            assert_eq!(observed.load(Ordering::SeqCst), 42, "{:?}", eng.kind());
        }
    }

    #[test]
    fn wait_for_var_only_waits_that_var() {
        let eng = create(EngineKind::Threaded, 4);
        let fast = eng.new_var();
        let slow = eng.new_var();
        let slow_done = Arc::new(AtomicUsize::new(0));
        {
            let d = Arc::clone(&slow_done);
            eng.push("slow", vec![], vec![slow], Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(100));
                d.store(1, Ordering::SeqCst);
            }));
        }
        eng.push("fast", vec![], vec![fast], Box::new(|| {}));
        eng.wait_for_var(fast);
        // `slow` is very likely still running; we only assert we did not
        // block on it for its full duration.
        eng.wait_all();
        assert_eq!(slow_done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn var_in_read_and_write_is_treated_as_write() {
        let eng = create(EngineKind::Threaded, 4);
        let v = eng.new_var();
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let h = Arc::clone(&hits);
            eng.push("rw", vec![v], vec![v], Box::new(move || {
                h.fetch_add(1, Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn delete_var_after_pending_ops() {
        let eng = create(EngineKind::Threaded, 2);
        let v = eng.new_var();
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        eng.push("op", vec![], vec![v], Box::new(move || {
            d.store(7, Ordering::SeqCst);
        }));
        eng.delete_var(v);
        eng.wait_all();
        assert_eq!(done.load(Ordering::SeqCst), 7);
    }
}

//! The multi-threaded dependency engine.
//!
//! Scheduling model (a faithful, compact re-implementation of MXNet's
//! `ThreadedEngine`): each variable keeps a FIFO queue of pending
//! dependency requests.  A *read* request is granted when it reaches the
//! logical front (no earlier writer queued) and no writer is active; any
//! number of reads may be active at once.  A *write* request is granted
//! only when it is at the front and the variable is fully quiescent.  An
//! operation becomes ready when all of its per-variable requests are
//! granted, at which point it is dispatched to the worker pool; on
//! completion each variable is notified, which may grant the next queued
//! requests.
//!
//! FIFO granting per variable gives two system properties the paper relies
//! on: (1) program order is preserved per resource, so the imperative
//! `w -= eta * g` after a graph backward observes the right gradient, and
//! (2) writers cannot starve.
//!
//! Per-variable state lives in a **generation-checked slab** indexed by
//! [`VarHandle::slot`] (ISSUE 3): the grant/notify hot path is pure Vec
//! indexing — the `HashMap<VarId, _>` lookup it replaced is gone.  A
//! handle whose generation (or id) no longer matches its slot refers to a
//! deleted variable and simply contributes no ordering.
//!
//! Bound executors skip this per-op machinery entirely via
//! [`Engine::run_plan`]: one engine op synchronizes a [`RunPlan`]'s
//! boundary vars, then the precompiled DAG replays on this engine's own
//! worker pool with lock-free countdowns (see [`super::plan`]).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::{Engine, EngineKind, OpFn, RunPlan, VarHandle, VarId, HEAVY_FLOPS};
use crate::profile::{self, Category, SpanTimer};
use crate::util::ThreadPool;

/// One queued dependency request: op index + whether it mutates the var.
#[derive(Debug, Clone, Copy)]
struct Request {
    op: usize,
    write: bool,
}

/// Per-variable scheduling state.
#[derive(Debug, Default)]
struct VarSched {
    queue: VecDeque<Request>,
    active_readers: usize,
    active_writer: bool,
    /// Set by `delete_var`; the slot is freed once fully quiescent.
    pending_delete: bool,
}

impl VarSched {
    fn quiescent(&self) -> bool {
        self.queue.is_empty() && self.active_readers == 0 && !self.active_writer
    }
}

/// One slab slot hosting (at most) one live variable.
#[derive(Debug)]
struct Slot {
    /// Bumped when the slot is freed; stale handles fail the check.
    gen: u32,
    /// Whether a live variable currently occupies the slot.
    alive: bool,
    /// Globally-unique id of the occupant — cross-checked so a handle
    /// from *another* engine can never alias this slot.
    id: VarId,
    sched: VarSched,
}

/// A pushed operation. `func` is taken exactly once when dispatched.
struct OpRecord {
    func: Option<OpFn>,
    /// Ungranted dependency count + 1 registration guard.
    pending: usize,
    /// Resolved slab slots (stale handles were dropped at push time).
    reads: Vec<u32>,
    writes: Vec<u32>,
    /// Estimated FLOPs ([`f64::NAN`] = unknown); drives the intra-op
    /// thread budget at dispatch time.
    cost: f64,
    name: &'static str,
    /// Push timestamp (profiling only; 0 when profiling was off at push
    /// time, in which case the span reports no queue wait).
    sched_us: u64,
}

#[derive(Default)]
struct SchedState {
    /// Variable slab, indexed by [`VarHandle::slot`].
    slots: Vec<Slot>,
    free_slots: Vec<u32>,
    ops: Vec<Option<OpRecord>>,
    free_ops: Vec<usize>,
}

impl SchedState {
    /// Map a handle to its live slot, or `None` when the handle is stale
    /// (variable deleted) or foreign (different engine).
    fn resolve(&self, v: &VarHandle) -> Option<u32> {
        let s = v.slot as usize;
        match self.slots.get(s) {
            Some(slot) if slot.alive && slot.gen == v.gen && slot.id == v.id => Some(v.slot),
            _ => None,
        }
    }
}

struct Inner {
    state: Mutex<SchedState>,
    pool: ThreadPool,
    /// Ops pushed but not yet completed (for `wait_all`).
    outstanding: AtomicUsize,
    done: (Mutex<()>, Condvar),
    /// Total ops ever executed (metrics).
    executed: AtomicU64,
    /// Heavy ops currently dispatched/running: the intra-op pool is
    /// divided evenly among them so N independent big kernels in flight
    /// do not oversubscribe the machine (inter-op beats intra-op when
    /// the graph offers enough parallelism; see DESIGN in rust/README).
    heavy_inflight: AtomicUsize,
}

/// Lazy multi-threaded dependency-scheduling engine (the paper's §3.2).
pub struct ThreadedEngine {
    inner: Arc<Inner>,
}

impl ThreadedEngine {
    /// Create an engine with `threads` workers.
    pub fn new(threads: usize) -> Self {
        ThreadedEngine {
            inner: Arc::new(Inner {
                state: Mutex::new(SchedState::default()),
                pool: ThreadPool::new(threads),
                outstanding: AtomicUsize::new(0),
                done: (Mutex::new(()), Condvar::new()),
                executed: AtomicU64::new(0),
                heavy_inflight: AtomicUsize::new(0),
            }),
        }
    }

    /// Number of ops executed since creation.
    pub fn ops_executed(&self) -> u64 {
        self.inner.executed.load(Ordering::Relaxed)
    }

    /// Live variable count (slab occupancy; tests).
    pub fn live_vars(&self) -> usize {
        let state = self.inner.state.lock().unwrap();
        state.slots.len() - state.free_slots.len()
    }
}

impl Inner {
    /// Grant queue-front requests on slot `s`; push newly-ready op
    /// indices into `ready`.  Caller holds the state lock.
    fn pump(state: &mut SchedState, s: u32, ready: &mut Vec<usize>) {
        loop {
            // Decide and update var-local state in a scoped borrow, then
            // touch the op table (grant) outside of it.
            let granted = {
                let sched = &mut state.slots[s as usize].sched;
                match sched.queue.front().copied() {
                    Some(Request { op, write: true })
                        if sched.active_readers == 0 && !sched.active_writer =>
                    {
                        sched.queue.pop_front();
                        sched.active_writer = true;
                        Some(op)
                    }
                    Some(Request { op, write: false }) if !sched.active_writer => {
                        sched.queue.pop_front();
                        sched.active_readers += 1;
                        Some(op)
                    }
                    _ => None,
                }
            };
            match granted {
                Some(op) => Self::grant(state, op, ready),
                None => return,
            }
        }
    }

    /// Decrement an op's pending count; collect when ready.
    fn grant(state: &mut SchedState, op: usize, ready: &mut Vec<usize>) {
        let rec = state.ops[op].as_mut().expect("op alive");
        rec.pending -= 1;
        if rec.pending == 0 {
            ready.push(op);
        }
    }

    /// Free a slot flagged for deletion once quiescent.
    fn maybe_delete(state: &mut SchedState, s: u32) {
        let slot = &mut state.slots[s as usize];
        if slot.alive && slot.sched.pending_delete && slot.sched.quiescent() {
            slot.alive = false;
            slot.gen = slot.gen.wrapping_add(1);
            state.free_slots.push(s);
        }
    }

    fn dispatch(self: &Arc<Self>, op_idx: usize) {
        let (func, cost, name, sched_us) = {
            let mut state = self.state.lock().unwrap();
            let rec = state.ops[op_idx].as_mut().expect("op alive");
            (rec.func.take().expect("func present"), rec.cost, rec.name, rec.sched_us)
        };
        let heavy = cost >= HEAVY_FLOPS;
        if heavy {
            self.heavy_inflight.fetch_add(1, Ordering::SeqCst);
        }
        let inner = Arc::clone(self);
        self.pool.execute(move || {
            // Serial-vs-parallel dispatch decision: only a *known*-heavy
            // op receives a share of the intra-op pool, divided evenly by
            // the heavy ops currently in flight.  Known-light and
            // unknown-cost ops run on this thread alone — an unknown op
            // cannot be allowed to recruit the whole pool, or N of them
            // in flight would oversubscribe the machine while bypassing
            // the heavy_inflight accounting (callers with genuinely big
            // ops pass a hint via push_costed, as the executor and
            // NDArray's compute-bound methods do).  The budget only
            // bounds *worker count*, never the chunk partition, so
            // results stay bitwise identical whatever budget is chosen.
            let budget = if heavy {
                let total = crate::util::intra_pool().threads();
                let sharing = inner.heavy_inflight.load(Ordering::SeqCst).max(1);
                (total / sharing).max(1)
            } else {
                1
            };
            let prev = crate::util::set_intra_budget(budget);
            let prof = SpanTimer::start();
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(func));
            if prof.on() {
                // queue_us = push→dispatch wait; cost hint rides in `a`.
                let q = if sched_us > 0 { prof.start_us().saturating_sub(sched_us) } else { 0 };
                let cost_hint = if cost.is_finite() { cost as u64 } else { 0 };
                prof.finish(Category::Engine, name, q, cost_hint, 0);
            }
            crate::util::set_intra_budget(prev);
            if heavy {
                inner.heavy_inflight.fetch_sub(1, Ordering::SeqCst);
            }
            if let Err(e) = result {
                super::report_op_panic("engine", name, &e);
            }
            inner.executed.fetch_add(1, Ordering::Relaxed);
            inner.complete(op_idx);
        });
    }

    /// Called on a worker thread after an op body finishes.
    fn complete(self: &Arc<Self>, op_idx: usize) {
        let mut ready = Vec::new();
        {
            let mut state = self.state.lock().unwrap();
            let rec = state.ops[op_idx].take().expect("op alive");
            state.free_ops.push(op_idx);
            for &s in &rec.writes {
                {
                    let sched = &mut state.slots[s as usize].sched;
                    debug_assert!(sched.active_writer);
                    sched.active_writer = false;
                }
                Self::pump(&mut state, s, &mut ready);
                Self::maybe_delete(&mut state, s);
            }
            for &s in &rec.reads {
                {
                    let sched = &mut state.slots[s as usize].sched;
                    debug_assert!(sched.active_readers > 0);
                    sched.active_readers -= 1;
                }
                Self::pump(&mut state, s, &mut ready);
                Self::maybe_delete(&mut state, s);
            }
        }
        for op in ready {
            self.dispatch(op);
        }
        if self.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
            let (lock, cvar) = &self.done;
            let _g = lock.lock().unwrap();
            cvar.notify_all();
        }
    }
}

/// Sentinel marking a replay's helper gate closed (see
/// [`spawn_plan_helper`] and `ThreadedEngine::run_plan`).
const GATE_CLOSED: usize = usize::MAX / 2;

/// Enqueue one replay helper onto the engine's worker pool.
///
/// The helper holds only a `Weak` plan ref and registers in `gate`
/// before taking a strong one, so neither a queued job nor a late
/// starter can pin the plan's pooled buffers past barrier retirement
/// (the barrier closes the gate).  It drains with an **idle bound**:
/// after a stretch with nothing ready (a serial segment of the plan) it
/// yields its worker back to the pool — letting unrelated engine ops
/// run — and re-enqueues itself behind them in case the plan widens
/// again.  Progress never depends on helpers (the op-completing thread
/// pops the successors it pushes), so bailing is always safe.
fn spawn_plan_helper(inner: &Arc<Inner>, w: std::sync::Weak<RunPlan>, gate: Arc<AtomicUsize>) {
    // Empty polls before a helper hands its worker back (~13 ms of
    // escalating backoff under the drain schedule).
    const HELPER_IDLE_LIMIT: u32 = 512;
    let inner2 = Arc::clone(inner);
    inner.pool.execute(move || {
        // Register before touching the plan; a closed gate means the
        // replay already retired.
        loop {
            let n = gate.load(Ordering::SeqCst);
            if n >= GATE_CLOSED {
                return;
            }
            if gate.compare_exchange(n, n + 1, Ordering::SeqCst, Ordering::SeqCst).is_ok() {
                break;
            }
        }
        let complete = match w.upgrade() {
            Some(q) => {
                let complete = q.drain_bounded(&inner2.heavy_inflight, HELPER_IDLE_LIMIT);
                drop(q);
                complete
            }
            None => true,
        };
        gate.fetch_sub(1, Ordering::SeqCst);
        if !complete {
            spawn_plan_helper(&inner2, w, gate);
        }
    });
}

impl Engine for ThreadedEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Threaded
    }

    fn new_var(&self) -> VarHandle {
        let id = super::alloc_var_id();
        let mut state = self.inner.state.lock().unwrap();
        let slot = match state.free_slots.pop() {
            Some(s) => {
                let sl = &mut state.slots[s as usize];
                debug_assert!(!sl.alive);
                sl.alive = true;
                sl.id = id;
                sl.sched = VarSched::default();
                s
            }
            None => {
                state.slots.push(Slot { gen: 0, alive: true, id, sched: VarSched::default() });
                (state.slots.len() - 1) as u32
            }
        };
        let gen = state.slots[slot as usize].gen;
        VarHandle { id, slot, gen }
    }

    fn push(&self, name: &'static str, read: Vec<VarHandle>, write: Vec<VarHandle>, func: OpFn) {
        self.push_costed(name, read, write, f64::NAN, func);
    }

    fn push_costed(
        &self,
        name: &'static str,
        read: Vec<VarHandle>,
        write: Vec<VarHandle>,
        cost_flops: f64,
        func: OpFn,
    ) {
        // Normalize outside the scheduler lock — only the slab resolution
        // below needs the lock, keeping the global critical section to
        // Vec indexing.
        let (read_h, write_h) = super::normalize_deps(&read, &write);
        // Single enabled() load on the disabled path (the overhead
        // contract); the timestamp feeds the span's queue-wait field.
        let sched_us = if profile::enabled() { profile::now_us() } else { 0 };
        self.inner.outstanding.fetch_add(1, Ordering::SeqCst);
        let mut ready = Vec::new();
        {
            let mut state = self.inner.state.lock().unwrap();
            // Resolve handles to live slots; stale/foreign handles
            // impose no ordering.  Distinct live handles map to distinct
            // slots, so the handle-level dedup above carries over.
            let writes: Vec<u32> = write_h.iter().filter_map(|v| state.resolve(v)).collect();
            let reads: Vec<u32> = read_h.iter().filter_map(|v| state.resolve(v)).collect();
            // +1 registration guard: the op cannot fire while we are still
            // appending its requests to queues.
            let rec = OpRecord {
                func: Some(func),
                pending: reads.len() + writes.len() + 1,
                reads: reads.clone(),
                writes: writes.clone(),
                cost: cost_flops,
                name,
                sched_us,
            };
            let op_idx = if let Some(i) = state.free_ops.pop() {
                state.ops[i] = Some(rec);
                i
            } else {
                state.ops.push(Some(rec));
                state.ops.len() - 1
            };
            for &s in &writes {
                let req = Request { op: op_idx, write: true };
                state.slots[s as usize].sched.queue.push_back(req);
                Inner::pump(&mut state, s, &mut ready);
            }
            for &s in &reads {
                let req = Request { op: op_idx, write: false };
                state.slots[s as usize].sched.queue.push_back(req);
                Inner::pump(&mut state, s, &mut ready);
            }
            // Release the registration guard.
            Inner::grant(&mut state, op_idx, &mut ready);
        }
        for op in ready {
            self.inner.dispatch(op);
        }
    }

    /// Native replay (ISSUE 3): one engine op grants the plan's boundary
    /// read/write sets — that is the *entire* interaction with the
    /// dynamic scheduler, preserving ordering against imperative ops and
    /// KVStore traffic — and its body replays the precompiled DAG across
    /// this engine's worker pool with lock-free countdowns.  Per plan op
    /// there is no lock, no slab, no queue: just an atomic in-degree
    /// countdown and a Treiber-stack push/pop.
    fn run_plan(&self, plan: &Arc<RunPlan>, step: u64) {
        if plan.is_empty() {
            return;
        }
        // The boundary *write* set is the serialization token that keeps
        // two replays of one plan from racing on its shared replay state
        // (countdowns, ready stack).  A plan that writes nothing has no
        // token — and nothing to gain from replay — so it takes the
        // dynamic per-op path instead.
        if plan.boundary_writes().is_empty() {
            super::push_plan_ops(self, plan, step);
            return;
        }
        let p = Arc::clone(plan);
        let inner = Arc::clone(&self.inner);
        // The barrier op itself carries no cost hint: it does no compute
        // of its own, and registering it as "heavy" for the whole replay
        // would wrongly halve the budget of every other heavy op (and of
        // the plan's own heavy ops, which account against the same
        // engine-global counter individually).
        self.push_costed(
            "run_plan",
            plan.boundary_reads().to_vec(),
            plan.boundary_writes().to_vec(),
            f64::NAN,
            Box::new(move || {
                p.begin_replay(step);
                // Recruit idle pool workers up to the plan's parallelism
                // bound; the barrier thread always participates, so a
                // 1-worker pool (or a serial-chain plan) degenerates to
                // inline sequential execution with zero cross-thread
                // traffic.  Helpers hold only a Weak ref, and take a
                // strong one only after registering in the `gate`
                // counter below — so neither a queued helper job nor a
                // late-starting one can pin the plan's pooled buffers
                // past this barrier op's retirement.
                let pool_extra = inner.pool.size().saturating_sub(1);
                let helpers = pool_extra.min(p.width().saturating_sub(1));
                let gate = Arc::new(AtomicUsize::new(0));
                for _ in 0..helpers {
                    spawn_plan_helper(&inner, Arc::downgrade(&p), Arc::clone(&gate));
                }
                p.drain(&inner.heavy_inflight);
                // Close the gate: wait for registered helpers (they may
                // hold a strong plan ref) and bar late starters from
                // entering at all.  Once this CAS succeeds, no helper
                // holds — or can ever take — a strong ref, so barrier
                // retirement + executor drop deterministically releases
                // every plan buffer back to the storage pool.  Only
                // *registered* helpers are awaited (they are running on
                // a worker and exit as soon as the drained stack is
                // empty); still-queued jobs never registered, so two
                // concurrent barriers on a saturated pool cannot
                // deadlock waiting for each other's queued helpers.
                while gate
                    .compare_exchange(0, GATE_CLOSED, Ordering::SeqCst, Ordering::SeqCst)
                    .is_err()
                {
                    std::thread::yield_now();
                }
            }),
        );
    }

    fn wait_for_var(&self, var: VarHandle) {
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        self.push("wait_for_var", vec![var], vec![], Box::new(move || {
            let _ = tx.send(());
        }));
        let _ = rx.recv();
    }

    fn wait_all(&self) {
        let (lock, cvar) = &self.inner.done;
        let mut guard = lock.lock().unwrap();
        while self.inner.outstanding.load(Ordering::SeqCst) != 0 {
            guard = cvar.wait(guard).unwrap();
        }
        drop(guard);
    }

    fn delete_var(&self, var: VarHandle) {
        let mut state = self.inner.state.lock().unwrap();
        if let Some(s) = state.resolve(&var) {
            state.slots[s as usize].sched.pending_delete = true;
            Inner::maybe_delete(&mut state, s);
        }
    }

    fn num_workers(&self) -> usize {
        self.inner.pool.size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn independent_ops_run_in_parallel() {
        // With >= 2 workers, two independent sleeps overlap: total elapsed
        // well under the serial sum. On a 1-core host threads still
        // timeshare sleeps, so this remains robust.
        let eng = ThreadedEngine::new(2);
        let a = eng.new_var();
        let b = eng.new_var();
        let t0 = std::time::Instant::now();
        for v in [a, b] {
            eng.push("sleep", vec![], vec![v], Box::new(|| {
                std::thread::sleep(Duration::from_millis(60));
            }));
        }
        eng.wait_all();
        assert!(t0.elapsed() < Duration::from_millis(110), "elapsed {:?}", t0.elapsed());
    }

    #[test]
    fn readers_share_writers_exclude() {
        let eng = ThreadedEngine::new(4);
        let v = eng.new_var();
        let readers = Arc::new(AtomicUsize::new(0));
        let max_readers = Arc::new(AtomicUsize::new(0));
        // Seed a write, then concurrent reads, then a write again.
        eng.push("w0", vec![], vec![v], Box::new(|| {}));
        for _ in 0..4 {
            let r = Arc::clone(&readers);
            let m = Arc::clone(&max_readers);
            eng.push("r", vec![v], vec![], Box::new(move || {
                let now = r.fetch_add(1, Ordering::SeqCst) + 1;
                m.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(20));
                r.fetch_sub(1, Ordering::SeqCst);
            }));
        }
        let during_write = Arc::new(AtomicUsize::new(usize::MAX));
        {
            let r = Arc::clone(&readers);
            let d = Arc::clone(&during_write);
            eng.push("w1", vec![], vec![v], Box::new(move || {
                d.store(r.load(Ordering::SeqCst), Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        assert!(max_readers.load(Ordering::SeqCst) >= 2, "reads should overlap");
        assert_eq!(during_write.load(Ordering::SeqCst), 0, "write saw active readers");
    }

    #[test]
    fn program_order_preserved_per_var() {
        // 200 increments and doublings interleaved must produce the exact
        // sequential result.
        let eng = ThreadedEngine::new(4);
        let v = eng.new_var();
        let cell = Arc::new(Mutex::new(0i64));
        let mut expected = 0i64;
        for i in 0..200 {
            let c = Arc::clone(&cell);
            if i % 3 == 0 {
                expected = expected * 2 + 1;
                eng.push("mul", vec![], vec![v], Box::new(move || {
                    let mut g = c.lock().unwrap();
                    *g = *g * 2 + 1;
                }));
            } else {
                expected += 5;
                eng.push("add", vec![], vec![v], Box::new(move || {
                    *c.lock().unwrap() += 5;
                }));
            }
        }
        eng.wait_all();
        assert_eq!(*cell.lock().unwrap(), expected);
    }

    #[test]
    fn diamond_dependency_order() {
        //    a
        //   / \
        //  b   c     b,c read a; d reads b,c. d must see both.
        //   \ /
        //    d
        let eng = ThreadedEngine::new(4);
        let (va, vb, vc, vd) = (eng.new_var(), eng.new_var(), eng.new_var(), eng.new_var());
        let log = Arc::new(Mutex::new(Vec::<&'static str>::new()));
        let push = |name: &'static str, r: Vec<VarHandle>, w: Vec<VarHandle>| {
            let l = Arc::clone(&log);
            eng.push(name, r, w, Box::new(move || {
                l.lock().unwrap().push(name);
            }));
        };
        push("a", vec![], vec![va]);
        push("b", vec![va], vec![vb]);
        push("c", vec![va], vec![vc]);
        push("d", vec![vb, vc], vec![vd]);
        eng.wait_all();
        let order = log.lock().unwrap().clone();
        let pos = |n: &str| order.iter().position(|&x| x == n).unwrap();
        assert!(pos("a") < pos("b"));
        assert!(pos("a") < pos("c"));
        assert!(pos("b") < pos("d"));
        assert!(pos("c") < pos("d"));
    }

    #[test]
    fn panicking_op_does_not_wedge_the_engine() {
        let eng = ThreadedEngine::new(2);
        let v = eng.new_var();
        eng.push("boom", vec![], vec![v], Box::new(|| panic!("intentional")));
        let ok = Arc::new(AtomicUsize::new(0));
        let o = Arc::clone(&ok);
        eng.push("after", vec![v], vec![], Box::new(move || {
            o.store(1, Ordering::SeqCst);
        }));
        eng.wait_all(); // must not hang
        assert_eq!(ok.load(Ordering::SeqCst), 1, "dependent op must still run");
    }

    #[test]
    fn costed_dispatch_budgets_intra_parallelism() {
        use crate::util::{intra_budget, intra_pool};
        let eng = ThreadedEngine::new(2);
        let v = eng.new_var();
        let light = Arc::new(AtomicUsize::new(0));
        let heavy = Arc::new(AtomicUsize::new(0));
        {
            let l = Arc::clone(&light);
            eng.push_costed("light", vec![], vec![v], 10.0, Box::new(move || {
                l.store(intra_budget(), Ordering::SeqCst);
            }));
        }
        {
            let h = Arc::clone(&heavy);
            eng.push_costed("heavy", vec![], vec![v], 1e9, Box::new(move || {
                h.store(intra_budget(), Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        // Known-cheap op: serial (inter-op parallelism only).
        assert_eq!(light.load(Ordering::SeqCst), 1);
        // Sole heavy op in flight: gets the whole intra-op pool.
        assert_eq!(heavy.load(Ordering::SeqCst), intra_pool().threads());
    }

    #[test]
    fn concurrent_heavy_ops_share_the_intra_pool() {
        use crate::util::{intra_budget, intra_pool};
        let total = intra_pool().threads();
        let eng = ThreadedEngine::new(4);
        let seen_min = Arc::new(AtomicUsize::new(usize::MAX));
        let barrier = Arc::new(std::sync::Barrier::new(2));
        // Two independent heavy ops held concurrent by a barrier.  The
        // op whose budget is computed second is guaranteed to observe
        // both heavies in flight (neither can retire before the barrier
        // releases), so the *minimum* observed budget must be at most an
        // even split of the pool.
        for _ in 0..2 {
            let v = eng.new_var();
            let m = Arc::clone(&seen_min);
            let b = Arc::clone(&barrier);
            eng.push_costed("heavy", vec![], vec![v], 1e9, Box::new(move || {
                b.wait();
                m.fetch_min(intra_budget(), Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        assert!(
            seen_min.load(Ordering::SeqCst) <= (total / 2).max(1),
            "two in-flight heavies should split the pool: saw {} of {total}",
            seen_min.load(Ordering::SeqCst)
        );
    }

    #[test]
    fn high_volume_stress() {
        let eng = ThreadedEngine::new(4);
        let vars: Vec<_> = (0..16).map(|_| eng.new_var()).collect();
        let total = Arc::new(AtomicUsize::new(0));
        let mut rng = crate::util::Rng::seed_from_u64(11);
        for _ in 0..5000 {
            let r = vars[rng.below(16)];
            let w = vars[rng.below(16)];
            let t = Arc::clone(&total);
            eng.push("op", vec![r], vec![w], Box::new(move || {
                t.fetch_add(1, Ordering::Relaxed);
            }));
        }
        eng.wait_all();
        assert_eq!(total.load(Ordering::Relaxed), 5000);
        assert_eq!(eng.ops_executed(), 5000);
    }

    // ---- slab-specific behavior --------------------------------------

    #[test]
    fn deleted_slot_is_reused_with_new_generation() {
        let eng = ThreadedEngine::new(2);
        let a = eng.new_var();
        eng.delete_var(a);
        let b = eng.new_var();
        // quiescent delete frees the slot immediately; the replacement
        // reuses it under a bumped generation
        assert_eq!(a.slot, b.slot, "slot should be recycled");
        assert_ne!(a.gen, b.gen, "generation must differ");
        assert_ne!(a.id(), b.id(), "ids stay globally unique");
        assert_eq!(eng.live_vars(), 1);
    }

    #[test]
    fn stale_handle_imposes_no_ordering_but_op_still_runs() {
        let eng = ThreadedEngine::new(2);
        let a = eng.new_var();
        let b = eng.new_var(); // keeps the engine busy-able
        eng.delete_var(a);
        let hit = Arc::new(AtomicUsize::new(0));
        {
            let h = Arc::clone(&hit);
            // writes a deleted var, reads a live one: must run normally
            eng.push("stale", vec![b], vec![a], Box::new(move || {
                h.fetch_add(1, Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        assert_eq!(hit.load(Ordering::SeqCst), 1);
        // and the recycled slot's new occupant was not disturbed
        let c = eng.new_var();
        assert_eq!(a.slot, c.slot);
        let h2 = Arc::clone(&hit);
        eng.push("fresh", vec![], vec![c], Box::new(move || {
            h2.fetch_add(10, Ordering::SeqCst);
        }));
        eng.wait_all();
        assert_eq!(hit.load(Ordering::SeqCst), 11);
    }

    #[test]
    fn delete_frees_slot_only_after_pending_ops() {
        let eng = ThreadedEngine::new(2);
        let v = eng.new_var();
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        eng.push("op", vec![], vec![v], Box::new(move || {
            std::thread::sleep(Duration::from_millis(20));
            d.store(7, Ordering::SeqCst);
        }));
        eng.delete_var(v);
        eng.wait_all();
        assert_eq!(done.load(Ordering::SeqCst), 7);
        assert_eq!(eng.live_vars(), 0, "slot reclaimed after quiescence");
    }

    #[test]
    fn slab_churn_many_generations() {
        // Allocate/delete through the same slots repeatedly; ops on the
        // current generation always run, old handles never interfere.
        let eng = ThreadedEngine::new(2);
        let total = Arc::new(AtomicUsize::new(0));
        let mut old: Vec<VarHandle> = Vec::new();
        for round in 0..50 {
            let v = eng.new_var();
            let t = Arc::clone(&total);
            eng.push("inc", vec![], vec![v], Box::new(move || {
                t.fetch_add(1, Ordering::Relaxed);
            }));
            if let Some(stale) = old.get(round % old.len().max(1)).copied() {
                // pushing on stale handles is harmless
                let t = Arc::clone(&total);
                eng.push("stale", vec![stale], vec![], Box::new(move || {
                    t.fetch_add(1, Ordering::Relaxed);
                }));
            }
            eng.delete_var(v);
            old.push(v);
        }
        eng.wait_all();
        assert!(total.load(Ordering::Relaxed) >= 50);
        assert_eq!(eng.live_vars(), 0);
    }
}

//! The multi-threaded dependency engine.
//!
//! Scheduling model (a faithful, compact re-implementation of MXNet's
//! `ThreadedEngine`): each variable keeps a FIFO queue of pending
//! dependency requests.  A *read* request is granted when it reaches the
//! logical front (no earlier writer queued) and no writer is active; any
//! number of reads may be active at once.  A *write* request is granted
//! only when it is at the front and the variable is fully quiescent.  An
//! operation becomes ready when all of its per-variable requests are
//! granted, at which point it is dispatched to the worker pool; on
//! completion each variable is notified, which may grant the next queued
//! requests.
//!
//! FIFO granting per variable gives two system properties the paper relies
//! on: (1) program order is preserved per resource, so the imperative
//! `w -= eta * g` after a graph backward observes the right gradient, and
//! (2) writers cannot starve.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::{Engine, EngineKind, OpFn, VarHandle, VarId};
use crate::util::ThreadPool;

/// One queued dependency request: op index + whether it mutates the var.
#[derive(Debug, Clone, Copy)]
struct Request {
    op: usize,
    write: bool,
}

/// Per-variable scheduling state.
#[derive(Debug, Default)]
struct VarSched {
    queue: VecDeque<Request>,
    active_readers: usize,
    active_writer: bool,
    /// Set by `delete_var`; the entry is dropped once fully quiescent.
    pending_delete: bool,
}

impl VarSched {
    fn quiescent(&self) -> bool {
        self.queue.is_empty() && self.active_readers == 0 && !self.active_writer
    }
}

/// A pushed operation. `func` is taken exactly once when dispatched.
struct OpRecord {
    func: Option<OpFn>,
    /// Ungranted dependency count + 1 registration guard.
    pending: usize,
    reads: Vec<VarId>,
    writes: Vec<VarId>,
    /// Estimated FLOPs ([`f64::NAN`] = unknown); drives the intra-op
    /// thread budget at dispatch time.
    cost: f64,
    #[allow(dead_code)]
    name: &'static str,
}

/// FLOP estimate above which an op counts as "heavy": it gets a share of
/// the intra-op pool instead of running on one thread (~0.5 ms of serial
/// compute at a 2 GFLOP/s single-core floor).
const HEAVY_FLOPS: f64 = 1e6;

#[derive(Default)]
struct SchedState {
    vars: HashMap<VarId, VarSched>,
    ops: Vec<Option<OpRecord>>,
    free_ops: Vec<usize>,
}

struct Inner {
    state: Mutex<SchedState>,
    pool: ThreadPool,
    /// Ops pushed but not yet completed (for `wait_all`).
    outstanding: AtomicUsize,
    done: (Mutex<()>, Condvar),
    /// Total ops ever executed (metrics).
    executed: AtomicU64,
    /// Heavy ops currently dispatched/running: the intra-op pool is
    /// divided evenly among them so N independent big kernels in flight
    /// do not oversubscribe the machine (inter-op beats intra-op when
    /// the graph offers enough parallelism; see DESIGN in rust/README).
    heavy_inflight: AtomicUsize,
}

/// Lazy multi-threaded dependency-scheduling engine (the paper's §3.2).
pub struct ThreadedEngine {
    inner: Arc<Inner>,
}

impl ThreadedEngine {
    /// Create an engine with `threads` workers.
    pub fn new(threads: usize) -> Self {
        ThreadedEngine {
            inner: Arc::new(Inner {
                state: Mutex::new(SchedState::default()),
                pool: ThreadPool::new(threads),
                outstanding: AtomicUsize::new(0),
                done: (Mutex::new(()), Condvar::new()),
                executed: AtomicU64::new(0),
                heavy_inflight: AtomicUsize::new(0),
            }),
        }
    }

    /// Number of ops executed since creation.
    pub fn ops_executed(&self) -> u64 {
        self.inner.executed.load(Ordering::Relaxed)
    }
}

impl Inner {
    /// Grant queue-front requests on `var`; push newly-ready op indices
    /// into `ready`.  Caller holds the state lock.
    fn pump(state: &mut SchedState, var: VarId, ready: &mut Vec<usize>) {
        loop {
            // Decide and update var-local state in a scoped borrow, then
            // touch the op table (grant) outside of it.
            let granted = {
                let sched = match state.vars.get_mut(&var) {
                    Some(s) => s,
                    None => return,
                };
                match sched.queue.front().copied() {
                    Some(Request { op, write: true })
                        if sched.active_readers == 0 && !sched.active_writer =>
                    {
                        sched.queue.pop_front();
                        sched.active_writer = true;
                        Some(op)
                    }
                    Some(Request { op, write: false }) if !sched.active_writer => {
                        sched.queue.pop_front();
                        sched.active_readers += 1;
                        Some(op)
                    }
                    _ => None,
                }
            };
            match granted {
                Some(op) => Self::grant(state, op, ready),
                None => return,
            }
        }
    }

    /// Decrement an op's pending count; collect when ready.
    fn grant(state: &mut SchedState, op: usize, ready: &mut Vec<usize>) {
        let rec = state.ops[op].as_mut().expect("op alive");
        rec.pending -= 1;
        if rec.pending == 0 {
            ready.push(op);
        }
    }

    /// Try to garbage-collect a var flagged for deletion.
    fn maybe_delete(state: &mut SchedState, var: VarId) {
        if let Some(s) = state.vars.get(&var) {
            if s.pending_delete && s.quiescent() {
                state.vars.remove(&var);
            }
        }
    }

    fn dispatch(self: &Arc<Self>, op_idx: usize) {
        let (func, cost) = {
            let mut state = self.state.lock().unwrap();
            let rec = state.ops[op_idx].as_mut().expect("op alive");
            (rec.func.take().expect("func present"), rec.cost)
        };
        let heavy = cost >= HEAVY_FLOPS;
        if heavy {
            self.heavy_inflight.fetch_add(1, Ordering::SeqCst);
        }
        let inner = Arc::clone(self);
        self.pool.execute(move || {
            // Serial-vs-parallel dispatch decision: only a *known*-heavy
            // op receives a share of the intra-op pool, divided evenly by
            // the heavy ops currently in flight.  Known-light and
            // unknown-cost ops run on this thread alone — an unknown op
            // cannot be allowed to recruit the whole pool, or N of them
            // in flight would oversubscribe the machine while bypassing
            // the heavy_inflight accounting (callers with genuinely big
            // ops pass a hint via push_costed, as the executor and
            // NDArray's compute-bound methods do).  The budget only
            // bounds *worker count*, never the chunk partition, so
            // results stay bitwise identical whatever budget is chosen.
            let budget = if heavy {
                let total = crate::util::intra_pool().threads();
                let sharing = inner.heavy_inflight.load(Ordering::SeqCst).max(1);
                (total / sharing).max(1)
            } else {
                1
            };
            let prev = crate::util::set_intra_budget(budget);
            // A panicking op must still complete, or its dependents (and
            // every wait_all) would block forever.  The panic is reported
            // and the schedule carries on — matching MXNet, where a failed
            // kernel logs and the engine keeps serving other ops.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(func));
            crate::util::set_intra_budget(prev);
            if heavy {
                inner.heavy_inflight.fetch_sub(1, Ordering::SeqCst);
            }
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| e.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic".into());
                eprintln!("mixnet engine: op panicked: {msg}");
            }
            inner.executed.fetch_add(1, Ordering::Relaxed);
            inner.complete(op_idx);
        });
    }

    /// Called on a worker thread after an op body finishes.
    fn complete(self: &Arc<Self>, op_idx: usize) {
        let mut ready = Vec::new();
        {
            let mut state = self.state.lock().unwrap();
            let rec = state.ops[op_idx].take().expect("op alive");
            state.free_ops.push(op_idx);
            for &v in &rec.writes {
                if let Some(s) = state.vars.get_mut(&v) {
                    debug_assert!(s.active_writer);
                    s.active_writer = false;
                }
                Self::pump(&mut state, v, &mut ready);
                Self::maybe_delete(&mut state, v);
            }
            for &v in &rec.reads {
                if let Some(s) = state.vars.get_mut(&v) {
                    debug_assert!(s.active_readers > 0);
                    s.active_readers -= 1;
                }
                Self::pump(&mut state, v, &mut ready);
                Self::maybe_delete(&mut state, v);
            }
        }
        for op in ready {
            self.dispatch(op);
        }
        if self.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
            let (lock, cvar) = &self.done;
            let _g = lock.lock().unwrap();
            cvar.notify_all();
        }
    }
}

/// Normalize dependency lists: dedupe, and drop reads that are also
/// writes (a write subsumes a read).
fn normalize(read: Vec<VarHandle>, write: Vec<VarHandle>) -> (Vec<VarId>, Vec<VarId>) {
    let mut writes: Vec<VarId> = write.into_iter().map(|v| v.0).collect();
    writes.sort_unstable();
    writes.dedup();
    let mut reads: Vec<VarId> = read
        .into_iter()
        .map(|v| v.0)
        .filter(|id| writes.binary_search(id).is_err())
        .collect();
    reads.sort_unstable();
    reads.dedup();
    (reads, writes)
}

impl Engine for ThreadedEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Threaded
    }

    fn new_var(&self) -> VarHandle {
        let id = super::alloc_var_id();
        let mut state = self.inner.state.lock().unwrap();
        state.vars.insert(id, VarSched::default());
        VarHandle(id)
    }

    fn push(&self, name: &'static str, read: Vec<VarHandle>, write: Vec<VarHandle>, func: OpFn) {
        self.push_costed(name, read, write, f64::NAN, func);
    }

    fn push_costed(
        &self,
        name: &'static str,
        read: Vec<VarHandle>,
        write: Vec<VarHandle>,
        cost_flops: f64,
        func: OpFn,
    ) {
        let (reads, writes) = normalize(read, write);
        self.inner.outstanding.fetch_add(1, Ordering::SeqCst);
        let mut ready = Vec::new();
        let op_idx;
        {
            let mut state = self.inner.state.lock().unwrap();
            // +1 registration guard: the op cannot fire while we are still
            // appending its requests to queues.
            let rec = OpRecord {
                func: Some(func),
                pending: reads.len() + writes.len() + 1,
                reads: reads.clone(),
                writes: writes.clone(),
                cost: cost_flops,
                name,
            };
            op_idx = if let Some(i) = state.free_ops.pop() {
                state.ops[i] = Some(rec);
                i
            } else {
                state.ops.push(Some(rec));
                state.ops.len() - 1
            };
            for &v in &writes {
                state.vars.entry(v).or_default().queue.push_back(Request { op: op_idx, write: true });
                Inner::pump(&mut state, v, &mut ready);
            }
            for &v in &reads {
                state.vars.entry(v).or_default().queue.push_back(Request { op: op_idx, write: false });
                Inner::pump(&mut state, v, &mut ready);
            }
            // Release the registration guard.
            Inner::grant(&mut state, op_idx, &mut ready);
        }
        for op in ready {
            self.inner.dispatch(op);
        }
    }

    fn wait_for_var(&self, var: VarHandle) {
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        self.push("wait_for_var", vec![var], vec![], Box::new(move || {
            let _ = tx.send(());
        }));
        let _ = rx.recv();
    }

    fn wait_all(&self) {
        let (lock, cvar) = &self.inner.done;
        let mut guard = lock.lock().unwrap();
        while self.inner.outstanding.load(Ordering::SeqCst) != 0 {
            guard = cvar.wait(guard).unwrap();
        }
        drop(guard);
    }

    fn delete_var(&self, var: VarHandle) {
        let mut state = self.inner.state.lock().unwrap();
        if let Some(s) = state.vars.get_mut(&var.0) {
            s.pending_delete = true;
        }
        Inner::maybe_delete(&mut state, var.0);
    }

    fn num_workers(&self) -> usize {
        self.inner.pool.size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn independent_ops_run_in_parallel() {
        // With >= 2 workers, two independent sleeps overlap: total elapsed
        // well under the serial sum. On a 1-core host threads still
        // timeshare sleeps, so this remains robust.
        let eng = ThreadedEngine::new(2);
        let a = eng.new_var();
        let b = eng.new_var();
        let t0 = std::time::Instant::now();
        for v in [a, b] {
            eng.push("sleep", vec![], vec![v], Box::new(|| {
                std::thread::sleep(Duration::from_millis(60));
            }));
        }
        eng.wait_all();
        assert!(t0.elapsed() < Duration::from_millis(110), "elapsed {:?}", t0.elapsed());
    }

    #[test]
    fn readers_share_writers_exclude() {
        let eng = ThreadedEngine::new(4);
        let v = eng.new_var();
        let readers = Arc::new(AtomicUsize::new(0));
        let max_readers = Arc::new(AtomicUsize::new(0));
        // Seed a write, then concurrent reads, then a write again.
        eng.push("w0", vec![], vec![v], Box::new(|| {}));
        for _ in 0..4 {
            let r = Arc::clone(&readers);
            let m = Arc::clone(&max_readers);
            eng.push("r", vec![v], vec![], Box::new(move || {
                let now = r.fetch_add(1, Ordering::SeqCst) + 1;
                m.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(20));
                r.fetch_sub(1, Ordering::SeqCst);
            }));
        }
        let during_write = Arc::new(AtomicUsize::new(usize::MAX));
        {
            let r = Arc::clone(&readers);
            let d = Arc::clone(&during_write);
            eng.push("w1", vec![], vec![v], Box::new(move || {
                d.store(r.load(Ordering::SeqCst), Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        assert!(max_readers.load(Ordering::SeqCst) >= 2, "reads should overlap");
        assert_eq!(during_write.load(Ordering::SeqCst), 0, "write saw active readers");
    }

    #[test]
    fn program_order_preserved_per_var() {
        // 200 increments and doublings interleaved must produce the exact
        // sequential result.
        let eng = ThreadedEngine::new(4);
        let v = eng.new_var();
        let cell = Arc::new(Mutex::new(0i64));
        let mut expected = 0i64;
        for i in 0..200 {
            let c = Arc::clone(&cell);
            if i % 3 == 0 {
                expected = expected * 2 + 1;
                eng.push("mul", vec![], vec![v], Box::new(move || {
                    let mut g = c.lock().unwrap();
                    *g = *g * 2 + 1;
                }));
            } else {
                expected += 5;
                eng.push("add", vec![], vec![v], Box::new(move || {
                    *c.lock().unwrap() += 5;
                }));
            }
        }
        eng.wait_all();
        assert_eq!(*cell.lock().unwrap(), expected);
    }

    #[test]
    fn diamond_dependency_order() {
        //    a
        //   / \
        //  b   c     b,c read a; d reads b,c. d must see both.
        //   \ /
        //    d
        let eng = ThreadedEngine::new(4);
        let (va, vb, vc, vd) = (eng.new_var(), eng.new_var(), eng.new_var(), eng.new_var());
        let log = Arc::new(Mutex::new(Vec::<&'static str>::new()));
        let push = |name: &'static str, r: Vec<VarHandle>, w: Vec<VarHandle>| {
            let l = Arc::clone(&log);
            eng.push(name, r, w, Box::new(move || {
                l.lock().unwrap().push(name);
            }));
        };
        push("a", vec![], vec![va]);
        push("b", vec![va], vec![vb]);
        push("c", vec![va], vec![vc]);
        push("d", vec![vb, vc], vec![vd]);
        eng.wait_all();
        let order = log.lock().unwrap().clone();
        let pos = |n: &str| order.iter().position(|&x| x == n).unwrap();
        assert!(pos("a") < pos("b"));
        assert!(pos("a") < pos("c"));
        assert!(pos("b") < pos("d"));
        assert!(pos("c") < pos("d"));
    }

    #[test]
    fn panicking_op_does_not_wedge_the_engine() {
        let eng = ThreadedEngine::new(2);
        let v = eng.new_var();
        eng.push("boom", vec![], vec![v], Box::new(|| panic!("intentional")));
        let ok = Arc::new(AtomicUsize::new(0));
        let o = Arc::clone(&ok);
        eng.push("after", vec![v], vec![], Box::new(move || {
            o.store(1, Ordering::SeqCst);
        }));
        eng.wait_all(); // must not hang
        assert_eq!(ok.load(Ordering::SeqCst), 1, "dependent op must still run");
    }

    #[test]
    fn costed_dispatch_budgets_intra_parallelism() {
        use crate::util::{intra_budget, intra_pool};
        let eng = ThreadedEngine::new(2);
        let v = eng.new_var();
        let light = Arc::new(AtomicUsize::new(0));
        let heavy = Arc::new(AtomicUsize::new(0));
        {
            let l = Arc::clone(&light);
            eng.push_costed("light", vec![], vec![v], 10.0, Box::new(move || {
                l.store(intra_budget(), Ordering::SeqCst);
            }));
        }
        {
            let h = Arc::clone(&heavy);
            eng.push_costed("heavy", vec![], vec![v], 1e9, Box::new(move || {
                h.store(intra_budget(), Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        // Known-cheap op: serial (inter-op parallelism only).
        assert_eq!(light.load(Ordering::SeqCst), 1);
        // Sole heavy op in flight: gets the whole intra-op pool.
        assert_eq!(heavy.load(Ordering::SeqCst), intra_pool().threads());
    }

    #[test]
    fn concurrent_heavy_ops_share_the_intra_pool() {
        use crate::util::{intra_budget, intra_pool};
        let total = intra_pool().threads();
        let eng = ThreadedEngine::new(4);
        let seen_min = Arc::new(AtomicUsize::new(usize::MAX));
        let barrier = Arc::new(std::sync::Barrier::new(2));
        // Two independent heavy ops held concurrent by a barrier.  The
        // op whose budget is computed second is guaranteed to observe
        // both heavies in flight (neither can retire before the barrier
        // releases), so the *minimum* observed budget must be at most an
        // even split of the pool.
        for _ in 0..2 {
            let v = eng.new_var();
            let m = Arc::clone(&seen_min);
            let b = Arc::clone(&barrier);
            eng.push_costed("heavy", vec![], vec![v], 1e9, Box::new(move || {
                b.wait();
                m.fetch_min(intra_budget(), Ordering::SeqCst);
            }));
        }
        eng.wait_all();
        assert!(
            seen_min.load(Ordering::SeqCst) <= (total / 2).max(1),
            "two in-flight heavies should split the pool: saw {} of {total}",
            seen_min.load(Ordering::SeqCst)
        );
    }

    #[test]
    fn high_volume_stress() {
        let eng = ThreadedEngine::new(4);
        let vars: Vec<_> = (0..16).map(|_| eng.new_var()).collect();
        let total = Arc::new(AtomicUsize::new(0));
        let mut rng = crate::util::Rng::seed_from_u64(11);
        for _ in 0..5000 {
            let r = vars[rng.below(16)];
            let w = vars[rng.below(16)];
            let t = Arc::clone(&total);
            eng.push("op", vec![r], vec![w], Box::new(move || {
                t.fetch_add(1, Ordering::Relaxed);
            }));
        }
        eng.wait_all();
        assert_eq!(total.load(Ordering::Relaxed), 5000);
        assert_eq!(eng.ops_executed(), 5000);
    }
}

//! Static run-plans (ISSUE 3): the dependency schedule of a bound graph,
//! compiled **once** and replayed every step.
//!
//! The dynamic engine re-derives the schedule on every push: each op
//! takes the global scheduler lock, appends a request to every operand's
//! queue, and completion walks those queues again.  For a bound executor
//! that is pure waste — the op sequence and its read/write sets never
//! change after bind, so the whole dependency structure can be
//! precomputed (the paper's §3.1/§4.2 static-graph argument; TensorFlow
//! makes the same one).  A [`RunPlan`] is that precomputation: a flat,
//! immutable DAG — successor lists plus an initial in-degree per op,
//! derived from the same read/write sets the dynamic path uses
//! (RAW/WAR/WAW edges; reads never order against reads).
//!
//! **Replay** walks the DAG with per-op atomic countdown counters and a
//! lock-free ready stack (tagged Treiber stack: `(version, index)`
//! packed in one `AtomicU64`, so the classic ABA hazard of re-pushed
//! indices across replays is excluded).  No mutex, no hash map, no
//! per-op queue traffic — per-op scheduling cost is a handful of atomic
//! ops.
//!
//! **Interop.** A plan does not bypass engine ordering: the engine that
//! replays it brackets the whole replay behind the plan's *boundary*
//! read/write var sets (see `ThreadedEngine::run_plan`), so imperative
//! NDArray ops (`w -= eta * g`), KVStore push/pull and other executors
//! on the same engine still serialize correctly against every buffer the
//! plan touches.  Engines without a native replay path fall back to
//! pushing each plan op through the ordinary dynamic path
//! ([`RunPlan::push_parts`]) — same ops, same read/write sets, same
//! results.
//!
//! A plan replays **one instance at a time**; the engine enforces this
//! for free, because two replays of the same plan write the same
//! boundary vars and are therefore serialized like any two conflicting
//! ops.  The mutable replay state (countdowns, ready stack, remaining
//! counter) is reset at the start of each replay under that exclusion.
//!
//! **Grad-retirement notification.**  Because a replay holds the
//! boundary write grant for the *whole* pass, an external op that reads
//! a gradient var cannot start until the entire backward plan retires —
//! which would defeat per-layer communication overlap.  The executor
//! therefore composes notification into the plan bodies themselves: the
//! body of each gradient's last-writer op fires the executor's
//! [grad-ready hook](crate::executor::GradReadyHook) right after the
//! kernel runs, *inside* the replay, where the final value is written
//! and reading it is race-free.  The data-parallel trainer uses this to
//! start KVStore pushes mid-backward (paper §5).

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use super::{OpFn, VarHandle};

/// A replayable op body: invoked once per replay with the step number.
pub type PlanBody = Arc<dyn Fn(u64) + Send + Sync>;

/// `(name, reads, writes, cost, one-shot closure)` — what a dynamic
/// [`Engine::push_costed`](super::Engine::push_costed) takes for one
/// plan op (see [`RunPlan::push_parts`]).
pub type PushParts = (&'static str, Vec<VarHandle>, Vec<VarHandle>, f64, OpFn);

/// One op as submitted to [`RunPlan::compile`]: the same (name, reads,
/// writes, cost) tuple a dynamic `push_costed` would take, with a
/// reusable body instead of a one-shot closure.
pub struct PlanOpSpec {
    /// Display name (same convention as `Engine::push`).
    pub name: &'static str,
    /// Vars read by the op.
    pub reads: Vec<VarHandle>,
    /// Vars mutated by the op (subsumes reads of the same var).
    pub writes: Vec<VarHandle>,
    /// Estimated FLOPs (`f64::NAN` = unknown) for intra-op budgeting.
    pub cost: f64,
    /// The op body.
    pub body: PlanBody,
}

struct PlanOp {
    name: &'static str,
    body: PlanBody,
    cost: f64,
    heavy: bool,
    /// Ops unblocked by this op's completion.
    succ: Vec<u32>,
    /// Number of distinct predecessors.
    indegree: u32,
    /// Original read/write sets, kept for the dynamic fallback path.
    reads: Vec<VarHandle>,
    writes: Vec<VarHandle>,
}

/// Ready-stack nil sentinel.
const NIL: u32 = u32::MAX;

#[inline]
fn pack(ver: u32, idx: u32) -> u64 {
    ((ver as u64) << 32) | idx as u64
}

#[inline]
fn unpack(v: u64) -> (u32, u32) {
    ((v >> 32) as u32, v as u32)
}

/// A compiled, immutable dependency DAG with reusable replay state.
pub struct RunPlan {
    ops: Vec<PlanOp>,
    /// Ops with no predecessors (replay seeds).
    roots: Vec<u32>,
    /// Dedup'd union of all vars read (minus written) / written by any
    /// op: the surface the engine orders against other work.
    boundary_reads: Vec<VarHandle>,
    boundary_writes: Vec<VarHandle>,
    /// Sum of known per-op costs (informational; heavy-op budgeting is
    /// per plan op against the engine-global counter, never the barrier).
    total_cost: f64,
    /// Max ops on one topological level — an upper-bound estimate of
    /// useful replay workers.
    width: usize,
    // ---- mutable replay state (one replay at a time) -----------------
    countdown: Vec<AtomicU32>,
    next: Vec<AtomicU32>,
    /// Tagged Treiber-stack head: (version, top index).
    head: AtomicU64,
    /// Ops not yet completed in the current replay.
    remaining: AtomicUsize,
    /// Step number handed to op bodies (set by `begin_replay`).
    step: AtomicU64,
}

impl RunPlan {
    /// Compile a sequence of op specs (in program order) into a plan.
    ///
    /// Edges are derived exactly as the dynamic engine would order the
    /// same pushes: an op depends on the latest earlier writer of
    /// anything it touches (RAW/WAW) and on every earlier reader of
    /// anything it writes (WAR).  Vars listed in both sets are treated
    /// as write-only, like `Engine::push`.
    pub fn compile(specs: Vec<PlanOpSpec>) -> RunPlan {
        use std::collections::HashMap;
        let n = specs.len();
        let mut last_writer: HashMap<u64, usize> = HashMap::new();
        let mut readers: HashMap<u64, Vec<usize>> = HashMap::new();
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut all_reads: Vec<VarHandle> = Vec::new();
        let mut all_writes: Vec<VarHandle> = Vec::new();
        let mut norm: Vec<(Vec<VarHandle>, Vec<VarHandle>)> = Vec::with_capacity(n);

        for (i, s) in specs.iter().enumerate() {
            // same normalization as the dynamic push path, by construction
            let (reads, writes) = super::normalize_deps(&s.reads, &s.writes);

            for v in &reads {
                if let Some(&w) = last_writer.get(&v.id()) {
                    preds[i].push(w);
                }
                readers.entry(v.id()).or_default().push(i);
            }
            for v in &writes {
                if let Some(rs) = readers.get_mut(&v.id()) {
                    preds[i].append(rs);
                }
                if let Some(&w) = last_writer.get(&v.id()) {
                    preds[i].push(w);
                }
                last_writer.insert(v.id(), i);
            }
            all_reads.extend(reads.iter().copied());
            all_writes.extend(writes.iter().copied());
            norm.push((reads, writes));
        }

        all_writes.sort_unstable();
        all_writes.dedup();
        all_reads.sort_unstable();
        all_reads.dedup();
        all_reads.retain(|v| all_writes.binary_search(v).is_err());

        let mut succ: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut indegree: Vec<u32> = vec![0; n];
        for (i, p) in preds.iter_mut().enumerate() {
            p.sort_unstable();
            p.dedup();
            indegree[i] = p.len() as u32;
            for &q in p.iter() {
                succ[q].push(i as u32);
            }
        }

        // Topological levels for the width estimate (specs arrive in
        // program order, which is topological by construction).
        let mut level: Vec<usize> = vec![0; n];
        let mut level_count: HashMap<usize, usize> = HashMap::new();
        for i in 0..n {
            let l = preds[i].iter().map(|&p| level[p] + 1).max().unwrap_or(0);
            level[i] = l;
            *level_count.entry(l).or_insert(0) += 1;
        }
        let width = level_count.values().copied().max().unwrap_or(0);

        let roots: Vec<u32> = (0..n).filter(|&i| indegree[i] == 0).map(|i| i as u32).collect();
        let total_cost: f64 =
            specs.iter().map(|s| if s.cost.is_finite() { s.cost } else { 0.0 }).sum();

        let ops: Vec<PlanOp> = specs
            .into_iter()
            .zip(norm)
            .zip(indegree.iter().zip(succ))
            .map(|((s, (reads, writes)), (&indeg, sc))| PlanOp {
                name: s.name,
                body: s.body,
                cost: s.cost,
                heavy: s.cost >= super::HEAVY_FLOPS,
                succ: sc,
                indegree: indeg,
                reads,
                writes,
            })
            .collect();

        RunPlan {
            countdown: ops.iter().map(|o| AtomicU32::new(o.indegree)).collect(),
            next: (0..n).map(|_| AtomicU32::new(NIL)).collect(),
            head: AtomicU64::new(pack(0, NIL)),
            remaining: AtomicUsize::new(0),
            step: AtomicU64::new(0),
            ops,
            roots,
            boundary_reads: all_reads,
            boundary_writes: all_writes,
            total_cost,
            width,
        }
    }

    /// Number of ops in the plan.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Whether the plan contains no ops.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Vars the plan reads from outside (dedup'd, minus written vars).
    pub fn boundary_reads(&self) -> &[VarHandle] {
        &self.boundary_reads
    }

    /// Vars any plan op writes.
    pub fn boundary_writes(&self) -> &[VarHandle] {
        &self.boundary_writes
    }

    /// Sum of known per-op FLOP estimates.
    pub fn total_cost(&self) -> f64 {
        self.total_cost
    }

    /// Upper bound on ops that can run concurrently (max topological
    /// level size) — sizes the replay worker fan-out.
    pub fn width(&self) -> usize {
        self.width
    }

    /// The pieces needed to push op `i` through the dynamic path: the
    /// fallback for engines without native replay.  The returned closure
    /// invokes the reusable body with `step`.
    pub fn push_parts(&self, i: usize, step: u64) -> PushParts {
        let op = &self.ops[i];
        let body = Arc::clone(&op.body);
        (op.name, op.reads.clone(), op.writes.clone(), op.cost, Box::new(move || body(step)))
    }

    // ------------------------------------------------------------------
    // lock-free replay (driven by the owning engine)
    // ------------------------------------------------------------------

    fn push_ready(&self, i: u32) {
        loop {
            let cur = self.head.load(Ordering::Acquire);
            let (ver, top) = unpack(cur);
            self.next[i as usize].store(top, Ordering::Relaxed);
            if self
                .head
                .compare_exchange_weak(
                    cur,
                    pack(ver.wrapping_add(1), i),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return;
            }
        }
    }

    fn pop_ready(&self) -> Option<u32> {
        loop {
            let cur = self.head.load(Ordering::Acquire);
            let (ver, top) = unpack(cur);
            if top == NIL {
                return None;
            }
            let nxt = self.next[top as usize].load(Ordering::Relaxed);
            if self
                .head
                .compare_exchange_weak(
                    cur,
                    pack(ver.wrapping_add(1), nxt),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return Some(top);
            }
        }
    }

    /// Arm the replay state and seed the ready stack with the roots.
    ///
    /// Caller contract (upheld by the engines): at most one replay of a
    /// given plan is in flight at a time, and `begin_replay` happens
    /// strictly before the corresponding `drain` calls observe work.
    pub(crate) fn begin_replay(&self, step: u64) {
        self.step.store(step, Ordering::Relaxed);
        for (c, op) in self.countdown.iter().zip(&self.ops) {
            c.store(op.indegree, Ordering::Relaxed);
        }
        // Publish the resets before any root becomes poppable: the
        // release CAS in push_ready pairs with the acquire load in
        // pop_ready.
        self.remaining.store(self.ops.len(), Ordering::Release);
        for &r in &self.roots {
            self.push_ready(r);
        }
    }

    /// Claim and execute ready ops until the replay is complete.  Any
    /// number of threads may drain concurrently; each returns once every
    /// op of the current replay has finished.  A panicking body is
    /// caught and reported so dependents (and the engine) never wedge.
    ///
    /// `heavy_inflight` is the **engine-global** heavy-op counter (the
    /// same one the dynamic dispatch path uses), so heavy plan ops split
    /// the intra-op pool against everything else in flight — concurrent
    /// replays of other plans and imperative heavy ops included.
    pub(crate) fn drain(&self, heavy_inflight: &AtomicUsize) {
        // Unbounded: re-enter on the (astronomically rare) idle-counter
        // saturation rather than ever returning with work in flight.
        while !self.drain_bounded(heavy_inflight, u32::MAX - 1) {}
    }

    /// [`RunPlan::drain`] with an idle bound, for *helper* threads that
    /// borrow an engine worker: after `idle_limit` consecutive empty
    /// polls the helper returns `false` (replay still in flight) so its
    /// worker can serve unrelated engine ops instead of camping through
    /// a long serial stretch of the plan.  Progress never depends on
    /// helpers: the thread that completes an op pushes and then pops its
    /// successors itself, and the barrier thread drains unbounded.
    pub(crate) fn drain_bounded(&self, heavy_inflight: &AtomicUsize, idle_limit: u32) -> bool {
        let mut idle = 0u32;
        loop {
            match self.pop_ready() {
                Some(i) => {
                    idle = 0;
                    self.run_op(i as usize, heavy_inflight);
                }
                None => {
                    if self.remaining.load(Ordering::Acquire) == 0 {
                        return true;
                    }
                    if idle >= idle_limit {
                        return false;
                    }
                    // Ops are in flight on other threads; their
                    // successors will appear on the stack.  Escalating
                    // backoff: spin, then yield, then doze — a long
                    // serial kernel must not have an idle drainer
                    // burning the cores its intra-op workers need.
                    idle = idle.saturating_add(1);
                    if idle < 64 {
                        std::hint::spin_loop();
                    } else if idle < 256 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(std::time::Duration::from_micros(50));
                    }
                }
            }
        }
    }

    fn run_op(&self, i: usize, heavy_inflight: &AtomicUsize) {
        let op = &self.ops[i];
        // Intra-op thread budget, mirroring the dynamic engine's
        // dispatch policy: known-heavy ops split the intra pool among
        // the heavy ops in flight; light/unknown ops run serial.
        let budget = if op.heavy {
            let total = crate::util::intra_pool().threads();
            let sharing = heavy_inflight.fetch_add(1, Ordering::SeqCst) + 1;
            (total / sharing).max(1)
        } else {
            1
        };
        let prev = crate::util::set_intra_budget(budget);
        let step = self.step.load(Ordering::Relaxed);
        let prof = crate::profile::SpanTimer::start();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (op.body)(step)));
        // Replay op span: `a` = replay step, `b` = op index — the pair a
        // well-formedness test uses to assert exactly-once-per-replay.
        prof.finish(crate::profile::Category::Plan, op.name, 0, step, i as u64);
        crate::util::set_intra_budget(prev);
        if op.heavy {
            heavy_inflight.fetch_sub(1, Ordering::SeqCst);
        }
        if let Err(e) = result {
            super::report_op_panic("plan", op.name, &e);
        }
        // AcqRel chains each predecessor's writes through the counter to
        // whichever thread takes it to zero and publishes the successor.
        for &s in &op.succ {
            if self.countdown[s as usize].fetch_sub(1, Ordering::AcqRel) == 1 {
                self.push_ready(s);
            }
        }
        self.remaining.fetch_sub(1, Ordering::AcqRel);
    }
}

impl std::fmt::Debug for RunPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "RunPlan({} ops, {} roots, width {}, {} boundary vars)",
            self.ops.len(),
            self.roots.len(),
            self.width,
            self.boundary_reads.len() + self.boundary_writes.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind, EngineRef};
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;

    fn spec(
        name: &'static str,
        reads: Vec<VarHandle>,
        writes: Vec<VarHandle>,
        body: impl Fn(u64) + Send + Sync + 'static,
    ) -> PlanOpSpec {
        PlanOpSpec { name, reads, writes, cost: f64::NAN, body: Arc::new(body) }
    }

    fn diamond_plan(eng: &EngineRef, log: &Arc<Mutex<Vec<&'static str>>>) -> Arc<RunPlan> {
        // a -> (b, c) -> d, ordered through vars exactly like the engine
        // diamond test.
        let (va, vb, vc, vd) = (eng.new_var(), eng.new_var(), eng.new_var(), eng.new_var());
        let mk = |name: &'static str, log: &Arc<Mutex<Vec<&'static str>>>| {
            let log = Arc::clone(log);
            move |_step: u64| log.lock().unwrap().push(name)
        };
        Arc::new(RunPlan::compile(vec![
            spec("a", vec![], vec![va], mk("a", log)),
            spec("b", vec![va], vec![vb], mk("b", log)),
            spec("c", vec![va], vec![vc], mk("c", log)),
            spec("d", vec![vb, vc], vec![vd], mk("d", log)),
        ]))
    }

    #[test]
    fn compile_derives_diamond_structure() {
        let eng = create(EngineKind::Threaded, 2);
        let log = Arc::new(Mutex::new(Vec::new()));
        let plan = diamond_plan(&eng, &log);
        assert_eq!(plan.len(), 4);
        assert_eq!(plan.roots, vec![0]);
        assert_eq!(plan.ops[0].succ, vec![1, 2]);
        assert_eq!(plan.ops[1].succ, vec![3]);
        assert_eq!(plan.ops[2].succ, vec![3]);
        assert_eq!(plan.ops[3].indegree, 2);
        assert_eq!(plan.width(), 2);
        // all four vars are written => boundary_writes = 4, no pure reads
        assert_eq!(plan.boundary_writes().len(), 4);
        assert!(plan.boundary_reads().is_empty());
    }

    #[test]
    fn war_and_waw_edges_are_derived() {
        let eng = create(EngineKind::Threaded, 2);
        let v = eng.new_var();
        let w = eng.new_var();
        let plan = RunPlan::compile(vec![
            spec("w0", vec![], vec![v], |_| {}),
            spec("r0", vec![v], vec![w], |_| {}),
            spec("w1", vec![], vec![v], |_| {}), // WAR on r0, WAW on w0
        ]);
        assert_eq!(plan.ops[2].indegree, 2, "w1 must wait for w0 (WAW) and r0 (WAR)");
        assert_eq!(plan.ops[0].succ, vec![1, 2]);
        assert_eq!(plan.ops[1].succ, vec![2]);
    }

    #[test]
    fn read_write_overlap_treated_as_write() {
        let eng = create(EngineKind::Threaded, 2);
        let v = eng.new_var();
        let plan = RunPlan::compile(vec![spec("rw", vec![v], vec![v], |_| {})]);
        assert!(plan.boundary_reads().is_empty());
        assert_eq!(plan.boundary_writes(), &[v]);
        assert_eq!(plan.ops[0].indegree, 0, "no self-edge");
    }

    #[test]
    fn threaded_replay_respects_dependency_order() {
        let eng = create(EngineKind::Threaded, 4);
        let log = Arc::new(Mutex::new(Vec::new()));
        let plan = diamond_plan(&eng, &log);
        for step in 1..=5u64 {
            eng.run_plan(&plan, step);
        }
        eng.wait_all();
        let order = log.lock().unwrap().clone();
        assert_eq!(order.len(), 20);
        for chunk in order.chunks(4) {
            let pos = |n: &str| chunk.iter().position(|&x| x == n).unwrap();
            assert_eq!(pos("a"), 0);
            assert_eq!(pos("d"), 3);
        }
    }

    #[test]
    fn naive_fallback_runs_in_program_order() {
        let eng = create(EngineKind::Naive, 1);
        let log = Arc::new(Mutex::new(Vec::new()));
        let plan = diamond_plan(&eng, &log);
        eng.run_plan(&plan, 1);
        assert_eq!(*log.lock().unwrap(), vec!["a", "b", "c", "d"]);
    }

    #[test]
    fn replay_passes_the_step_number() {
        let eng = create(EngineKind::Threaded, 2);
        let v = eng.new_var();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s2 = Arc::clone(&seen);
        let plan = Arc::new(RunPlan::compile(vec![spec(
            "s",
            vec![],
            vec![v],
            move |step| s2.lock().unwrap().push(step),
        )]));
        for step in [3u64, 9, 27] {
            eng.run_plan(&plan, step);
        }
        eng.wait_all();
        assert_eq!(*seen.lock().unwrap(), vec![3, 9, 27]);
    }

    #[test]
    fn replay_interleaves_correctly_with_imperative_pushes() {
        // plan writes x; an imperative op pushed after the replay reads x
        // and must observe the plan's write (boundary-var ordering).
        let eng = create(EngineKind::Threaded, 4);
        let x = eng.new_var();
        let cell = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&cell);
        let plan = Arc::new(RunPlan::compile(vec![spec(
            "slow_write",
            vec![],
            vec![x],
            move |_| {
                std::thread::sleep(std::time::Duration::from_millis(10));
                c2.store(42, Ordering::SeqCst);
            },
        )]));
        eng.run_plan(&plan, 1);
        let observed = Arc::new(AtomicUsize::new(0));
        let (c3, o) = (Arc::clone(&cell), Arc::clone(&observed));
        eng.push(
            "read",
            vec![x],
            vec![],
            Box::new(move || o.store(c3.load(Ordering::SeqCst), Ordering::SeqCst)),
        );
        eng.wait_all();
        assert_eq!(observed.load(Ordering::SeqCst), 42);
    }

    #[test]
    fn panicking_plan_op_does_not_wedge_replay_or_engine() {
        let eng = create(EngineKind::Threaded, 2);
        let v = eng.new_var();
        let done = Arc::new(AtomicUsize::new(0));
        let d2 = Arc::clone(&done);
        let plan = Arc::new(RunPlan::compile(vec![
            spec("boom", vec![], vec![v], |_| panic!("intentional")),
            spec("after", vec![v], vec![], move |_| {
                d2.fetch_add(1, Ordering::SeqCst);
            }),
        ]));
        eng.run_plan(&plan, 1);
        eng.wait_all(); // must not hang
        assert_eq!(done.load(Ordering::SeqCst), 1);
        // and the plan remains replayable
        eng.run_plan(&plan, 2);
        eng.wait_all();
        assert_eq!(done.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn wide_plan_executes_everything_across_workers() {
        let eng = create(EngineKind::Threaded, 4);
        let hits = Arc::new(AtomicUsize::new(0));
        let mut specs = Vec::new();
        for _ in 0..128 {
            let v = eng.new_var();
            let h = Arc::clone(&hits);
            specs.push(spec("inc", vec![], vec![v], move |_| {
                h.fetch_add(1, Ordering::Relaxed);
            }));
        }
        let plan = Arc::new(RunPlan::compile(specs));
        assert_eq!(plan.width(), 128);
        for _ in 0..10 {
            eng.run_plan(&plan, 1);
        }
        eng.wait_all();
        assert_eq!(hits.load(Ordering::Relaxed), 1280);
    }

    #[test]
    fn empty_plan_is_a_no_op() {
        let eng = create(EngineKind::Threaded, 2);
        let plan = Arc::new(RunPlan::compile(vec![]));
        assert!(plan.is_empty());
        eng.run_plan(&plan, 1);
        eng.wait_all();
    }
}

//! The eager, inline engine — *concrete execution* per Table 1.
//!
//! `push` runs the operation immediately on the calling thread, exactly
//! like numpy/Torch7/Caffe execute statements.  Dependencies are trivially
//! satisfied because everything is sequential.  This engine is
//!
//! * the baseline for the Figure 6 execution-model comparison, and
//! * the oracle in engine correctness tests (any schedule the threaded
//!   engine produces must compute the same values the naive one does).

use std::sync::atomic::{AtomicU64, Ordering};

use super::{Engine, EngineKind, OpFn, VarHandle};

/// Eager inline execution engine.
#[derive(Default)]
pub struct NaiveEngine {
    executed: AtomicU64,
}

impl NaiveEngine {
    /// Create a naive engine.
    pub fn new() -> Self {
        NaiveEngine { executed: AtomicU64::new(0) }
    }

    /// Number of ops executed since creation.
    pub fn ops_executed(&self) -> u64 {
        self.executed.load(Ordering::Relaxed)
    }
}

impl Engine for NaiveEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Naive
    }

    fn new_var(&self) -> VarHandle {
        VarHandle { id: super::alloc_var_id(), slot: u32::MAX, gen: 0 }
    }

    fn push(&self, _name: &'static str, _read: Vec<VarHandle>, _write: Vec<VarHandle>, func: OpFn) {
        func();
        self.executed.fetch_add(1, Ordering::Relaxed);
    }

    fn wait_for_var(&self, _var: VarHandle) {}

    fn wait_all(&self) {}

    fn delete_var(&self, _var: VarHandle) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn push_is_synchronous() {
        let eng = NaiveEngine::new();
        let hit = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hit);
        eng.push("op", vec![], vec![], Box::new(move || {
            h.store(1, Ordering::SeqCst);
        }));
        // No wait needed: already done.
        assert_eq!(hit.load(Ordering::SeqCst), 1);
        assert_eq!(eng.ops_executed(), 1);
    }

    #[test]
    fn preserves_program_order() {
        let eng = NaiveEngine::new();
        let v = eng.new_var();
        let log = Arc::new(std::sync::Mutex::new(Vec::new()));
        for i in 0..10 {
            let l = Arc::clone(&log);
            eng.push("op", vec![], vec![v], Box::new(move || {
                l.lock().unwrap().push(i);
            }));
        }
        assert_eq!(*log.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }
}

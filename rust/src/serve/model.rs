//! Servable models: checkpoint-loaded parameters plus a pool of cached
//! forward-only executors, one per batch-size bucket.
//!
//! A [`Servable`] owns one set of parameter [`NDArray`]s.  Every bucket
//! executor binds *clones* of those arrays — clones share storage and
//! engine tag — so a servable with buckets {1, 4, 16, 64} pays the
//! parameter memory once and only the per-bucket activation memory
//! scales.  All executors are bound with [`BindConfig::inference`]: no
//! backward graph, no gradient buffers.
//!
//! **Losslessness.**  Responses are guaranteed bitwise identical to a
//! batch-1 forward of the same sample only for *row-pure* graphs: every
//! op must compute output row `i` from input row `i` alone (GEMM dispatch
//! is per-row shape-pure, conv is image-parallel, softmax/activations are
//! row-wise, dropout is identity at inference).  `BatchNorm` computes
//! batch statistics and is therefore refused — fold it into the weights
//! before serving, as production servers require.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::executor::{BindConfig, Executor};
use crate::graph::Op;
use crate::kvstore::LocalKVStore;
use crate::models::Model;
use crate::ndarray::NDArray;
use crate::symbol::Symbol;

/// Live link between a servable's shared parameter arrays and a
/// training [`LocalKVStore`]: between batches, each worker refreshes
/// the parameters from the store's **committed** snapshots (online
/// learning — the server answers traffic while the trainer keeps
/// pushing).
///
/// Tear-safety: `pull_committed` captures one committed round's bytes
/// under the snapshot lock and writes them in a single engine op holding
/// the write grant on the parameter var, so a concurrently running
/// forward (which reads the var) is ordered entirely before or after
/// the refresh — a response can never observe a half-written parameter.
/// On the default plan-replay path a whole forward is *one* engine op,
/// so all parameters a response reads also come from one refresh
/// generation; with `replay` disabled, refreshes may interleave between
/// layer ops (per-parameter snapshots remain whole; the cross-layer mix
/// is ordinary eventual consistency).
pub(crate) struct LiveRefresher {
    store: Arc<LocalKVStore>,
    /// Shared-storage clones of the servable's parameter arrays.
    params: Vec<(String, NDArray)>,
    /// Last snapshot round refreshed into each parameter (CAS-guarded so
    /// concurrent workers schedule one refresh per new round, not one
    /// per worker).
    seen: Vec<AtomicU64>,
}

impl LiveRefresher {
    /// Schedule refreshes for every parameter whose committed snapshot
    /// advanced since the last refresh.  Cheap when nothing changed: one
    /// atomic load + one store lock per parameter.
    pub(crate) fn refresh(&self) {
        for (i, (name, arr)) in self.params.iter().enumerate() {
            let Ok(round) = self.store.snapshot_round(name) else { continue };
            let prev = self.seen[i].load(Ordering::Acquire);
            if round > prev
                && self.seen[i]
                    .compare_exchange(prev, round, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                // The captured snapshot may be even newer than `round`
                // (monotonic), never older than a committed round.
                let _ = self.store.pull_committed(name, arr);
            }
        }
    }
}

/// A model ready to serve: symbol + parameters + engine.
pub struct Servable {
    model: Model,
    engine: EngineRef,
    params: HashMap<String, NDArray>,
    label_name: String,
    feat_len: usize,
    live: Option<Arc<LiveRefresher>>,
    /// Graph fusion (elementwise + GEMM/conv epilogue) for bucket
    /// executors; on by default, CLI `--no-fuse` turns it off.
    fuse: bool,
}

impl Servable {
    /// Wrap a model and its parameter arrays, validating completeness,
    /// shapes, and row-purity of the graph.
    pub fn new(
        model: Model,
        params: HashMap<String, NDArray>,
        engine: EngineRef,
    ) -> Result<Servable> {
        let graph = Symbol::to_graph(std::slice::from_ref(&model.symbol));
        if graph.nodes.iter().any(|n| matches!(n.op, Op::BatchNorm { .. })) {
            return Err(Error::serve(format!(
                "model '{}' contains BatchNorm: batch statistics make batched \
                 responses depend on co-batched requests; fold BN into the \
                 weights before serving",
                model.name
            )));
        }
        let shapes = model.param_shapes(1)?;
        for (name, shape) in &shapes {
            let arr = params.get(name).ok_or_else(|| {
                Error::serve(format!("missing parameter '{name}' for model '{}'", model.name))
            })?;
            if arr.shape() != shape.as_slice() {
                return Err(Error::serve(format!(
                    "parameter '{name}': shape {:?} != expected {:?}",
                    arr.shape(),
                    shape
                )));
            }
        }
        let label_name = model
            .symbol
            .list_arguments()
            .into_iter()
            .find(|n| n.ends_with("_label"))
            .ok_or_else(|| Error::serve("model has no softmax label variable"))?;
        let feat_len = model.feat_shape.iter().product();
        Ok(Servable { model, engine, params, label_name, feat_len, live: None, fuse: true })
    }

    /// Toggle graph fusion for bucket executors bound after this call
    /// (fusion is lossless — bitwise-identical responses — so this is a
    /// perf A/B knob, not a correctness one).
    pub fn set_fuse(&mut self, fuse: bool) {
        self.fuse = fuse;
    }

    /// Attach this servable to a training [`LocalKVStore`]: every bucket
    /// executor bound *after* this call refreshes the shared parameters
    /// from the store's committed snapshots before each batch, and the
    /// parameters are synchronized to the store's current snapshots
    /// right away.  Every parameter must be registered in the store with
    /// a matching size.  See [`LiveRefresher`] for the tear-safety
    /// contract; snapshots are per-key, so responses mid-training are
    /// eventually consistent across layers (and exactly consistent once
    /// the trainer stops and a final refresh lands).
    pub fn attach_live(&mut self, store: &Arc<LocalKVStore>) -> Result<()> {
        let mut params = Vec::with_capacity(self.params.len());
        let mut seen = Vec::with_capacity(self.params.len());
        for (name, arr) in &self.params {
            let n = store.value_len(name)?;
            if n != arr.size() {
                return Err(Error::serve(format!(
                    "attach_live: store key '{name}' has {n} elements, parameter has {}",
                    arr.size()
                )));
            }
            // Eager initial sync: serve the store's committed state from
            // the first request on.
            let round = store.pull_committed(name, arr)?;
            params.push((name.clone(), arr.clone()));
            seen.push(AtomicU64::new(round));
        }
        self.live = Some(Arc::new(LiveRefresher {
            store: Arc::clone(store),
            params,
            seen,
        }));
        Ok(())
    }

    /// Whether this servable is live-attached to a training store.
    pub fn is_live(&self) -> bool {
        self.live.is_some()
    }

    /// Load a checkpoint (paper's `save_checkpoint` format) and wrap it
    /// for serving — the train → checkpoint → serve path.
    pub fn from_checkpoint(
        model: Model,
        path: impl AsRef<Path>,
        engine: EngineRef,
    ) -> Result<Servable> {
        let params = crate::io::checkpoint::load(path, engine.clone())?;
        Servable::new(model, params, engine)
    }

    /// Flattened per-sample feature length.
    pub fn feat_len(&self) -> usize {
        self.feat_len
    }

    /// Output classes per response.
    pub fn num_classes(&self) -> usize {
        self.model.num_classes
    }

    /// Model name.
    pub fn name(&self) -> &str {
        &self.model.name
    }

    /// The engine all executors are scheduled on.
    pub fn engine(&self) -> EngineRef {
        self.engine.clone()
    }

    /// Bind one forward-only executor for batch size `batch`, sharing
    /// this servable's parameter arrays.
    pub fn bind_bucket(&self, batch: usize) -> Result<BucketExec> {
        let mut args: HashMap<String, NDArray> = HashMap::new();
        let mut data_shape = vec![batch];
        data_shape.extend_from_slice(&self.model.feat_shape);
        // Pool-backed, no zero-fill: every run() fully overwrites the
        // data buffer via the scatter op before the forward reads it.
        let data = NDArray::alloc_uninit_on(&data_shape, self.engine.clone());
        args.insert("data".into(), data.clone());
        args.insert(
            self.label_name.clone(),
            NDArray::zeros_on(&[batch], self.engine.clone()),
        );
        for (name, arr) in &self.params {
            args.insert(name.clone(), arr.clone()); // shares storage + tag
        }
        let exec = Executor::bind(
            &self.model.symbol,
            self.engine.clone(),
            args,
            &[],
            BindConfig { fuse: self.fuse, ..BindConfig::inference() },
        )?;
        Ok(BucketExec {
            batch,
            data,
            exec,
            feat_len: self.feat_len,
            out_len: self.model.num_classes,
            live: self.live.clone(),
        })
    }
}

/// One pre-bound forward-only executor for a fixed batch-size bucket.
pub struct BucketExec {
    batch: usize,
    data: NDArray,
    exec: Executor,
    feat_len: usize,
    out_len: usize,
    /// Live-training link (refresh parameters before each batch).
    live: Option<Arc<LiveRefresher>>,
}

impl BucketExec {
    /// Bucket capacity.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Scatter `rows` into the batch buffer (zero padding), run the
    /// forward pass, and gather one output row per request.
    ///
    /// The staged buffer is moved into one engine op that writes the
    /// data array directly — no extra copy, and no synchronization
    /// before the forward: the engine orders scatter → forward → gather
    /// through the data/output tags, so the only wait is the final
    /// output read.
    ///
    /// Staging scratch is leased from the storage pool (ISSUE 3): the
    /// lease returns to the pool when the scatter op drops it, so a
    /// steady-state worker re-leases the same buffer every batch and
    /// dispatch allocates nothing.
    pub fn run(&mut self, rows: &[&[f32]]) -> Vec<Vec<f32>> {
        assert!(rows.len() <= self.batch, "{} rows > bucket {}", rows.len(), self.batch);
        if let Some(live) = &self.live {
            // Online learning: pick up newly committed training rounds
            // before this batch's forward is scheduled.
            live.refresh();
        }
        // Zero-filled staging: unused rows never leak a previous batch.
        let prof = crate::profile::SpanTimer::start();
        let mut staged = crate::ndarray::pool::lease_zeroed(self.batch * self.feat_len);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), self.feat_len, "request row {i} has wrong feature length");
            staged[i * self.feat_len..(i + 1) * self.feat_len].copy_from_slice(r);
        }
        let storage = self.data.storage();
        self.data.engine().push(
            "serve.scatter",
            vec![],
            vec![self.data.var()],
            Box::new(move || {
                // SAFETY: the engine granted the exclusive write on the
                // data array's tag (same discipline as NDArray ops).
                unsafe { storage.slice_mut() }.copy_from_slice(&staged);
                // `staged` drops here: back to the pool for the next batch
            }),
        );
        // Caller-side phases: scatter = stage + dispatch, forward = graph
        // push, gather = the one blocking wait on the head output.
        prof.finish(crate::profile::Category::Serve, "serve.scatter", 0, rows.len() as u64, 0);
        let prof = crate::profile::SpanTimer::start();
        self.exec.forward();
        prof.finish(crate::profile::Category::Serve, "serve.forward", 0, rows.len() as u64, 0);
        let prof = crate::profile::SpanTimer::start();
        let out = self.exec.outputs()[0].to_vec(); // waits for the head
        let gathered = rows
            .iter()
            .enumerate()
            .map(|(i, _)| out[i * self.out_len..(i + 1) * self.out_len].to_vec())
            .collect();
        prof.finish(crate::profile::Category::Serve, "serve.gather", 0, rows.len() as u64, 0);
        gathered
    }
}

/// A worker's set of bucket executors, ascending by batch size.
pub struct ExecPool {
    buckets: Vec<BucketExec>,
}

impl ExecPool {
    /// Bind one executor per bucket size (sorted, deduplicated).
    pub fn for_buckets(servable: &Servable, buckets: &[usize]) -> Result<ExecPool> {
        let mut sizes: Vec<usize> = buckets.iter().copied().filter(|&b| b > 0).collect();
        sizes.sort_unstable();
        sizes.dedup();
        if sizes.is_empty() {
            return Err(Error::serve("no batch buckets configured"));
        }
        let buckets = sizes
            .into_iter()
            .map(|b| servable.bind_bucket(b))
            .collect::<Result<Vec<_>>>()?;
        Ok(ExecPool { buckets })
    }

    /// Largest bucket (the effective max batch).
    pub fn max_batch(&self) -> usize {
        self.buckets.last().map(|b| b.batch).unwrap_or(0)
    }

    /// Serve one coalesced batch on the smallest bucket that fits it.
    /// Oversized batches are split into max-bucket chunks.
    pub fn run(&mut self, rows: &[&[f32]]) -> Vec<Vec<f32>> {
        let max = self.max_batch();
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(max.max(1)) {
            let idx = self
                .buckets
                .iter()
                .position(|b| b.batch >= chunk.len())
                .unwrap_or(self.buckets.len() - 1);
            out.extend(self.buckets[idx].run(chunk));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::kvstore::{Consistency, KVStore};
    use crate::models::{mlp, simple_cnn};
    use crate::module::Module;
    use crate::optimizer::Sgd;

    fn trained_params(engine: &EngineRef) -> (Model, HashMap<String, NDArray>) {
        let model = mlp(&[8], 6, 3);
        let shapes = model.param_shapes(4).unwrap();
        let mut m = Module::new(mlp(&[8], 6, 3).symbol, engine.clone());
        m.bind_inference(4, &[6], &shapes, 42).unwrap();
        let params = m
            .param_names()
            .iter()
            .map(|n| (n.clone(), m.param(n).unwrap().clone()))
            .collect();
        (model, params)
    }

    #[test]
    fn servable_validates_params_and_buckets_share_them() {
        let engine = create(EngineKind::Threaded, 2);
        let (model, params) = trained_params(&engine);
        let s = Servable::new(model, params.clone(), engine.clone()).unwrap();
        assert_eq!(s.feat_len(), 6);
        assert_eq!(s.num_classes(), 3);
        let b1 = s.bind_bucket(1).unwrap();
        let b4 = s.bind_bucket(4).unwrap();
        // parameter storage is shared, not copied
        assert!(std::sync::Arc::ptr_eq(
            &b1.exec.arg("fc1_weight").unwrap().storage(),
            &b4.exec.arg("fc1_weight").unwrap().storage()
        ));
        // and no grad buffers exist anywhere
        assert!(b1.exec.grads().is_empty());
        assert!(b4.exec.grads().is_empty());

        // missing parameter rejected
        let mut broken = params;
        broken.remove("fc1_bias");
        assert!(Servable::new(mlp(&[8], 6, 3), broken, engine).is_err());
    }

    #[test]
    fn batchnorm_models_are_refused() {
        let engine = create(EngineKind::Threaded, 2);
        match Servable::new(simple_cnn(4, 16), HashMap::new(), engine) {
            Err(Error::Serve(msg)) => assert!(msg.contains("BatchNorm"), "{msg}"),
            Err(e) => panic!("expected Serve error, got {e}"),
            Ok(_) => panic!("BatchNorm model must be refused"),
        }
    }

    #[test]
    fn bucket_run_matches_batch1_bitwise() {
        let engine = create(EngineKind::Threaded, 4);
        let (model, params) = trained_params(&engine);
        let s = Servable::new(model, params, engine).unwrap();
        let mut pool = ExecPool::for_buckets(&s, &[1, 4, 8]).unwrap();
        let mut single = s.bind_bucket(1).unwrap();
        let samples: Vec<Vec<f32>> = (0..7)
            .map(|i| (0..6).map(|j| ((i * 6 + j) as f32 * 0.37).sin()).collect())
            .collect();
        let rows: Vec<&[f32]> = samples.iter().map(|s| s.as_slice()).collect();
        let batched = pool.run(&rows); // smallest fitting bucket: 8
        for (i, sample) in samples.iter().enumerate() {
            let one = single.run(&[sample.as_slice()]);
            assert_eq!(one[0], batched[i], "row {i} differs from batch-1");
        }
    }

    #[test]
    fn attach_live_syncs_params_and_picks_up_committed_rounds() {
        let engine = create(EngineKind::Threaded, 2);
        let (model, params) = trained_params(&engine);
        // A training store holding *different* weights for the same keys.
        let store = Arc::new(LocalKVStore::new(
            engine.clone(),
            1,
            Arc::new(Sgd::new(1.0)),
            Consistency::Sequential,
        ));
        for (name, arr) in &params {
            let alt =
                NDArray::from_vec_on(arr.shape(), vec![0.25; arr.size()], engine.clone());
            store.init(name, &alt).unwrap();
        }
        let mut s = Servable::new(model, params.clone(), engine.clone()).unwrap();
        assert!(!s.is_live());
        s.attach_live(&store).unwrap();
        assert!(s.is_live());
        engine.wait_all();
        // eager sync: the servable now holds the store's committed state
        for (name, arr) in &params {
            assert!(arr.to_vec().iter().all(|&v| v == 0.25), "'{name}' not synced");
        }
        // a committed round is picked up by the next bucket dispatch
        let mut b = s.bind_bucket(1).unwrap();
        let g = NDArray::from_vec_on(
            params["fc1_weight"].shape(),
            vec![0.25; params["fc1_weight"].size()],
            engine.clone(),
        );
        store.push("fc1_weight", &g, 0).unwrap();
        store.flush();
        let sample = vec![0.0f32; 6];
        let _ = b.run(&[sample.as_slice()]);
        engine.wait_all();
        assert!(
            params["fc1_weight"].to_vec().iter().all(|&v| v == 0.0),
            "lr=1 push must land in the served parameters (0.25 - 0.25)"
        );
        // attaching with a missing key is rejected
        let (model2, params2) = trained_params(&engine);
        let empty = Arc::new(LocalKVStore::new(
            engine.clone(),
            1,
            Arc::new(Sgd::new(1.0)),
            Consistency::Sequential,
        ));
        let mut s2 = Servable::new(model2, params2, engine).unwrap();
        assert!(s2.attach_live(&empty).is_err());
    }

    #[test]
    fn oversized_batches_split_across_bucket_chunks() {
        let engine = create(EngineKind::Threaded, 2);
        let (model, params) = trained_params(&engine);
        let s = Servable::new(model, params, engine).unwrap();
        let mut pool = ExecPool::for_buckets(&s, &[2]).unwrap();
        let samples: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; 6]).collect();
        let rows: Vec<&[f32]> = samples.iter().map(|s| s.as_slice()).collect();
        assert_eq!(pool.run(&rows).len(), 5);
    }
}

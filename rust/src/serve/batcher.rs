//! The dynamic batcher: a bounded request queue with a
//! max-batch-size + max-queue-delay coalescing policy.
//!
//! Requests are admitted under backpressure (the queue is bounded; the
//! blocking push waits for space, the non-blocking push rejects) and
//! collected into batches by the serving workers: a worker's
//! [`BatchQueue::pop_batch`] returns as soon as a full batch is waiting
//! *or* the oldest queued request has aged past the delay budget —
//! whichever comes first.  The policy is adaptive in the natural sense:
//! under load batches fill instantly and the delay never triggers; when
//! traffic is sparse a lone request waits at most `max_delay` before it
//! is served alone.
//!
//! Shutdown is graceful: admitted requests are always dispatched
//! (`pop_batch` keeps draining after [`BatchQueue::shutdown`]), new
//! admissions are refused.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::Result;

/// Batching policy knobs (see `PALLAS_SERVE_MAX_BATCH` /
/// `PALLAS_SERVE_MAX_DELAY_US`).
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Largest batch a single dispatch may carry.
    pub max_batch: usize,
    /// Longest a request may wait for co-batching before it is dispatched
    /// in a partial batch.
    pub max_delay: Duration,
}

/// One admitted request: the flattened feature vector plus the channel
/// its response travels back on.
pub(crate) struct PendingRequest {
    /// Flattened single-sample feature tensor.
    pub features: Vec<f32>,
    /// Admission time (latency measurement starts here).
    pub enqueued: Instant,
    /// Response channel back to the waiting client.
    pub tx: mpsc::Sender<Result<Vec<f32>>>,
}

/// Why a non-blocking admission was refused.
pub(crate) enum Rejected {
    /// The queue is at capacity (backpressure) — retry later.
    Full(PendingRequest),
    /// The server is shutting down — do not retry.
    Shutdown(PendingRequest),
}

struct QueueState {
    deque: VecDeque<PendingRequest>,
    shutdown: bool,
}

/// Bounded MPMC request queue with the dynamic-batching pop policy.
pub(crate) struct BatchQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    policy: BatchPolicy,
}

impl BatchQueue {
    pub fn new(cap: usize, policy: BatchPolicy) -> Self {
        BatchQueue {
            state: Mutex::new(QueueState { deque: VecDeque::new(), shutdown: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
            policy: BatchPolicy {
                max_batch: policy.max_batch.max(1),
                max_delay: policy.max_delay,
            },
        }
    }

    /// Admit without blocking; rejects when full or shut down.
    pub fn try_push(&self, req: PendingRequest) -> std::result::Result<(), Rejected> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(Rejected::Shutdown(req));
        }
        if st.deque.len() >= self.cap {
            return Err(Rejected::Full(req));
        }
        st.deque.push_back(req);
        drop(st);
        self.not_empty.notify_all();
        Ok(())
    }

    /// Admit, blocking while the queue is at capacity (backpressure).
    /// Returns the request back when the server shuts down first.
    pub fn push_wait(&self, req: PendingRequest) -> std::result::Result<(), PendingRequest> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return Err(req);
            }
            if st.deque.len() < self.cap {
                st.deque.push_back(req);
                drop(st);
                self.not_empty.notify_all();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Collect the next batch according to the policy.  Blocks until a
    /// batch is ready; `None` means shut down *and* fully drained.
    pub fn pop_batch(&self) -> Option<Vec<PendingRequest>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.deque.is_empty() {
                if st.deque.len() >= self.policy.max_batch || st.shutdown {
                    return Some(self.drain(&mut st));
                }
                let age = st.deque.front().unwrap().enqueued.elapsed();
                if age >= self.policy.max_delay {
                    return Some(self.drain(&mut st));
                }
                // Partial batch, delay budget not spent: wait for either
                // more requests (notify) or the budget to expire.
                let (s, _timeout) =
                    self.not_empty.wait_timeout(st, self.policy.max_delay - age).unwrap();
                st = s;
            } else if st.shutdown {
                return None;
            } else {
                st = self.not_empty.wait(st).unwrap();
            }
        }
    }

    fn drain(&self, st: &mut QueueState) -> Vec<PendingRequest> {
        let n = st.deque.len().min(self.policy.max_batch);
        let batch: Vec<PendingRequest> = st.deque.drain(..n).collect();
        self.not_full.notify_all();
        batch
    }

    /// Refuse new admissions; wake every waiter.  Already-admitted
    /// requests continue to be dispatched by `pop_batch`.
    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Currently queued (admitted, not yet dispatched) requests.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().deque.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(tag: f32) -> (PendingRequest, mpsc::Receiver<Result<Vec<f32>>>) {
        let (tx, rx) = mpsc::channel();
        (PendingRequest { features: vec![tag], enqueued: Instant::now(), tx }, rx)
    }

    fn policy(max_batch: usize, delay_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay: Duration::from_millis(delay_ms) }
    }

    #[test]
    fn full_batch_dispatches_without_delay() {
        let q = BatchQueue::new(64, policy(4, 10_000));
        for i in 0..4 {
            let (r, _rx) = req(i as f32);
            q.try_push(r).map_err(|_| ()).unwrap();
        }
        let t0 = Instant::now();
        let batch = q.pop_batch().unwrap();
        assert_eq!(batch.len(), 4);
        // a full batch must not wait for the (huge) delay budget
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn partial_batch_dispatches_after_delay() {
        let q = BatchQueue::new(64, policy(8, 30));
        let (r, _rx) = req(1.0);
        q.try_push(r).map_err(|_| ()).unwrap();
        let batch = q.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn overflow_is_rejected_not_dropped() {
        let q = BatchQueue::new(2, policy(8, 10_000));
        let (r1, _x1) = req(1.0);
        let (r2, _x2) = req(2.0);
        let (r3, _x3) = req(3.0);
        assert!(q.try_push(r1).is_ok());
        assert!(q.try_push(r2).is_ok());
        match q.try_push(r3) {
            Err(Rejected::Full(r)) => assert_eq!(r.features, vec![3.0]),
            _ => panic!("expected backpressure rejection"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn shutdown_drains_then_ends() {
        let q = Arc::new(BatchQueue::new(64, policy(4, 10_000)));
        for i in 0..6 {
            let (r, _rx) = req(i as f32);
            q.try_push(r).map_err(|_| ()).unwrap();
        }
        q.shutdown();
        // new admissions refused
        let (r, _rx) = req(9.0);
        assert!(matches!(q.try_push(r), Err(Rejected::Shutdown(_))));
        // but queued requests drain: 4 + 2, then None forever
        assert_eq!(q.pop_batch().unwrap().len(), 4);
        assert_eq!(q.pop_batch().unwrap().len(), 2);
        assert!(q.pop_batch().is_none());
        assert!(q.pop_batch().is_none());
    }

    #[test]
    fn pop_blocks_until_push_from_other_thread() {
        let q = Arc::new(BatchQueue::new(8, policy(1, 1_000)));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_batch().map(|b| b.len()));
        std::thread::sleep(Duration::from_millis(20));
        let (r, _rx) = req(1.0);
        q.try_push(r).map_err(|_| ()).unwrap();
        assert_eq!(h.join().unwrap(), Some(1));
    }
}

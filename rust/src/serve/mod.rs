//! Dynamic-batching inference serving (the request-level path the
//! training-centric paper leaves open; cf. TensorFlow-Serving's batching
//! layer and SystemML's batch-size-aware replanning).
//!
//! ```text
//!  clients ──submit──▶ bounded queue ──pop_batch──▶ worker threads
//!   (any thread)        (backpressure)   (max-batch | max-delay)
//!                                            │ scatter into bucket buffer
//!                                            │ forward on cached executor
//!                                            │   (bucket ∈ {1,4,16,64,…})
//!                                            ▼ gather + reply per request
//! ```
//!
//! * **Dynamic batching** — requests are coalesced until either the max
//!   batch size is reached or the oldest request has waited the max
//!   queue delay ([`batcher::BatchQueue`]).
//! * **Executor bucketing** — each worker owns forward-only executors
//!   pre-bound per batch-size bucket, all sharing one set of parameter
//!   arrays ([`model::Servable`]); a batch runs on the smallest bucket
//!   that fits.
//! * **Concurrency** — workers push their forward passes onto the shared
//!   dependency engine, so independent batches overlap through the
//!   engine's inter-op pool and big kernels still fan out intra-op.
//! * **Losslessness** — every response is bitwise identical to a batch-1
//!   forward of the same sample (row-pure kernels; see
//!   `ndarray/kernels.rs::SMALL_GEMM_ROW_FLOPS`).
//! * **Observability** — per-request latency lands in a bounded-reservoir
//!   histogram ([`crate::metrics::Histogram`]); [`Server::stats`] reports
//!   p50/p95/p99, throughput and mean batch occupancy.
//! * **Live serving** — a servable attached to a training
//!   [`LocalKVStore`](crate::kvstore::LocalKVStore) via
//!   [`Servable::attach_live`] refreshes its bucket-shared parameters
//!   from the store's **committed** snapshots between batches: the
//!   server answers traffic while the trainer keeps pushing (online
//!   learning), and no response ever reads a torn parameter buffer.
//!
//! Knobs (env defaults, overridable per [`ServeConfig`]):
//! `PALLAS_SERVE_MAX_BATCH`, `PALLAS_SERVE_MAX_DELAY_US`,
//! `PALLAS_SERVE_QUEUE_CAP`, `PALLAS_SERVE_WORKERS`.

pub mod batcher;
pub mod model;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::metrics;

use batcher::{BatchPolicy, BatchQueue, PendingRequest, Rejected};
pub use model::{BucketExec, ExecPool, Servable};

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Largest batch one dispatch may carry (`PALLAS_SERVE_MAX_BATCH`).
    pub max_batch: usize,
    /// Max time a request waits for co-batching, in microseconds
    /// (`PALLAS_SERVE_MAX_DELAY_US`).
    pub max_delay_us: u64,
    /// Bounded queue capacity — the backpressure limit
    /// (`PALLAS_SERVE_QUEUE_CAP`).
    pub queue_cap: usize,
    /// Worker threads, each with its own bucket-executor pool
    /// (`PALLAS_SERVE_WORKERS`).
    pub workers: usize,
    /// Batch-size buckets; empty means [`default_buckets`] of
    /// `max_batch`.
    pub buckets: Vec<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 64,
            max_delay_us: 2_000,
            queue_cap: 1024,
            workers: 2,
            buckets: vec![],
        }
    }
}

impl ServeConfig {
    /// Defaults overridden by the `PALLAS_SERVE_*` environment knobs.
    pub fn from_env() -> Self {
        fn env<T: std::str::FromStr>(key: &str, default: T) -> T {
            std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
        }
        let d = ServeConfig::default();
        ServeConfig {
            max_batch: env("PALLAS_SERVE_MAX_BATCH", d.max_batch),
            max_delay_us: env("PALLAS_SERVE_MAX_DELAY_US", d.max_delay_us),
            queue_cap: env("PALLAS_SERVE_QUEUE_CAP", d.queue_cap),
            workers: env("PALLAS_SERVE_WORKERS", d.workers),
            buckets: vec![],
        }
    }
}

/// Power-of-4 bucket ladder up to `max_batch`: 1, 4, 16, 64, …, capped
/// and terminated by `max_batch` itself.
pub fn default_buckets(max_batch: usize) -> Vec<usize> {
    let max_batch = max_batch.max(1);
    let mut v = Vec::new();
    let mut b = 1usize;
    while b < max_batch {
        v.push(b);
        b = b.saturating_mul(4);
    }
    v.push(max_batch);
    v
}

/// A point-in-time snapshot of serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests answered.
    pub requests: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Non-blocking submissions rejected by backpressure.
    pub rejected: u64,
    /// Mean requests per dispatched batch.
    pub mean_batch: f64,
    /// Median queue-to-response latency, microseconds.
    pub p50_us: u64,
    /// 95th-percentile latency, microseconds.
    pub p95_us: u64,
    /// 99th-percentile latency, microseconds.
    pub p99_us: u64,
    /// Server uptime, seconds.
    pub uptime_s: f64,
    /// Answered requests per second over the uptime.
    pub rps: f64,
}

struct ServerShared {
    requests: AtomicU64,
    batches: AtomicU64,
    rejected: AtomicU64,
    latency: Mutex<metrics::Histogram>,
}

/// A response that has been admitted but may not have completed yet.
pub struct Pending {
    rx: mpsc::Receiver<Result<Vec<f32>>>,
}

impl Pending {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::serve("request dropped (server worker gone)")),
        }
    }
}

/// The dynamic-batching inference server.
pub struct Server {
    queue: Arc<BatchQueue>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<ServerShared>,
    feat_len: usize,
    started: Instant,
}

impl Server {
    /// Pre-bind every worker's bucket executors and start the serving
    /// threads.
    pub fn start(servable: &Servable, cfg: &ServeConfig) -> Result<Server> {
        let buckets = if cfg.buckets.is_empty() {
            default_buckets(cfg.max_batch)
        } else {
            cfg.buckets.clone()
        };
        let nworkers = cfg.workers.max(1);
        let pools: Vec<ExecPool> = (0..nworkers)
            .map(|_| ExecPool::for_buckets(servable, &buckets))
            .collect::<Result<_>>()?;
        let queue = Arc::new(BatchQueue::new(
            cfg.queue_cap,
            BatchPolicy {
                max_batch: cfg.max_batch,
                max_delay: Duration::from_micros(cfg.max_delay_us),
            },
        ));
        let shared = Arc::new(ServerShared {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            latency: Mutex::new(metrics::Histogram::new(metrics::HISTOGRAM_CAP)),
        });
        let workers = pools
            .into_iter()
            .enumerate()
            .map(|(i, mut pool)| {
                let queue = Arc::clone(&queue);
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("mixnet-serve-{i}"))
                    .spawn(move || {
                        while let Some(batch) = queue.pop_batch() {
                            serve_batch(&mut pool, batch, &shared);
                        }
                    })
                    .expect("spawn serve worker")
            })
            .collect();
        Ok(Server {
            queue,
            workers,
            shared,
            feat_len: servable.feat_len(),
            started: Instant::now(),
        })
    }

    fn make_request(
        &self,
        features: Vec<f32>,
    ) -> Result<(PendingRequest, mpsc::Receiver<Result<Vec<f32>>>)> {
        if features.len() != self.feat_len {
            return Err(Error::serve(format!(
                "request has {} features, model expects {}",
                features.len(),
                self.feat_len
            )));
        }
        let (tx, rx) = mpsc::channel();
        Ok((PendingRequest { features, enqueued: Instant::now(), tx }, rx))
    }

    /// Admit one single-sample request, blocking under backpressure.
    pub fn submit(&self, features: Vec<f32>) -> Result<Pending> {
        let (req, rx) = self.make_request(features)?;
        match self.queue.push_wait(req) {
            Ok(()) => Ok(Pending { rx }),
            Err(_) => Err(Error::serve("server is shut down")),
        }
    }

    /// Admit without blocking; errs immediately when the queue is full.
    pub fn try_submit(&self, features: Vec<f32>) -> Result<Pending> {
        let (req, rx) = self.make_request(features)?;
        match self.queue.try_push(req) {
            Ok(()) => Ok(Pending { rx }),
            Err(Rejected::Full(_)) => {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::serve("queue full (backpressure)"))
            }
            Err(Rejected::Shutdown(_)) => Err(Error::serve("server is shut down")),
        }
    }

    /// Submit and wait: the closed-loop client call.
    pub fn infer(&self, features: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(features)?.wait()
    }

    /// Requests currently queued (admitted, not yet dispatched).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Snapshot the serving statistics.
    pub fn stats(&self) -> ServeStats {
        let requests = self.shared.requests.load(Ordering::Relaxed);
        let batches = self.shared.batches.load(Ordering::Relaxed);
        let p = self.shared.latency.lock().unwrap().percentiles(&[50.0, 95.0, 99.0]);
        let uptime_s = self.started.elapsed().as_secs_f64();
        ServeStats {
            requests,
            batches,
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            mean_batch: if batches > 0 { requests as f64 / batches as f64 } else { 0.0 },
            p50_us: p[0],
            p95_us: p[1],
            p99_us: p[2],
            uptime_s,
            rps: if uptime_s > 0.0 { requests as f64 / uptime_s } else { 0.0 },
        }
    }

    /// Graceful shutdown: refuse new requests, serve everything already
    /// admitted, join the workers, and return the final statistics.
    pub fn shutdown(&mut self) -> ServeStats {
        self.queue.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.workers.is_empty() {
            self.shutdown();
        }
    }
}

/// Scatter → forward → gather → reply for one coalesced batch.
///
/// A panic while serving (a kernel assert, an executor invariant) must
/// not kill the worker loop: queued requests would then park forever in
/// [`Pending::wait`].  The batch is failed, the worker survives.
fn serve_batch(pool: &mut ExecPool, batch: Vec<PendingRequest>, shared: &ServerShared) {
    let prof = crate::profile::SpanTimer::start();
    // queue_us = the longest any request in this batch sat in the queue
    // before the batch was picked up.
    let queue_us = if prof.on() {
        let now = Instant::now();
        batch.iter().map(|r| now.duration_since(r.enqueued).as_micros() as u64).max().unwrap_or(0)
    } else {
        0
    };
    let outs = {
        let rows: Vec<&[f32]> = batch.iter().map(|r| r.features.as_slice()).collect();
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run(&rows)))
    };
    let outs = match outs {
        Ok(outs) => outs,
        Err(_) => {
            let n = batch.len() as u64;
            eprintln!("mixnet serve: worker panicked serving a batch of {}", batch.len());
            for req in batch {
                let _ = req.tx.send(Err(Error::serve("internal error serving batch")));
            }
            // b = 1 marks a failed batch in the trace.
            prof.finish(crate::profile::Category::Serve, "serve.batch", queue_us, n, 1);
            return;
        }
    };
    shared.batches.fetch_add(1, Ordering::Relaxed);
    shared.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
    let done = Instant::now();
    // One lock per histogram per batch, not per request: the reply loop
    // is the serving hot path.
    let lats: Vec<u64> = batch
        .iter()
        .map(|req| done.duration_since(req.enqueued).as_micros() as u64)
        .collect();
    {
        let mut lat = shared.latency.lock().unwrap();
        for &us in &lats {
            lat.observe(us);
        }
    }
    metrics::observe_us_all("serve.latency_us", &lats);
    let n = batch.len() as u64;
    for (req, out) in batch.into_iter().zip(outs) {
        // A client that gave up is not an error worth crashing a worker.
        let _ = req.tx.send(Ok(out));
    }
    // a = batch size; queue_us = worst queue wait in the batch.
    prof.finish(crate::profile::Category::Serve, "serve.batch", queue_us, n, 0);
}

/// Closed-loop load report (see [`closed_loop`]).
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Requests attempted.
    pub requests: u64,
    /// Requests that errored (shutdown / backpressure).
    pub errors: u64,
    /// Wall-clock duration of the whole loop, seconds.
    pub wall_s: f64,
    /// Successful requests per second.
    pub rps: f64,
}

/// Drive `clients` closed-loop client threads, each issuing
/// `per_client` blocking [`Server::infer`] calls over `samples`
/// round-robin.  The shared harness for the serve bench, the CLI demo
/// and the integration tests.
pub fn closed_loop(
    server: &Server,
    clients: usize,
    per_client: usize,
    samples: &[Vec<f32>],
) -> LoadReport {
    assert!(!samples.is_empty(), "closed_loop needs at least one sample");
    let errors = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let errors = &errors;
            scope.spawn(move || {
                for i in 0..per_client {
                    let s = &samples[(c + i * clients) % samples.len()];
                    if server.infer(s.clone()).is_err() {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let requests = (clients * per_client) as u64;
    let errors = errors.load(Ordering::Relaxed);
    let ok = requests - errors;
    LoadReport {
        requests,
        errors,
        wall_s,
        rps: if wall_s > 0.0 { ok as f64 / wall_s } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::models::mlp;
    use crate::module::Module;

    fn servable(engine: &crate::engine::EngineRef) -> Servable {
        let model = mlp(&[8], 6, 3);
        let shapes = model.param_shapes(4).unwrap();
        let mut m = Module::new(mlp(&[8], 6, 3).symbol, engine.clone());
        m.bind_inference(4, &[6], &shapes, 42).unwrap();
        let params = m
            .param_names()
            .iter()
            .map(|n| (n.clone(), m.param(n).unwrap().clone()))
            .collect();
        Servable::new(model, params, engine.clone()).unwrap()
    }

    #[test]
    fn default_bucket_ladder() {
        assert_eq!(default_buckets(64), vec![1, 4, 16, 64]);
        assert_eq!(default_buckets(1), vec![1]);
        assert_eq!(default_buckets(10), vec![1, 4, 10]);
    }

    #[test]
    fn serves_single_requests_and_counts() {
        let engine = create(EngineKind::Threaded, 2);
        let s = servable(&engine);
        let cfg = ServeConfig {
            max_batch: 4,
            max_delay_us: 500,
            queue_cap: 16,
            workers: 1,
            buckets: vec![],
        };
        let mut server = Server::start(&s, &cfg).unwrap();
        for i in 0..6 {
            let probs = server.infer(vec![i as f32 * 0.1; 6]).unwrap();
            assert_eq!(probs.len(), 3);
            let sum: f32 = probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "{sum}");
        }
        // wrong feature length is rejected up front
        assert!(server.infer(vec![0.0; 5]).is_err());
        let stats = server.shutdown();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches >= 1 && stats.batches <= 6);
        assert!(stats.p50_us > 0);
        // after shutdown new submissions fail
        assert!(server.submit(vec![0.0; 6]).is_err());
    }

    #[test]
    fn shutdown_drains_admitted_requests() {
        let engine = create(EngineKind::Threaded, 2);
        let s = servable(&engine);
        // Huge delay + big batch: requests sit in the queue until
        // shutdown forces the drain.
        let cfg = ServeConfig {
            max_batch: 64,
            max_delay_us: 10_000_000,
            queue_cap: 64,
            workers: 1,
            buckets: vec![],
        };
        let mut server = Server::start(&s, &cfg).unwrap();
        let pending: Vec<Pending> =
            (0..5).map(|i| server.submit(vec![i as f32; 6]).unwrap()).collect();
        let stats = server.shutdown();
        assert_eq!(stats.requests, 5, "shutdown must serve admitted requests");
        for p in pending {
            let probs = p.wait().unwrap();
            assert_eq!(probs.len(), 3);
        }
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let engine = create(EngineKind::Threaded, 2);
        let s = servable(&engine);
        // Queue of 1 and a long delay: the first request parks in the
        // queue, the second non-blocking submit must bounce.
        let cfg = ServeConfig {
            max_batch: 8,
            max_delay_us: 2_000_000,
            queue_cap: 1,
            workers: 1,
            buckets: vec![],
        };
        let mut server = Server::start(&s, &cfg).unwrap();
        let first = server.submit(vec![0.5; 6]).unwrap();
        let err = server.try_submit(vec![0.7; 6]);
        assert!(err.is_err(), "queue of 1 must reject the second request");
        let stats = server.stats();
        assert_eq!(stats.rejected, 1);
        // the parked request is still served (delay expires or shutdown)
        server.shutdown();
        assert_eq!(first.wait().unwrap().len(), 3);
    }

    #[test]
    fn closed_loop_multi_worker_roundtrip() {
        let engine = create(EngineKind::Threaded, 4);
        let s = servable(&engine);
        let cfg = ServeConfig {
            max_batch: 8,
            max_delay_us: 1_000,
            queue_cap: 128,
            workers: 2,
            buckets: vec![],
        };
        let mut server = Server::start(&s, &cfg).unwrap();
        let samples: Vec<Vec<f32>> = (0..16).map(|i| vec![(i as f32).cos(); 6]).collect();
        let report = closed_loop(&server, 8, 10, &samples);
        assert_eq!(report.errors, 0);
        assert_eq!(report.requests, 80);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 80);
        assert!(stats.mean_batch >= 1.0);
    }
}

//! The graph executor: bind a symbol, plan memory, run forward/backward
//! through the dependency engine.
//!
//! `bind` freezes a [`Graph`] against concrete argument arrays: shapes are
//! inferred, the backward pass is appended (training mode), elementwise
//! chains are optionally fused, the memory planner assigns storage, and
//! every node becomes a prepared template.
//!
//! Because everything about the schedule is known at bind time, the node
//! sequence is also compiled into static [`RunPlan`]s (ISSUE 3): one for
//! the forward pass, one for the backward.  [`Executor::forward`] /
//! [`Executor::backward`] then hand the whole plan to the engine as a
//! single operation — the dependency DAG replays with lock-free
//! countdowns instead of paying per-node scheduling — while plan
//! boundaries still synchronize through engine vars, so imperative
//! `NDArray` work (`w -= eta * g`), KVStore traffic and other executors
//! interleave exactly as before (the paper's joint scheduling of both
//! paradigms).  `BindConfig { replay: false, .. }` keeps the classic
//! push-one-op-per-node path; the two are bitwise equivalent.
//!
//! Internal storage (plan blocks, workspace) is materialized through the
//! [storage pool](crate::ndarray::pool) with no zero-fill — every block's
//! first use each step fully overwrites it — so rebinding and steady-state
//! stepping allocate nothing once the pool is warm.

pub mod native_ops;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::engine::{EngineRef, PlanOpSpec, RunPlan};
use crate::error::{Error, Result};
use crate::graph::autodiff::build_backward;
use crate::graph::memory::{default_external, plan_memory, AllocStrategy, MemPlan};
use crate::graph::optimize::{fuse_elementwise, fuse_epilogue};
use crate::graph::recompute::{self, MemOpt, RecomputeInfo};
use crate::graph::{infer_shapes, Entry, Graph, Op, ShapeMap};
use crate::ndarray::{NDArray, Storage};
use crate::symbol::Symbol;
use native_ops::OpArgs;

/// Binding configuration.
#[derive(Debug, Clone, Copy)]
pub struct BindConfig {
    /// Memory allocation strategy (Figure 7 comparison).
    pub strategy: AllocStrategy,
    /// Build the backward pass (honored only when `grads` is also true:
    /// backward without gradient buffers has nothing to write).
    pub training: bool,
    /// Allocate gradient buffers.  `false` is the forward-only fast path
    /// (inference binds): no backward graph is built and no grad NDArrays
    /// are materialized, regardless of `grad_names` — the configuration
    /// every serving executor uses.
    pub grads: bool,
    /// Fuse elementwise chains (§3.1 operator grouping).
    pub fuse: bool,
    /// Compile the node sequence into static [`RunPlan`]s at bind time
    /// and replay them each step (one engine op per pass, lock-free
    /// in-plan scheduling) instead of pushing one engine op per node.
    /// Scheduling-equivalent — results are bitwise identical; `false`
    /// keeps the per-op dynamic path (benches, equivalence tests).
    pub replay: bool,
    /// Sublinear-memory training: `MemOpt::Recompute` drops interior
    /// activations after forward and recomputes them during backward
    /// ([`crate::graph::recompute`]).  Bitwise-identical to `Off`; only
    /// peak memory and step time change.  Ignored on inference binds.
    pub memopt: MemOpt,
}

impl Default for BindConfig {
    fn default() -> Self {
        BindConfig {
            strategy: AllocStrategy::Both,
            training: true,
            grads: true,
            fuse: true,
            replay: true,
            memopt: MemOpt::Off,
        }
    }
}

impl BindConfig {
    /// Forward-only inference bind: no backward pass, no gradient buffers.
    pub fn inference() -> Self {
        BindConfig {
            strategy: AllocStrategy::Both,
            training: false,
            grads: false,
            fuse: true,
            replay: true,
            memopt: MemOpt::Off,
        }
    }
}

/// Per-parameter gradient-ready hook (data-parallel training): invoked
/// with `(grad name, step, ok)` on the engine worker that just wrote
/// the gradient's **final** value for the current backward pass — i.e.
/// the moment the layer's gradient retires, while the rest of backward
/// is still running on other workers.
///
/// With `ok == true`, the named gradient buffer is safe to *read*
/// directly inside the hook (nothing later in the pass writes it, and
/// engine ordering keeps all external writers behind the pass), which
/// is what lets a KVStore push start mid-backward instead of queuing
/// behind the whole pass.  `ok == false` means the writing kernel
/// panicked: the hook still fires (so a trainer waiting on a push latch
/// is never stranded) but the buffer contents are unspecified — treat
/// the pass as failed, do not deliver the gradient.  (A panic in an
/// *upstream* op follows the engine-wide report-and-continue policy and
/// is not reflected here.)  The hook runs on the critical path of the
/// pass — keep it short (copy out and return); schedule heavy work as
/// engine ops.
pub type GradReadyHook = Arc<dyn Fn(&str, u64, bool) + Send + Sync>;

/// Shared, swappable hook slot captured by the compiled op bodies.
#[derive(Default)]
struct HookSlot(std::sync::RwLock<Option<GradReadyHook>>);

impl HookSlot {
    fn fire(&self, names: &[String], step: u64, ok: bool) {
        let hook = self.0.read().unwrap().clone();
        if let Some(h) = hook {
            for n in names {
                h(n, step, ok);
            }
        }
    }
}

/// Run a template and then fire the grad-ready hooks for the gradients
/// whose final value it wrote.  The hooks fire even when the kernel
/// panicked (with `ok = false`; the panic is re-raised afterwards) so a
/// wedged kernel can never strand a trainer waiting on its push latch —
/// and never silently delivers a half-written gradient either.
fn run_template_with_hooks(
    t: &NodeTemplate,
    training: bool,
    step: u64,
    slot: &HookSlot,
    names: &[String],
) {
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_template(t, training, step)
    }));
    slot.fire(names, step, r.is_ok());
    if let Err(e) = r {
        std::panic::resume_unwind(e);
    }
}

/// Prepared per-node execution template.
struct NodeTemplate {
    op: Op,
    name: &'static str,
    /// Analytic FLOP estimate (sim::cost::op_flops), handed to the engine
    /// as the dispatch cost hint for intra-op thread budgeting.
    cost: f64,
    in_storages: Vec<Arc<Storage>>,
    in_sizes: Vec<usize>,
    in_shapes: Vec<Vec<usize>>,
    /// true when this input aliases output 0 (inplace plan).
    aliased: Vec<bool>,
    out_storages: Vec<Arc<Storage>>,
    out_sizes: Vec<usize>,
    out_shapes: Vec<Vec<usize>>,
    ws: Option<(Arc<Storage>, usize)>,
    read_vars: Vec<crate::engine::VarHandle>,
    write_vars: Vec<crate::engine::VarHandle>,
}

/// Execute one prepared node template (shared by the dynamic push path
/// and the run-plan replay path; both invoke it under the same engine
/// grants).
fn run_template(t: &NodeTemplate, training: bool, step: u64) {
    // SAFETY: the engine granted shared reads on every input var and
    // exclusive writes on every output/workspace var.
    crate::metrics::time(t.name, || unsafe {
        let in_data: Vec<Option<&[f32]>> = t
            .in_storages
            .iter()
            .zip(&t.in_sizes)
            .zip(&t.aliased)
            .map(|((s, &n), &al)| if al { None } else { Some(&s.slice()[..n]) })
            .collect();
        let out: Vec<&mut [f32]> = t
            .out_storages
            .iter()
            .zip(&t.out_sizes)
            .map(|(s, &n)| &mut s.slice_mut()[..n])
            .collect();
        let workspace = t.ws.as_ref().map(|(s, n)| &mut s.slice_mut()[..*n]);
        native_ops::execute(
            &t.op,
            OpArgs {
                in_data,
                in_shapes: t.in_shapes.clone(),
                out,
                out_shapes: t.out_shapes.clone(),
                workspace,
                training,
                step,
            },
        );
    })
}

/// A bound, runnable computation (paper §2.1 "bind").
pub struct Executor {
    graph: Graph,
    shapes: ShapeMap,
    engine: EngineRef,
    templates: Vec<Option<Arc<NodeTemplate>>>,
    args: HashMap<String, NDArray>,
    grads: HashMap<String, NDArray>,
    outputs_arr: Vec<NDArray>,
    training: bool,
    step: AtomicU64,
    plan: MemPlan,
    num_forward: usize,
    /// What the recompute rewrite did (`None` when `memopt` is off or the
    /// rewrite was an identity on this graph).
    recompute_info: Option<RecomputeInfo>,
    /// Planned `(total, peak)` internal bytes of the memopt-off bind,
    /// kept when a recompute bind wants to report its saving.
    baseline_bytes: Option<(usize, usize)>,
    /// Static run-plans compiled at bind time (`cfg.replay`); `None`
    /// falls back to pushing one engine op per node.
    fwd_plan: Option<Arc<RunPlan>>,
    bwd_plan: Option<Arc<RunPlan>>,
    /// Swappable grad-ready hook, shared with the compiled op bodies.
    grad_hook: Arc<HookSlot>,
    /// node id -> gradients whose *final* value that node writes (the
    /// last writer of each grad var in program order).
    grad_ready_at: HashMap<usize, Vec<String>>,
    /// Keep-alives for the planner storage blocks and dedicated scratch:
    /// templates and plans hold their `VarHandle`s, and a handle only
    /// orders operations while its variable is alive (the slab drops
    /// stale handles — no resurrect-on-push like the old HashMap), so
    /// these arrays must live exactly as long as the executor.  Dropping
    /// them deletes the vars and recycles the buffers through the pool.
    _storage_arrays: Vec<NDArray>,
    _scratch_arrays: Vec<NDArray>,
}

impl Executor {
    /// Bind a single-head symbol.  `args` must contain one array per
    /// argument variable; `grad_names` selects which variables receive
    /// gradient buffers (training mode).
    pub fn bind(
        symbol: &Symbol,
        engine: EngineRef,
        args: HashMap<String, NDArray>,
        grad_names: &[&str],
        cfg: BindConfig,
    ) -> Result<Executor> {
        let graph = Symbol::to_graph(std::slice::from_ref(symbol));
        Self::bind_graph(graph, engine, args, grad_names, cfg)
    }

    /// Bind an explicit graph (used by the model zoo and benches).
    pub fn bind_graph(
        mut graph: Graph,
        engine: EngineRef,
        args: HashMap<String, NDArray>,
        grad_names: &[&str],
        cfg: BindConfig,
    ) -> Result<Executor> {
        graph.validate()?;

        // 1. autodiff (skipped entirely on the forward-only fast path)
        let training = cfg.training && cfg.grads;
        let mut grad_entries: HashMap<String, Entry> = HashMap::new();
        if training {
            let wrt: Vec<_> = grad_names
                .iter()
                .map(|n| {
                    graph
                        .find_variable(n)
                        .ok_or_else(|| Error::Bind(format!("unknown grad variable '{n}'")))
                })
                .collect::<Result<_>>()?;
            let gi = build_backward(&mut graph, &wrt)?;
            for (&vid, &e) in &gi.var_grads {
                grad_entries.insert(graph.nodes[vid].name.clone(), e);
            }
        }

        // 2. fuse elementwise chains (protect grad entries from being
        //    swallowed), then fold surviving chains that trail a GEMM /
        //    conv into the producer's epilogue so they run while the
        //    output tile is cache-hot
        if cfg.fuse {
            let protected: Vec<Entry> = grad_entries.values().copied().collect();
            let (fused, emap) = fuse_elementwise(&graph, &protected);
            for e in grad_entries.values_mut() {
                *e = emap[e];
            }
            let protected: Vec<Entry> = grad_entries.values().copied().collect();
            let (fused, emap) = fuse_epilogue(&fused, &protected);
            for e in grad_entries.values_mut() {
                *e = emap[e];
            }
            graph = fused;
            graph.validate()?;
        }

        // 3. shapes (the variable set is fixed from here on: the
        //    recompute rewrite below never adds or renames variables)
        let var_shapes: HashMap<String, Vec<usize>> = graph
            .variables()
            .into_iter()
            .map(|vid| {
                let name = graph.nodes[vid].name.clone();
                let arr = args
                    .get(&name)
                    .ok_or_else(|| Error::Bind(format!("missing argument array '{name}'")))?;
                Ok((name, arr.shape().to_vec()))
            })
            .collect::<Result<_>>()?;

        // 3b. sublinear-memory rewrite: runs after fusion so recompute
        //     clones carry their epilogues, and before planning so the
        //     planner frees dropped activations at their last forward
        //     reader.  The pre-rewrite plan is kept for baseline
        //     reporting (what memopt-off would have used).
        let mut recompute_info: Option<RecomputeInfo> = None;
        let mut baseline_bytes: Option<(usize, usize)> = None;
        if training {
            if let MemOpt::Recompute { segments } = cfg.memopt {
                let pre_shapes = infer_shapes(&graph, &var_shapes)?;
                let extra: Vec<Entry> = grad_entries.values().copied().collect();
                let ext = default_external(&graph, &extra);
                let base = plan_memory(&graph, &pre_shapes, &ext, cfg.strategy);
                baseline_bytes = Some((base.total_internal_bytes, base.peak_bytes));
                let bounds = recompute::segment_boundaries(&graph, &pre_shapes, segments);
                let (rewritten, emap, info) =
                    recompute::apply_recompute(&graph, &pre_shapes, &bounds)?;
                for e in grad_entries.values_mut() {
                    *e = emap[e];
                }
                graph = rewritten;
                recompute_info = if info.recompute_nodes > 0 { Some(info) } else { None };
            }
        }
        let shapes = infer_shapes(&graph, &var_shapes)?;

        // 4. memory plan
        let extra: Vec<Entry> = grad_entries.values().copied().collect();
        let external = default_external(&graph, &extra);
        let plan = plan_memory(&graph, &shapes, &external, cfg.strategy);

        // 5. materialize storage — the planner's co-share blocks map
        //    straight onto pooled slots: drawn from the storage pool with
        //    no zero-fill (each block's first use every step fully
        //    overwrites it), recycled back at executor drop.
        let storage_arrays: Vec<NDArray> = plan
            .storage_elems()
            .map(|elems| NDArray::alloc_uninit_on(&[elems], Arc::clone(&engine)))
            .collect();

        // entry -> NDArray
        let mut entry_arrays: HashMap<Entry, NDArray> = HashMap::new();
        for (id, node) in graph.nodes.iter().enumerate() {
            for out in 0..graph.num_outputs_of(id) {
                let e = Entry { node: id, out };
                let shape = &shapes[id][out];
                let arr = if node.op.is_variable() {
                    let a = args.get(&node.name).expect("checked above");
                    if a.shape() != shape.as_slice() {
                        return Err(Error::Bind(format!(
                            "argument '{}' shape {:?} != expected {:?}",
                            node.name,
                            a.shape(),
                            shape
                        )));
                    }
                    a.clone()
                } else if let Some(&sid) = plan.storage_of.get(&e) {
                    storage_arrays[sid].alias(shape)
                } else {
                    // external non-variable entry (graph output / grad)
                    NDArray::zeros_on(shape, Arc::clone(&engine))
                };
                entry_arrays.insert(e, arr);
            }
        }

        // 6. templates
        let ws_bytes = crate::graph::workspace_bytes(&graph, &shapes);
        let mut templates: Vec<Option<Arc<NodeTemplate>>> =
            Vec::with_capacity(graph.nodes.len());
        let mut scratch_arrays: Vec<NDArray> = Vec::new();
        for (id, node) in graph.nodes.iter().enumerate() {
            if node.op.is_variable() {
                templates.push(None);
                continue;
            }
            let nout = graph.num_outputs_of(id);
            let outs: Vec<&NDArray> = (0..nout)
                .map(|o| entry_arrays.get(&Entry { node: id, out: o }).expect("out array"))
                .collect();
            let ins: Vec<&NDArray> =
                node.inputs.iter().map(|e| entry_arrays.get(e).expect("in array")).collect();
            let aliased: Vec<bool> = ins
                .iter()
                .map(|i| Arc::ptr_eq(&i.storage(), &outs[0].storage()))
                .collect();
            let ws = if ws_bytes[id] > 0 {
                let sid = plan.workspace_of.get(&id);
                match sid {
                    Some(&sid) => Some((storage_arrays[sid].storage(), ws_bytes[id] / 4)),
                    None => {
                        // dedicated scratch: pooled, never pre-zeroed,
                        // kept alive (with its var) by the executor
                        let a = NDArray::alloc_uninit_on(&[ws_bytes[id] / 4], Arc::clone(&engine));
                        let s = (a.storage(), ws_bytes[id] / 4);
                        scratch_arrays.push(a);
                        Some(s)
                    }
                }
            } else {
                None
            };
            let mut read_vars: Vec<_> = ins.iter().map(|a| a.var()).collect();
            let mut write_vars: Vec<_> = outs.iter().map(|a| a.var()).collect();
            if let Some(&sid) = plan.workspace_of.get(&id) {
                write_vars.push(storage_arrays[sid].var());
            }
            // control deps from co-share plan are implicit: co-tenant
            // entries share a storage var, serialized by push order.
            read_vars.dedup();
            let in_shapes: Vec<Vec<usize>> =
                node.inputs.iter().map(|e| shapes[e.node][e.out].clone()).collect();
            let out_shapes: Vec<Vec<usize>> = (0..nout).map(|o| shapes[id][o].clone()).collect();
            let cost = crate::sim::cost::op_flops(&node.op, &in_shapes, &out_shapes);
            templates.push(Some(Arc::new(NodeTemplate {
                op: node.op.clone(),
                // Recompute clones get their own span/metrics name so
                // timelines show the extra backward-side forward work
                // ("plan.recompute" on the replay path).
                name: if recompute::is_recompute_name(&node.name) {
                    "recompute"
                } else {
                    node.op.type_name()
                },
                cost,
                in_storages: ins.iter().map(|a| a.storage()).collect(),
                in_sizes: ins.iter().map(|a| a.size()).collect(),
                in_shapes,
                aliased,
                out_storages: outs.iter().map(|a| a.storage()).collect(),
                out_sizes: outs.iter().map(|a| a.size()).collect(),
                out_shapes,
                ws,
                read_vars,
                write_vars,
            })));
        }

        let outputs_arr: Vec<NDArray> =
            graph.outputs.iter().map(|e| entry_arrays[e].clone()).collect();
        let grads: HashMap<String, NDArray> = grad_entries
            .iter()
            .map(|(name, e)| (name.clone(), entry_arrays[e].clone()))
            .collect();

        let num_forward =
            if graph.num_forward == 0 { graph.nodes.len() } else { graph.num_forward };

        // Grad-ready hook wiring (data-parallel overlap): find, for every
        // gradient array, the node that writes its final value — the last
        // writer of its var in program order (gradient accumulation via
        // AddN makes that the accumulator).  Those nodes' bodies fire the
        // hook right after executing, on both scheduling paths.
        let grad_hook = Arc::new(HookSlot::default());
        let grad_ready_at: HashMap<usize, Vec<String>> = {
            let by_var: HashMap<u64, &String> =
                grads.iter().map(|(n, a)| (a.var().id(), n)).collect();
            let mut last_writer: HashMap<&String, usize> = HashMap::new();
            for (id, tmpl) in templates.iter().enumerate() {
                if let Some(t) = tmpl {
                    for v in &t.write_vars {
                        if let Some(&name) = by_var.get(&v.id()) {
                            last_writer.insert(name, id);
                        }
                    }
                }
            }
            let mut at: HashMap<usize, Vec<String>> = HashMap::new();
            for (name, id) in last_writer {
                at.entry(id).or_default().push(name.clone());
            }
            for names in at.values_mut() {
                names.sort(); // deterministic fire order within one node
            }
            at
        };

        // 7. compile the static run-plans (ISSUE 3): the same (reads,
        //    writes, cost) tuples the dynamic path would push, with
        //    reusable bodies — replayed as one engine op per pass.
        let (fwd_plan, bwd_plan) = if cfg.replay {
            let mut fwd_specs: Vec<PlanOpSpec> = Vec::new();
            let mut bwd_specs: Vec<PlanOpSpec> = Vec::new();
            for (id, tmpl) in templates.iter().enumerate() {
                let t = match tmpl {
                    Some(t) => Arc::clone(t),
                    None => continue,
                };
                let body_t = Arc::clone(&t);
                let body: crate::engine::PlanBody = match grad_ready_at.get(&id) {
                    Some(names) => {
                        let names = names.clone();
                        let slot = Arc::clone(&grad_hook);
                        Arc::new(move |step: u64| {
                            run_template_with_hooks(&body_t, training, step, &slot, &names)
                        })
                    }
                    None => Arc::new(move |step: u64| run_template(&body_t, training, step)),
                };
                let spec = PlanOpSpec {
                    name: t.name,
                    reads: t.read_vars.clone(),
                    writes: t.write_vars.clone(),
                    cost: t.cost,
                    body,
                };
                if id < num_forward {
                    fwd_specs.push(spec);
                } else {
                    bwd_specs.push(spec);
                }
            }
            let fwd = Arc::new(RunPlan::compile(fwd_specs));
            let bwd = if bwd_specs.is_empty() {
                None
            } else {
                Some(Arc::new(RunPlan::compile(bwd_specs)))
            };
            (Some(fwd), bwd)
        } else {
            (None, None)
        };

        Ok(Executor {
            graph,
            shapes,
            engine,
            templates,
            args,
            grads,
            outputs_arr,
            training,
            step: AtomicU64::new(0),
            plan,
            num_forward,
            recompute_info,
            baseline_bytes,
            fwd_plan,
            bwd_plan,
            grad_hook,
            grad_ready_at,
            _storage_arrays: storage_arrays,
            _scratch_arrays: scratch_arrays,
        })
    }

    fn push_node(&self, id: usize, step: u64) {
        let tmpl = match &self.templates[id] {
            Some(t) => Arc::clone(t),
            None => return,
        };
        let training = self.training;
        let t = Arc::clone(&tmpl);
        let hooks = self.grad_ready_at.get(&id).cloned();
        let slot = Arc::clone(&self.grad_hook);
        self.engine.push_costed(
            tmpl.name,
            tmpl.read_vars.clone(),
            tmpl.write_vars.clone(),
            tmpl.cost,
            Box::new(move || match &hooks {
                Some(names) => run_template_with_hooks(&t, training, step, &slot, names),
                None => run_template(&t, training, step),
            }),
        );
    }

    /// Schedule the forward pass (returns immediately): one replayed
    /// run-plan op on the replay path, or one engine op per node on the
    /// dynamic path — bitwise-identical either way.
    pub fn forward(&self) {
        let step = self.step.fetch_add(1, Ordering::Relaxed) + 1;
        self.dispatch_forward(step);
    }

    /// [`Executor::forward`] with an explicit step number.  The step
    /// seeds step-dependent ops (Dropout masks), so a data-parallel
    /// trainer passes the same *round* number to every replica to keep
    /// per-shard computation identical whatever the device count.
    /// Subsequent [`Executor::forward`] calls continue from `step`.
    pub fn forward_at(&self, step: u64) {
        self.step.store(step, Ordering::Relaxed);
        self.dispatch_forward(step);
    }

    fn dispatch_forward(&self, step: u64) {
        match &self.fwd_plan {
            Some(p) => self.engine.run_plan(p, step),
            None => {
                for id in 0..self.num_forward {
                    self.push_node(id, step);
                }
            }
        }
    }

    /// Schedule the backward pass (returns immediately).
    pub fn backward(&self) -> Result<()> {
        let step = self.step.load(Ordering::Relaxed);
        self.backward_at(step)
    }

    /// [`Executor::backward`] with an explicit step number (pairs with
    /// [`Executor::forward_at`]).
    pub fn backward_at(&self, step: u64) -> Result<()> {
        if !self.training {
            return Err(Error::Bind("executor bound with training=false".into()));
        }
        match &self.bwd_plan {
            Some(p) => self.engine.run_plan(p, step),
            None => {
                for id in self.num_forward..self.graph.nodes.len() {
                    self.push_node(id, step);
                }
            }
        }
        Ok(())
    }

    /// The step number of the most recently scheduled forward pass.
    pub fn steps(&self) -> u64 {
        self.step.load(Ordering::Relaxed)
    }

    /// Install the grad-ready hook (see [`GradReadyHook`]): it fires for
    /// every parameter gradient, on every backward pass, the moment that
    /// gradient's final value has been written.  Replaces any previous
    /// hook; affects passes scheduled after the call.
    pub fn set_grad_ready_hook(&self, hook: GradReadyHook) {
        *self.grad_hook.0.write().unwrap() = Some(hook);
    }

    /// Remove the grad-ready hook (passes already in flight may still
    /// observe the old hook).
    pub fn clear_grad_ready_hook(&self) {
        *self.grad_hook.0.write().unwrap() = None;
    }

    /// Forward + backward in one call (paper's `net.forward_backward()`).
    pub fn forward_backward(&self) -> Result<()> {
        self.forward();
        self.backward()
    }

    /// Output arrays (reading them waits for completion).
    pub fn outputs(&self) -> &[NDArray] {
        &self.outputs_arr
    }

    /// Argument array by name.
    pub fn arg(&self, name: &str) -> Option<&NDArray> {
        self.args.get(name)
    }

    /// Gradient array for a variable.
    pub fn grad(&self, name: &str) -> Option<&NDArray> {
        self.grads.get(name)
    }

    /// All (name, grad) pairs.
    pub fn grads(&self) -> &HashMap<String, NDArray> {
        &self.grads
    }

    /// Block until everything pushed so far has completed.
    pub fn wait(&self) {
        self.engine.wait_all();
    }

    /// Planned internal-variable bytes (the Figure 7 metric).
    pub fn internal_bytes(&self) -> usize {
        self.plan.total_internal_bytes
    }

    /// Planned peak of simultaneously-live internal bytes — the metric
    /// the recompute rewrite shrinks.
    pub fn planned_peak_bytes(&self) -> usize {
        self.plan.peak_bytes
    }

    /// Planned `(total, peak)` internal bytes the same bind would have
    /// used with `MemOpt::Off` (only recorded on recompute binds).
    pub fn baseline_bytes(&self) -> Option<(usize, usize)> {
        self.baseline_bytes
    }

    /// What the recompute rewrite did, when `memopt` was on and the graph
    /// had something to drop.
    pub fn recompute_info(&self) -> Option<&RecomputeInfo> {
        self.recompute_info.as_ref()
    }

    /// The bound graph (post autodiff/fusion).
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Inferred shapes.
    pub fn shapes(&self) -> &ShapeMap {
        &self.shapes
    }

    /// The (single) softmax head's probability array and its bound label
    /// array.
    fn softmax_head(&self) -> Result<(&NDArray, &NDArray)> {
        let head = self
            .graph
            .outputs
            .iter()
            .find(|e| matches!(self.graph.nodes[e.node].op, Op::SoftmaxOutput))
            .copied()
            .ok_or_else(|| Error::Bind("no SoftmaxOutput head".into()))?;
        let label_entry = self.graph.nodes[head.node].inputs[1];
        let label_name = &self.graph.nodes[label_entry.node].name;
        let labels = self
            .args
            .get(label_name)
            .ok_or_else(|| Error::Bind(format!("label '{label_name}' unbound")))?;
        let idx = self.graph.outputs.iter().position(|e| *e == head).unwrap();
        Ok((&self.outputs_arr[idx], labels))
    }

    /// Mean cross-entropy loss of the (single) softmax head against its
    /// bound label array.  Waits for the forward pass.
    pub fn softmax_xent_loss(&self) -> Result<f32> {
        self.softmax_metrics().map(|(loss, _)| loss)
    }

    /// Accuracy of the softmax head against its label array.
    pub fn softmax_accuracy(&self) -> Result<f32> {
        self.softmax_metrics().map(|(_, acc)| acc)
    }

    /// `(loss, accuracy)` of the softmax head in one synchronized read —
    /// the training loop's per-batch metric call.  One wait and one copy
    /// of the probabilities instead of two of each (`fit` used to call
    /// [`Executor::softmax_xent_loss`] and [`Executor::softmax_accuracy`]
    /// back to back).
    pub fn softmax_metrics(&self) -> Result<(f32, f32)> {
        let (probs_arr, labels) = self.softmax_head()?;
        let probs = probs_arr.to_vec();
        let lab = labels.to_vec();
        let (m, n) = (probs_arr.shape()[0], probs_arr.shape()[1]);
        let loss = crate::ndarray::kernels::xent_loss(&probs, &lab, m, n);
        let mut preds = vec![0.0; m];
        crate::ndarray::kernels::argmax_rows(&probs, &mut preds, m, n);
        let correct = preds.iter().zip(&lab).filter(|(p, l)| p == l).count();
        Ok((loss, correct as f32 / m as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::symbol::Act;

    fn mlp_symbol() -> Symbol {
        Symbol::var("data")
            .fully_connected("fc1", 32)
            .activation("relu1", Act::Relu)
            .fully_connected("fc2", 4)
            .softmax_output("softmax")
    }

    fn mlp_args(batch: usize, engine: EngineRef, seed: u64) -> HashMap<String, NDArray> {
        let mut args = HashMap::new();
        args.insert(
            "data".into(),
            NDArray::randn_on(&[batch, 16], 0.0, 1.0, seed, Arc::clone(&engine)),
        );
        args.insert(
            "fc1_weight".into(),
            NDArray::randn_on(&[32, 16], 0.0, 0.3, seed + 1, Arc::clone(&engine)),
        );
        args.insert("fc1_bias".into(), NDArray::zeros_on(&[32], Arc::clone(&engine)));
        args.insert(
            "fc2_weight".into(),
            NDArray::randn_on(&[4, 32], 0.0, 0.3, seed + 2, Arc::clone(&engine)),
        );
        args.insert("fc2_bias".into(), NDArray::zeros_on(&[4], Arc::clone(&engine)));
        let labels: Vec<f32> = (0..batch).map(|i| (i % 4) as f32).collect();
        args.insert(
            "softmax_label".into(),
            NDArray::from_vec_on(&[batch], labels, Arc::clone(&engine)),
        );
        args
    }

    const PARAMS: [&str; 4] = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"];

    #[test]
    fn forward_produces_valid_probabilities() {
        let engine = create(EngineKind::Threaded, 4);
        let exec = Executor::bind(
            &mlp_symbol(),
            Arc::clone(&engine),
            mlp_args(8, engine, 3),
            &PARAMS,
            BindConfig { training: false, ..Default::default() },
        )
        .unwrap();
        exec.forward();
        let probs = exec.outputs()[0].to_vec();
        assert_eq!(probs.len(), 8 * 4);
        for row in probs.chunks(4) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "{s}");
            assert!(row.iter().all(|p| *p >= 0.0));
        }
    }

    #[test]
    fn strategies_agree_numerically() {
        // All four allocation strategies must produce identical outputs
        // and gradients (co-share/inplace change layout, not semantics).
        let mut baseline: Option<(Vec<f32>, Vec<f32>)> = None;
        for strategy in AllocStrategy::all() {
            let engine = create(EngineKind::Threaded, 4);
            let exec = Executor::bind(
                &mlp_symbol(),
                Arc::clone(&engine),
                mlp_args(8, Arc::clone(&engine), 7),
                &PARAMS,
                BindConfig { strategy, training: true, fuse: false, ..Default::default() },
            )
            .unwrap();
            exec.forward_backward().unwrap();
            exec.wait();
            let probs = exec.outputs()[0].to_vec();
            let gw = exec.grad("fc1_weight").unwrap().to_vec();
            match &baseline {
                None => baseline = Some((probs, gw)),
                Some((p0, g0)) => {
                    for (x, y) in probs.iter().zip(p0) {
                        assert!((x - y).abs() < 1e-5, "{strategy}: probs differ");
                    }
                    for (x, y) in gw.iter().zip(g0) {
                        assert!((x - y).abs() < 1e-5, "{strategy}: grads differ");
                    }
                }
            }
        }
    }

    #[test]
    fn naive_and_threaded_engines_agree() {
        let mut results = vec![];
        for kind in [EngineKind::Naive, EngineKind::Threaded] {
            let engine = create(kind, 4);
            let exec = Executor::bind(
                &mlp_symbol(),
                Arc::clone(&engine),
                mlp_args(8, Arc::clone(&engine), 11),
                &PARAMS,
                BindConfig::default(),
            )
            .unwrap();
            exec.forward_backward().unwrap();
            exec.wait();
            results.push(exec.grad("fc2_weight").unwrap().to_vec());
        }
        for (a, b) in results[0].iter().zip(&results[1]) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gradient_check_mlp_end_to_end() {
        // Numerical gradient check through the whole executor: perturb one
        // weight, compare loss delta to the analytic gradient.
        let engine = create(EngineKind::Threaded, 2);
        let args = mlp_args(4, Arc::clone(&engine), 21);
        let exec = Executor::bind(
            &mlp_symbol(),
            Arc::clone(&engine),
            args.clone(),
            &PARAMS,
            BindConfig { fuse: false, ..Default::default() },
        )
        .unwrap();
        exec.forward_backward().unwrap();
        exec.wait();
        let analytic = exec.grad("fc2_weight").unwrap().to_vec();

        let w = args.get("fc2_weight").unwrap();
        let orig = w.to_vec();
        let eps = 1e-2f32;
        for idx in [0usize, 7, 63] {
            for (sign, store) in [(1.0f32, 0usize), (-1.0, 1)].iter() {
                let mut pert = orig.clone();
                pert[idx] += sign * eps;
                w.copy_from_slice_sync(&pert);
                exec.forward();
                let l = exec.softmax_xent_loss().unwrap();
                if *store == 0 {
                    PLUS.with(|p| p.set(l));
                } else {
                    let lp = PLUS.with(|p| p.get());
                    let num = (lp - l) / (2.0 * eps);
                    assert!(
                        (num - analytic[idx]).abs() < 2e-2,
                        "idx {idx}: numeric {num} vs analytic {}",
                        analytic[idx]
                    );
                }
            }
        }
        w.copy_from_slice_sync(&orig);
        std::thread_local! {
            static PLUS: std::cell::Cell<f32> = const { std::cell::Cell::new(0.0) };
        }
    }

    #[test]
    fn loss_decreases_with_sgd() {
        // The paper's §2.2 training loop: forward_backward + imperative
        // update on the same engine.
        let engine = create(EngineKind::Threaded, 4);
        let args = mlp_args(16, Arc::clone(&engine), 31);
        let exec = Executor::bind(
            &mlp_symbol(),
            Arc::clone(&engine),
            args.clone(),
            &PARAMS,
            BindConfig::default(),
        )
        .unwrap();
        let mut losses = vec![];
        for _ in 0..30 {
            exec.forward_backward().unwrap();
            for p in PARAMS {
                let w = exec.arg(p).unwrap();
                let g = exec.grad(p).unwrap();
                w.sub_scaled_(g, 0.5); // imperative update, same engine
            }
            losses.push(exec.softmax_xent_loss().unwrap());
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "loss did not decrease: {losses:?}"
        );
    }

    #[test]
    fn inference_bind_allocates_no_grad_arrays() {
        // The forward-only fast path: even with grad names supplied, an
        // inference bind must not materialize a single gradient NDArray
        // and must not accept backward().
        let engine = create(EngineKind::Threaded, 2);
        let exec = Executor::bind(
            &mlp_symbol(),
            Arc::clone(&engine),
            mlp_args(4, Arc::clone(&engine), 5),
            &PARAMS,
            BindConfig::inference(),
        )
        .unwrap();
        assert!(exec.grads().is_empty(), "inference bind allocated grads");
        assert!(exec.backward().is_err());
        exec.forward();
        exec.wait();
        // and the outputs are still valid probabilities
        let probs = exec.outputs()[0].to_vec();
        for row in probs.chunks(4) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn eval_mode_rejects_backward() {
        let engine = create(EngineKind::Threaded, 2);
        let exec = Executor::bind(
            &mlp_symbol(),
            Arc::clone(&engine),
            mlp_args(4, engine, 1),
            &[],
            BindConfig { training: false, ..Default::default() },
        )
        .unwrap();
        exec.forward();
        assert!(exec.backward().is_err());
    }

    #[test]
    fn missing_argument_is_bind_error() {
        let engine = create(EngineKind::Threaded, 2);
        let mut args = mlp_args(4, Arc::clone(&engine), 1);
        args.remove("fc1_bias");
        let err = Executor::bind(&mlp_symbol(), engine, args, &PARAMS, BindConfig::default());
        assert!(err.is_err());
    }

    #[test]
    fn grad_ready_hook_fires_once_per_grad_with_final_value() {
        // On both scheduling paths: every parameter gradient fires
        // exactly once per backward, and the buffer read inside the hook
        // already holds the final value (== what a post-wait read sees).
        for replay in [true, false] {
            let engine = create(EngineKind::Threaded, 4);
            let exec = Executor::bind(
                &mlp_symbol(),
                Arc::clone(&engine),
                mlp_args(8, Arc::clone(&engine), 13),
                &PARAMS,
                BindConfig { replay, ..Default::default() },
            )
            .unwrap();
            let seen: Arc<std::sync::Mutex<Vec<(String, u64, Vec<f32>)>>> =
                Arc::new(std::sync::Mutex::new(Vec::new()));
            let s2 = Arc::clone(&seen);
            let grads: std::collections::HashMap<String, (Arc<Storage>, usize)> = PARAMS
                .iter()
                .map(|&p| {
                    let g = exec.grad(p).unwrap();
                    (p.to_string(), (g.storage(), g.size()))
                })
                .collect();
            exec.set_grad_ready_hook(Arc::new(move |name, step, ok| {
                assert!(ok, "kernel did not panic, hook must report ok");
                let (st, n) = &grads[name];
                // SAFETY: the hook contract — the gradient's final value
                // is written and nothing else touches it mid-pass.
                let v = unsafe { st.slice()[..*n].to_vec() };
                s2.lock().unwrap().push((name.to_string(), step, v));
            }));
            exec.forward_at(7);
            exec.backward_at(7).unwrap();
            exec.wait();
            let fired = seen.lock().unwrap().clone();
            assert_eq!(fired.len(), PARAMS.len(), "replay={replay}");
            for p in PARAMS {
                let hits: Vec<_> = fired.iter().filter(|(n, _, _)| n == p).collect();
                assert_eq!(hits.len(), 1, "{p} fired {} times", hits.len());
                let (_, step, v) = hits[0];
                assert_eq!(*step, 7);
                assert_eq!(*v, exec.grad(p).unwrap().to_vec(), "{p}: hook saw stale grad");
            }
            // cleared hook fires nothing
            exec.clear_grad_ready_hook();
            seen.lock().unwrap().clear();
            exec.forward();
            exec.backward().unwrap();
            exec.wait();
            assert!(seen.lock().unwrap().is_empty());
        }
    }

    #[test]
    fn explicit_step_then_legacy_forward_stays_monotonic() {
        let engine = create(EngineKind::Threaded, 2);
        let exec = Executor::bind(
            &mlp_symbol(),
            Arc::clone(&engine),
            mlp_args(4, engine, 3),
            &PARAMS,
            BindConfig::default(),
        )
        .unwrap();
        exec.forward_at(41);
        assert_eq!(exec.steps(), 41);
        exec.forward();
        assert_eq!(exec.steps(), 42);
        exec.wait();
    }

    #[test]
    fn fused_and_unfused_agree() {
        let mut per_mode: Vec<Vec<f32>> = Vec::new();
        for fuse in [false, true] {
            let engine = create(EngineKind::Threaded, 2);
            let exec = Executor::bind(
                &mlp_symbol(),
                Arc::clone(&engine),
                mlp_args(4, Arc::clone(&engine), 17),
                &PARAMS,
                BindConfig { fuse, ..Default::default() },
            )
            .unwrap();
            exec.forward();
            let p = exec.outputs()[0].to_vec();
            // deterministic given seed; compare to self across runs
            exec.forward();
            assert_eq!(p, exec.outputs()[0].to_vec(), "fuse={fuse}");
            per_mode.push(p);
        }
        // ... and fusion (elementwise + epilogue) must be lossless:
        // bitwise-identical outputs across the two binds.
        let same = per_mode[0]
            .iter()
            .zip(&per_mode[1])
            .all(|(u, f)| u.to_bits() == f.to_bits());
        assert!(same, "fused output differs bitwise from unfused");
    }

    #[test]
    fn epilogue_fusion_reduces_node_count_in_inference_bind() {
        // fc1+relu must fold into one epilogue-fused node on the
        // forward-only path; fc2 feeds softmax and stays plain.
        let engine = create(EngineKind::Threaded, 2);
        let exec = Executor::bind(
            &mlp_symbol(),
            Arc::clone(&engine),
            mlp_args(4, Arc::clone(&engine), 5),
            &[],
            BindConfig::inference(),
        )
        .unwrap();
        let fused = exec
            .graph()
            .nodes
            .iter()
            .filter(|nd| !nd.op.epilogue().is_empty())
            .count();
        assert_eq!(fused, 1, "expected exactly one epilogue-fused node");
        exec.forward();
        exec.wait();
    }
}

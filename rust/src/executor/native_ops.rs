//! Native CPU operator dispatch for the graph executor.
//!
//! Maps each graph [`Op`] onto the raw kernels in
//! [`crate::ndarray::kernels`].  Supports in-place execution: when the
//! memory planner assigns an output to one of the node's input buffers
//! (the *inplace* strategy), the executor passes `None` for that input and
//! the handler mutates the output buffer directly — the data is already
//! there.

use crate::graph::{FusedStep, Op};
use crate::ndarray::kernels as k;
use crate::util::Rng;

/// Everything an operator needs to run one node.
pub struct OpArgs<'a> {
    /// Input buffers; `None` when the input aliases output 0 (inplace).
    pub in_data: Vec<Option<&'a [f32]>>,
    /// Input shapes (always present, aliased or not).
    pub in_shapes: Vec<Vec<usize>>,
    /// Output buffers (exact entry sizes).
    pub out: Vec<&'a mut [f32]>,
    /// Output shapes.
    pub out_shapes: Vec<Vec<usize>>,
    /// Scratch workspace if the op requested one.
    pub workspace: Option<&'a mut [f32]>,
    /// Training mode (dropout active).
    pub training: bool,
    /// Step counter (dropout mask seeds).
    pub step: u64,
}

fn dims2(s: &[usize]) -> (usize, usize) {
    (s[0], s[1..].iter().product())
}

/// Bind a graph-level epilogue chain to kernel [`k::EpStep`]s, pulling
/// each `Binary` step's operand from `extras` in order (the fused node's
/// inputs after x, w, b).  FC/conv nodes have no inplace pairs, so every
/// operand is present.
fn ep_steps<'a>(steps: &[FusedStep], extras: &[Option<&'a [f32]>]) -> Vec<k::EpStep<'a>> {
    let mut extra = 0usize;
    steps
        .iter()
        .map(|st| match st {
            FusedStep::Act(kind) => k::EpStep::Act(*kind),
            FusedStep::AddScalar(s) => k::EpStep::AddScalar(*s),
            FusedStep::MulScalar(s) => k::EpStep::MulScalar(*s),
            FusedStep::Binary(op) => {
                let b = extras[extra].expect("epilogue operand");
                extra += 1;
                k::EpStep::Binary(*op, b)
            }
        })
        .collect()
}

fn nchw(s: &[usize]) -> (usize, usize, usize, usize) {
    (s[0], s[1], s[2], s[3])
}

/// Execute one graph node on the CPU.
///
/// Panics on malformed arguments — shape inference has validated the
/// graph before execution, so violations are bugs, not user errors.
pub fn execute(op: &Op, mut a: OpArgs<'_>) {
    match op {
        Op::Variable => unreachable!("variables are bound, not executed"),
        Op::FullyConnected { epilogue, .. } => {
            let (m, kk) = dims2(&a.in_shapes[0]);
            let n = a.in_shapes[1][0]; // weight [n, k]
            let x = a.in_data[0].expect("fc x");
            let w = a.in_data[1].expect("fc w");
            let b = a.in_data[2].expect("fc b");
            if epilogue.is_empty() {
                k::gemm_nt(x, w, a.out[0], m, kk, n, 0.0);
                k::bias_add(a.out[0], b, m, n);
            } else {
                let steps = ep_steps(epilogue, &a.in_data[3..]);
                let ep = k::Epilogue { bias: Some(b), bias_per_row: false, steps: &steps };
                k::gemm_nt_ep(x, w, a.out[0], m, kk, n, 0.0, &ep);
            }
        }
        Op::FullyConnectedBackward => {
            // (dy, x, w) -> (dx, dw, db)
            let (m, h) = dims2(&a.in_shapes[0]);
            let (_, kk) = dims2(&a.in_shapes[1]);
            let dy = a.in_data[0].expect("dy");
            let x = a.in_data[1].expect("x");
            let w = a.in_data[2].expect("w");
            let (dx, rest) = a.out.split_at_mut(1);
            let (dw, db) = rest.split_at_mut(1);
            k::gemm(dy, w, dx[0], m, h, kk, 0.0); // dx = dy @ w
            k::gemm_tn(dy, x, dw[0], h, m, kk, 0.0); // dw = dy^T @ x
            k::bias_grad(dy, db[0], m, h, 0.0);
        }
        Op::Convolution { num_filter, kernel, stride, pad, epilogue } => {
            let (n, c, h, w) = nchw(&a.in_shapes[0]);
            let x = a.in_data[0].expect("conv x");
            let wt = a.in_data[1].expect("conv w");
            let b = a.in_data[2].expect("conv b");
            // Image-parallel path with per-thread im2col scratch; the
            // planner workspace is only needed by the backward pass.
            if epilogue.is_empty() {
                k::conv2d_forward(
                    x, wt, b, a.out[0], n, c, h, w, *num_filter, *kernel, *stride, *pad,
                );
            } else {
                let steps = ep_steps(epilogue, &a.in_data[3..]);
                k::conv2d_forward_ep(
                    x, wt, b, a.out[0], n, c, h, w, *num_filter, *kernel, *stride, *pad,
                    &steps,
                );
            }
        }
        Op::ConvolutionBackward { kernel, stride, pad } => {
            // (dy, x, w) -> (dx, dw, db)
            let (n, f, _oh, _ow) = nchw(&a.in_shapes[0]);
            let (_, c, h, w) = nchw(&a.in_shapes[1]);
            let dy = a.in_data[0].expect("dy");
            let x = a.in_data[1].expect("x");
            let wt = a.in_data[2].expect("w");
            let cols = a.workspace.as_deref_mut().expect("convbwd workspace");
            let (dx, rest) = a.out.split_at_mut(1);
            let (dw, db) = rest.split_at_mut(1);
            k::conv2d_backward(
                dy, x, wt, dx[0], dw[0], db[0], cols, n, c, h, w, f, *kernel, *stride, *pad,
            );
        }
        Op::Activation { kind } => match a.in_data[0] {
            Some(x) => k::act_forward(*kind, x, a.out[0]),
            None => {
                // inplace: data already in out
                let out = &mut *a.out[0];
                match kind {
                    k::ActKind::Relu => {
                        for v in out.iter_mut() {
                            *v = v.max(0.0);
                        }
                    }
                    k::ActKind::Tanh => {
                        for v in out.iter_mut() {
                            *v = v.tanh();
                        }
                    }
                    k::ActKind::Sigmoid => {
                        for v in out.iter_mut() {
                            *v = 1.0 / (1.0 + (-*v).exp());
                        }
                    }
                }
            }
        },
        Op::ActivationBackward { kind } => {
            // (dy, y) -> dx ; dy may be inplace with dx
            let y = a.in_data[1].expect("act y");
            match a.in_data[0] {
                Some(dy) => k::act_backward(*kind, dy, y, a.out[0]),
                None => {
                    let dx = &mut *a.out[0];
                    match kind {
                        k::ActKind::Relu => {
                            for i in 0..dx.len() {
                                if y[i] <= 0.0 {
                                    dx[i] = 0.0;
                                }
                            }
                        }
                        k::ActKind::Tanh => {
                            for i in 0..dx.len() {
                                dx[i] *= 1.0 - y[i] * y[i];
                            }
                        }
                        k::ActKind::Sigmoid => {
                            for i in 0..dx.len() {
                                dx[i] *= y[i] * (1.0 - y[i]);
                            }
                        }
                    }
                }
            }
        }
        Op::Pooling { kind, kernel, stride, pad } => {
            let (n, c, h, w) = nchw(&a.in_shapes[0]);
            let x = a.in_data[0].expect("pool x");
            let (y, am) = a.out.split_at_mut(1);
            k::pool_forward(*kind, x, y[0], am[0], n, c, h, w, *kernel, *stride, *pad);
        }
        Op::PoolingBackward { kind, kernel, stride, pad } => {
            let (n, c, h, w) = nchw(&a.in_shapes[2]);
            let dy = a.in_data[0].expect("pool dy");
            let am = a.in_data[1].expect("pool argmax");
            k::pool_backward(*kind, dy, am, a.out[0], n, c, h, w, *kernel, *stride, *pad);
        }
        Op::BatchNorm { eps } => {
            let s = &a.in_shapes[0];
            let (n, c, spatial) = if s.len() >= 3 {
                (s[0], s[1], s[2..].iter().product())
            } else {
                (s[0], s[1], 1)
            };
            let x = a.in_data[0].expect("bn x");
            let gamma = a.in_data[1].expect("bn gamma");
            let beta = a.in_data[2].expect("bn beta");
            let (y, rest) = a.out.split_at_mut(1);
            let (sm, si) = rest.split_at_mut(1);
            k::batchnorm_forward(x, gamma, beta, y[0], sm[0], si[0], n, c, spatial, *eps);
        }
        Op::BatchNormBackward => {
            let s = &a.in_shapes[1];
            let (n, c, spatial) = if s.len() >= 3 {
                (s[0], s[1], s[2..].iter().product())
            } else {
                (s[0], s[1], 1)
            };
            let dy = a.in_data[0].expect("bn dy");
            let x = a.in_data[1].expect("bn x");
            let gamma = a.in_data[2].expect("bn gamma");
            let sm = a.in_data[3].expect("bn mean");
            let si = a.in_data[4].expect("bn invstd");
            let (dx, rest) = a.out.split_at_mut(1);
            let (dg, db) = rest.split_at_mut(1);
            k::batchnorm_backward(x, dy, gamma, sm, si, dx[0], dg[0], db[0], n, c, spatial);
        }
        Op::Flatten | Op::Identity => match a.in_data[0] {
            Some(x) => a.out[0].copy_from_slice(x),
            None => {} // inplace: nothing to do
        },
        Op::FlattenBackward => match a.in_data[0] {
            Some(dy) => a.out[0].copy_from_slice(dy),
            None => {}
        },
        Op::Elemwise { op } => {
            // Either input may alias the output (inplace plan); when both
            // do (x + x with inplace) the op degenerates to out (op) out.
            let apply_rhs = |out: &mut [f32], b: &[f32]| match op {
                k::EwBinary::Add => {
                    for i in 0..out.len() {
                        out[i] += b[i];
                    }
                }
                k::EwBinary::Sub => {
                    for i in 0..out.len() {
                        out[i] -= b[i];
                    }
                }
                k::EwBinary::Mul => {
                    for i in 0..out.len() {
                        out[i] *= b[i];
                    }
                }
                k::EwBinary::Div => {
                    for i in 0..out.len() {
                        out[i] /= b[i];
                    }
                }
            };
            match (a.in_data[0], a.in_data[1]) {
                (Some(x), Some(b)) => k::ew_binary(*op, x, b, a.out[0]),
                (None, Some(b)) => apply_rhs(a.out[0], b),
                (Some(x), None) => {
                    // out aliases b: out = x (op) out, done in place
                    let out = &mut *a.out[0];
                    match op {
                        k::EwBinary::Add => {
                            for i in 0..out.len() {
                                out[i] = x[i] + out[i];
                            }
                        }
                        k::EwBinary::Sub => {
                            for i in 0..out.len() {
                                out[i] = x[i] - out[i];
                            }
                        }
                        k::EwBinary::Mul => {
                            for i in 0..out.len() {
                                out[i] = x[i] * out[i];
                            }
                        }
                        k::EwBinary::Div => {
                            for i in 0..out.len() {
                                out[i] = x[i] / out[i];
                            }
                        }
                    }
                }
                (None, None) => {
                    // x == b == out
                    let out = &mut *a.out[0];
                    match op {
                        k::EwBinary::Add => {
                            for v in out.iter_mut() {
                                *v += *v;
                            }
                        }
                        k::EwBinary::Sub => out.fill(0.0),
                        k::EwBinary::Mul => {
                            for v in out.iter_mut() {
                                *v *= *v;
                            }
                        }
                        k::EwBinary::Div => out.fill(1.0),
                    }
                }
            }
        }
        Op::AddScalar { s } => match a.in_data[0] {
            Some(x) => {
                for i in 0..x.len() {
                    a.out[0][i] = x[i] + s;
                }
            }
            None => {
                for v in a.out[0].iter_mut() {
                    *v += s;
                }
            }
        },
        Op::MulScalar { s } => match a.in_data[0] {
            Some(x) => {
                for i in 0..x.len() {
                    a.out[0][i] = x[i] * s;
                }
            }
            None => {
                for v in a.out[0].iter_mut() {
                    *v *= s;
                }
            }
        },
        Op::AddN => {
            if let Some(x) = a.in_data[0] {
                a.out[0].copy_from_slice(x);
            }
            for i in 1..a.in_data.len() {
                match a.in_data[i] {
                    Some(x) => k::axpy(1.0, x, a.out[0]),
                    // operand aliases out: out += out
                    None => {
                        for v in a.out[0].iter_mut() {
                            *v += *v;
                        }
                    }
                }
            }
        }
        Op::Concat => {
            // NCHW channel concat
            let out_shape = a.out_shapes[0].clone();
            let n = out_shape[0];
            let spatial: usize = out_shape[2..].iter().product::<usize>().max(1);
            let out_c = out_shape[1];
            let mut ch_off = 0usize;
            for (idx, xin) in a.in_data.iter().enumerate() {
                let x = xin.expect("concat input");
                let ci = a.in_shapes[idx][1];
                for img in 0..n {
                    let src = &x[img * ci * spatial..(img + 1) * ci * spatial];
                    let dst = &mut a.out[0][(img * out_c + ch_off) * spatial
                        ..(img * out_c + ch_off + ci) * spatial];
                    dst.copy_from_slice(src);
                }
                ch_off += ci;
            }
        }
        Op::ConcatBackward => {
            // (dy, x_1..x_k) -> (dx_1..dx_k)
            let dy = a.in_data[0].expect("concat dy");
            let dy_shape = a.in_shapes[0].clone();
            let n = dy_shape[0];
            let total_c = dy_shape[1];
            let spatial: usize = dy_shape[2..].iter().product::<usize>().max(1);
            let mut ch_off = 0usize;
            for (oidx, out) in a.out.iter_mut().enumerate() {
                let ci = a.out_shapes[oidx][1];
                for img in 0..n {
                    let src = &dy[(img * total_c + ch_off) * spatial
                        ..(img * total_c + ch_off + ci) * spatial];
                    let dst = &mut out[img * ci * spatial..(img + 1) * ci * spatial];
                    dst.copy_from_slice(src);
                }
                ch_off += ci;
            }
        }
        Op::Dropout { p, seed } => {
            let (y, mask) = {
                let (y, m) = a.out.split_at_mut(1);
                (&mut *y[0], &mut *m[0])
            };
            if !a.training || *p <= 0.0 {
                if let Some(x) = a.in_data[0] {
                    y.copy_from_slice(x);
                }
                mask.fill(1.0);
            } else {
                let scale = 1.0 / (1.0 - p);
                let mut rng = Rng::seed_from_u64(seed ^ a.step.wrapping_mul(0x9E3779B9));
                match a.in_data[0] {
                    Some(x) => {
                        for i in 0..y.len() {
                            let keep = rng.next_f32() >= *p;
                            mask[i] = if keep { scale } else { 0.0 };
                            y[i] = x[i] * mask[i];
                        }
                    }
                    None => {
                        for i in 0..y.len() {
                            let keep = rng.next_f32() >= *p;
                            mask[i] = if keep { scale } else { 0.0 };
                            y[i] *= mask[i];
                        }
                    }
                }
            }
        }
        Op::DropoutBackward => {
            let mask = a.in_data[1].expect("dropout mask");
            match a.in_data[0] {
                Some(dy) => {
                    for i in 0..dy.len() {
                        a.out[0][i] = dy[i] * mask[i];
                    }
                }
                None => {
                    for (v, m) in a.out[0].iter_mut().zip(mask) {
                        *v *= m;
                    }
                }
            }
        }
        Op::SoftmaxOutput => {
            let (m, n) = dims2(&a.in_shapes[0]);
            let x = a.in_data[0].expect("softmax x");
            k::softmax_rows(x, a.out[0], m, n);
        }
        Op::SoftmaxOutputBackward => {
            let (m, n) = dims2(&a.in_shapes[0]);
            let probs = a.in_data[0].expect("probs");
            let labels = a.in_data[1].expect("labels");
            k::softmax_xent_backward(probs, labels, a.out[0], m, n);
        }
        Op::FusedElemwise { steps } => {
            // seed the accumulator
            if let Some(x) = a.in_data[0] {
                a.out[0].copy_from_slice(x);
            }
            let mut extra = 1usize;
            for st in steps {
                match st {
                    FusedStep::Act(kind) => {
                        let out = &mut *a.out[0];
                        match kind {
                            k::ActKind::Relu => {
                                for v in out.iter_mut() {
                                    *v = v.max(0.0);
                                }
                            }
                            k::ActKind::Tanh => {
                                for v in out.iter_mut() {
                                    *v = v.tanh();
                                }
                            }
                            k::ActKind::Sigmoid => {
                                for v in out.iter_mut() {
                                    *v = 1.0 / (1.0 + (-*v).exp());
                                }
                            }
                        }
                    }
                    FusedStep::AddScalar(s) => {
                        for v in a.out[0].iter_mut() {
                            *v += s;
                        }
                    }
                    FusedStep::MulScalar(s) => {
                        for v in a.out[0].iter_mut() {
                            *v *= s;
                        }
                    }
                    FusedStep::Binary(op) => {
                        let operand = a.in_data[extra];
                        extra += 1;
                        let out = &mut *a.out[0];
                        let b: &[f32] = match operand {
                            Some(b) => b,
                            None => {
                                // operand aliases out: apply out (op) out
                                match op {
                                    k::EwBinary::Add => {
                                        for v in out.iter_mut() {
                                            *v += *v;
                                        }
                                    }
                                    k::EwBinary::Sub => out.fill(0.0),
                                    k::EwBinary::Mul => {
                                        for v in out.iter_mut() {
                                            *v *= *v;
                                        }
                                    }
                                    k::EwBinary::Div => out.fill(1.0),
                                }
                                continue;
                            }
                        };
                        match op {
                            k::EwBinary::Add => {
                                for i in 0..out.len() {
                                    out[i] += b[i];
                                }
                            }
                            k::EwBinary::Sub => {
                                for i in 0..out.len() {
                                    out[i] -= b[i];
                                }
                            }
                            k::EwBinary::Mul => {
                                for i in 0..out.len() {
                                    out[i] *= b[i];
                                }
                            }
                            k::EwBinary::Div => {
                                for i in 0..out.len() {
                                    out[i] /= b[i];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_unary(op: &Op, x: Vec<f32>, shape: Vec<usize>) -> Vec<f32> {
        let mut out = vec![0.0; x.len()];
        execute(
            op,
            OpArgs {
                in_data: vec![Some(&x)],
                in_shapes: vec![shape.clone()],
                out: vec![&mut out],
                out_shapes: vec![shape],
                workspace: None,
                training: true,
                step: 0,
            },
        );
        out
    }

    #[test]
    fn fc_forward_known_values() {
        // x [1,2] @ w^T [3,2] + b
        let x = vec![1.0, 2.0];
        let w = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let b = vec![0.5, -0.5, 0.0];
        let mut y = vec![0.0; 3];
        execute(
            &Op::FullyConnected { num_hidden: 3, epilogue: vec![] },
            OpArgs {
                in_data: vec![Some(&x), Some(&w), Some(&b)],
                in_shapes: vec![vec![1, 2], vec![3, 2], vec![3]],
                out: vec![&mut y],
                out_shapes: vec![vec![1, 3]],
                workspace: None,
                training: true,
                step: 0,
            },
        );
        assert_eq!(y, vec![1.5, 1.5, 3.0]);
    }

    #[test]
    fn fc_with_epilogue_matches_unfused_dispatch() {
        // Same node as fc_forward_known_values plus relu and a residual
        // add in the epilogue: dispatch must agree exactly with running
        // the unfused op sequence.
        let x = vec![1.0, 2.0, -3.0, 1.0];
        let w = vec![1.0, 0.0, 0.0, 1.0, -1.0, -1.0];
        let b = vec![0.5, -0.5, 0.0];
        let res = vec![0.25, 0.5, 0.75, 1.0, 1.25, 1.5];
        let mut y = vec![0.0; 6];
        execute(
            &Op::FullyConnected {
                num_hidden: 3,
                epilogue: vec![
                    FusedStep::Act(k::ActKind::Relu),
                    FusedStep::Binary(k::EwBinary::Add),
                ],
            },
            OpArgs {
                in_data: vec![Some(&x), Some(&w), Some(&b), Some(&res)],
                in_shapes: vec![vec![2, 2], vec![3, 2], vec![3], vec![2, 3]],
                out: vec![&mut y],
                out_shapes: vec![vec![2, 3]],
                workspace: None,
                training: true,
                step: 0,
            },
        );
        // unfused: gemm_nt + bias, relu, + res
        let mut want = vec![0.0; 6];
        k::gemm_nt(&x, &w, &mut want, 2, 2, 3, 0.0);
        k::bias_add(&mut want, &b, 2, 3);
        for v in want.iter_mut() {
            *v = v.max(0.0);
        }
        for (v, r) in want.iter_mut().zip(&res) {
            *v += r;
        }
        assert_eq!(y, want);
    }

    #[test]
    fn relu_inplace_matches_copy() {
        let x = vec![-1.0, 2.0, -3.0, 4.0];
        let copy = run_unary(&Op::Activation { kind: k::ActKind::Relu }, x.clone(), vec![4]);
        // inplace path
        let mut out = x.clone();
        execute(
            &Op::Activation { kind: k::ActKind::Relu },
            OpArgs {
                in_data: vec![None],
                in_shapes: vec![vec![4]],
                out: vec![&mut out],
                out_shapes: vec![vec![4]],
                workspace: None,
                training: true,
                step: 0,
            },
        );
        assert_eq!(copy, out);
    }

    #[test]
    fn dropout_eval_is_identity() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![0.0; 3];
        let mut m = vec![0.0; 3];
        execute(
            &Op::Dropout { p: 0.5, seed: 1 },
            OpArgs {
                in_data: vec![Some(&x)],
                in_shapes: vec![vec![3]],
                out: vec![&mut y, &mut m],
                out_shapes: vec![vec![3], vec![3]],
                workspace: None,
                training: false,
                step: 0,
            },
        );
        assert_eq!(y, x);
        assert_eq!(m, vec![1.0; 3]);
    }

    #[test]
    fn dropout_train_masks_and_scales() {
        let x = vec![1.0; 1000];
        let mut y = vec![0.0; 1000];
        let mut m = vec![0.0; 1000];
        execute(
            &Op::Dropout { p: 0.5, seed: 7 },
            OpArgs {
                in_data: vec![Some(&x)],
                in_shapes: vec![vec![1000]],
                out: vec![&mut y, &mut m],
                out_shapes: vec![vec![1000], vec![1000]],
                workspace: None,
                training: true,
                step: 3,
            },
        );
        let kept = y.iter().filter(|&&v| v > 0.0).count();
        assert!((300..700).contains(&kept), "kept {kept}");
        for v in &y {
            assert!(*v == 0.0 || (*v - 2.0).abs() < 1e-6);
        }
        // E[y] ~ 1
        let mean: f32 = y.iter().sum::<f32>() / 1000.0;
        assert!((mean - 1.0).abs() < 0.15, "{mean}");
    }

    #[test]
    fn concat_and_backward_roundtrip() {
        // 1 image, channels 1+2, spatial 2x1
        let x1 = vec![1.0, 2.0];
        let x2 = vec![3.0, 4.0, 5.0, 6.0];
        let mut y = vec![0.0; 6];
        execute(
            &Op::Concat,
            OpArgs {
                in_data: vec![Some(&x1), Some(&x2)],
                in_shapes: vec![vec![1, 1, 2, 1], vec![1, 2, 2, 1]],
                out: vec![&mut y],
                out_shapes: vec![vec![1, 3, 2, 1]],
                workspace: None,
                training: true,
                step: 0,
            },
        );
        assert_eq!(y, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut d1 = vec![0.0; 2];
        let mut d2 = vec![0.0; 4];
        execute(
            &Op::ConcatBackward,
            OpArgs {
                in_data: vec![Some(&y), Some(&x1), Some(&x2)],
                in_shapes: vec![vec![1, 3, 2, 1], vec![1, 1, 2, 1], vec![1, 2, 2, 1]],
                out: vec![&mut d1, &mut d2],
                out_shapes: vec![vec![1, 1, 2, 1], vec![1, 2, 2, 1]],
                workspace: None,
                training: true,
                step: 0,
            },
        );
        assert_eq!(d1, x1);
        assert_eq!(d2, x2);
    }

    #[test]
    fn conv_forward_identity_kernel() {
        // 1x1 conv with identity weight reproduces input
        let x: Vec<f32> = (0..8).map(|v| v as f32).collect(); // [1,2,2,2]
        let w = vec![1.0, 0.0, 0.0, 1.0]; // [2,2,1,1]
        let b = vec![0.0, 0.0];
        let mut y = vec![0.0; 8];
        let mut ws = vec![0.0; 2 * 4];
        execute(
            &Op::Convolution { num_filter: 2, kernel: 1, stride: 1, pad: 0, epilogue: vec![] },
            OpArgs {
                in_data: vec![Some(&x), Some(&w), Some(&b)],
                in_shapes: vec![vec![1, 2, 2, 2], vec![2, 2, 1, 1], vec![2]],
                out: vec![&mut y],
                out_shapes: vec![vec![1, 2, 2, 2]],
                workspace: Some(&mut ws),
                training: true,
                step: 0,
            },
        );
        assert_eq!(y, x);
    }
}

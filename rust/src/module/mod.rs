//! The training module (paper §2.4): parameter init + `fit` / `score`
//! loops over a symbol, a data iterator and an optimizer, optionally
//! distributed through a [`KVStore`].
//!
//! The KVStore path is built on the [data-parallel round
//! loop](data_parallel): `Module::fit` is the single-replica
//! degeneration of [`DataParallelTrainer`], sharing the same pull /
//! forward-backward / per-layer-overlapped-push code path.

pub mod data_parallel;
pub mod sync;

pub use data_parallel::{Context, DataParallelTrainer, SyncMode, TrainerConfig};
pub use sync::{
    proportional_parts, Assignment, BoundedDelay, Bsp, Elastic, MemberEvent, SyncPolicy,
};

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::executor::{BindConfig, Executor};
use crate::io::DataIter;
use crate::kvstore::KVStore;
use crate::ndarray::NDArray;
use crate::optimizer::Optimizer;
use crate::symbol::Symbol;
use crate::util::Rng;

/// Per-epoch training statistics.
#[derive(Debug, Clone)]
pub struct EpochStats {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean cross-entropy over batches.
    pub loss: f32,
    /// Training accuracy over the epoch.
    pub accuracy: f32,
    /// Wall-clock seconds for the epoch.
    pub seconds: f64,
    /// Batches processed.
    pub batches: usize,
}

/// How parameters are updated each batch.
pub enum UpdateMode {
    /// Local optimizer applied directly to the executor's parameters.
    Local(Arc<dyn Optimizer>),
    /// Data-parallel: pull params from / push grads to a KVStore whose
    /// registered updater performs the merge (paper §2.3 loop).
    KvStore {
        /// The store (local or distributed).
        store: Arc<dyn KVStore>,
        /// This worker's device index.
        device: usize,
    },
}

/// A symbol + bound executor + parameters, ready to fit.
pub struct Module {
    symbol: Symbol,
    engine: EngineRef,
    exec: Option<Executor>,
    params: HashMap<String, NDArray>,
    data_arr: Option<NDArray>,
    label_arr: Option<NDArray>,
    label_name: String,
    param_names: Vec<String>,
    /// Synchronization rounds driven so far (the canonical step number
    /// handed to step-seeded ops on the KVStore path).
    rounds: u64,
}

impl Module {
    /// Wrap a symbol whose head is a `SoftmaxOutput`.
    pub fn new(symbol: Symbol, engine: EngineRef) -> Self {
        Module {
            symbol,
            engine,
            exec: None,
            params: HashMap::new(),
            data_arr: None,
            label_arr: None,
            label_name: String::new(),
            param_names: vec![],
            rounds: 0,
        }
    }

    /// Access a parameter array.
    pub fn param(&self, name: &str) -> Option<&NDArray> {
        self.params.get(name)
    }

    /// Parameter names (excludes data/label).
    pub fn param_names(&self) -> &[String] {
        &self.param_names
    }

    /// The bound executor (after [`Module::bind`]).
    pub fn executor(&self) -> Option<&Executor> {
        self.exec.as_ref()
    }

    /// Bind the symbol for `(batch, feature_shape)` input, initializing
    /// parameters with Xavier-uniform (seeded).
    ///
    /// `param_shapes` supplies the shape of every non-data variable (the
    /// model zoo computes these); data and label shapes come from the
    /// arguments.
    pub fn bind(
        &mut self,
        batch: usize,
        feat_shape: &[usize],
        param_shapes: &HashMap<String, Vec<usize>>,
        cfg: BindConfig,
        seed: u64,
    ) -> Result<()> {
        let args_list = self.symbol.list_arguments();
        let mut rng = Rng::seed_from_u64(seed);
        let mut args: HashMap<String, NDArray> = HashMap::new();
        let mut data_shape = vec![batch];
        data_shape.extend_from_slice(feat_shape);
        let data = NDArray::zeros_on(&data_shape, self.engine.clone());
        args.insert("data".into(), data.clone());
        self.data_arr = Some(data);
        self.param_names.clear();
        for name in &args_list {
            if name == "data" {
                continue;
            }
            if name.ends_with("_label") {
                self.label_name = name.clone();
                let label = NDArray::zeros_on(&[batch], self.engine.clone());
                args.insert(name.clone(), label.clone());
                self.label_arr = Some(label);
                continue;
            }
            let shape = param_shapes
                .get(name)
                .ok_or_else(|| Error::Bind(format!("no shape for parameter '{name}'")))?;
            let arr = init_param(name, shape, &mut rng, &self.engine);
            self.params.insert(name.clone(), arr.clone());
            self.param_names.push(name.clone());
            args.insert(name.clone(), arr);
        }
        let grad_names: Vec<&str> = self.param_names.iter().map(|s| s.as_str()).collect();
        let exec =
            Executor::bind(&self.symbol, self.engine.clone(), args, &grad_names, cfg)?;
        self.exec = Some(exec);
        Ok(())
    }

    /// Bind forward-only for inference ([`BindConfig::inference`]): no
    /// backward graph and no gradient buffers are allocated — the fast
    /// path [`Module::predict`] and [`Module::score`] need.
    pub fn bind_inference(
        &mut self,
        batch: usize,
        feat_shape: &[usize],
        param_shapes: &HashMap<String, Vec<usize>>,
        seed: u64,
    ) -> Result<()> {
        self.bind(batch, feat_shape, param_shapes, BindConfig::inference(), seed)
    }

    /// Forward one batch and return a copy of the head output (softmax
    /// probabilities), `[batch, classes]`.  `data` must match the bound
    /// data shape.  Works on both training and inference binds; the
    /// returned array is an engine-scheduled copy, so repeated predicts
    /// pipeline correctly.  Takes `&mut self` because it loads the
    /// shared bound data array — concurrent callers would read each
    /// other's batches (the serving layer uses per-worker executors
    /// instead).
    pub fn predict(&mut self, data: &NDArray) -> Result<NDArray> {
        let exec = self.exec.as_ref().ok_or_else(|| Error::Bind("module not bound".into()))?;
        let d = self.data_arr.as_ref().ok_or_else(|| Error::Bind("module not bound".into()))?;
        if data.shape() != d.shape() {
            return Err(Error::Bind(format!(
                "predict: data shape {:?} != bound {:?}",
                data.shape(),
                d.shape()
            )));
        }
        d.copy_from_(data);
        exec.forward();
        Ok(exec.outputs()[0].copy())
    }

    /// Load one batch into the bound data/label arrays.
    fn load_batch(&self, data: &NDArray, label: &NDArray) -> Result<()> {
        let d = self.data_arr.as_ref().ok_or_else(|| Error::Bind("module not bound".into()))?;
        let l = self.label_arr.as_ref().ok_or_else(|| Error::Bind("module not bound".into()))?;
        d.copy_from_(data);
        l.copy_from_(label);
        Ok(())
    }

    /// Train for `epochs` over `iter`.  Returns per-epoch stats.
    ///
    /// The KVStore mode runs the shared [data-parallel round
    /// loop](data_parallel::DataParallelTrainer) with this module as the
    /// single replica pushing part `device`: pulls are version-stamped,
    /// and each layer's gradient is pushed the moment it retires inside
    /// backward (grad-ready hook) — the N=1 degeneration of the
    /// multi-device trainer.
    pub fn fit(
        &mut self,
        iter: &mut dyn DataIter,
        mode: &UpdateMode,
        epochs: usize,
    ) -> Result<Vec<EpochStats>> {
        match mode {
            UpdateMode::Local(opt) => self.fit_local(iter, opt, epochs),
            UpdateMode::KvStore { store, device } => {
                self.fit_kvstore(iter, store, *device, epochs)
            }
        }
    }

    fn fit_local(
        &mut self,
        iter: &mut dyn DataIter,
        opt: &Arc<dyn Optimizer>,
        epochs: usize,
    ) -> Result<Vec<EpochStats>> {
        let exec = self.exec.as_ref().ok_or_else(|| Error::Bind("module not bound".into()))?;
        let mut stats = Vec::with_capacity(epochs);
        for epoch in 0..epochs {
            let t0 = Instant::now();
            iter.reset();
            let mut loss_sum = 0.0f64;
            let mut acc_sum = 0.0f64;
            let mut batches = 0usize;
            while let Some(b) = iter.next_batch() {
                self.load_batch(&b.data, &b.label)?;
                exec.forward_backward()?;
                for name in &self.param_names {
                    opt.update(name, &self.params[name], exec.grad(name).unwrap());
                }
                // One synchronized head read per batch (loss + accuracy
                // together) — this wait is the step boundary the replayed
                // run-plans and the imperative updates drain through.
                let (loss, acc) = exec.softmax_metrics()?;
                loss_sum += loss as f64;
                acc_sum += acc as f64;
                batches += 1;
            }
            self.engine.wait_all();
            if batches == 0 {
                return Err(Error::Bind("iterator produced no batches".into()));
            }
            stats.push(EpochStats {
                epoch,
                loss: (loss_sum / batches as f64) as f32,
                accuracy: (acc_sum / batches as f64) as f32,
                seconds: t0.elapsed().as_secs_f64(),
                batches,
            });
        }
        Ok(stats)
    }

    fn fit_kvstore(
        &mut self,
        iter: &mut dyn DataIter,
        store: &Arc<dyn KVStore>,
        device: usize,
        epochs: usize,
    ) -> Result<Vec<EpochStats>> {
        let exec = self.exec.as_ref().ok_or_else(|| Error::Bind("module not bound".into()))?;
        let data = self.data_arr.as_ref().ok_or_else(|| Error::Bind("module not bound".into()))?;
        let label =
            self.label_arr.as_ref().ok_or_else(|| Error::Bind("module not bound".into()))?;
        // Register params with the kvstore once (first init wins).
        for name in &self.param_names {
            let _ = store.init(name, &self.params[name]);
        }
        let view = data_parallel::ReplicaView {
            exec,
            params: &self.params,
            data,
            label,
            pull_device: device,
        };
        // The single-replica degeneration: a fixed assignment pushing
        // store part `device` (the worker's slot in a multi-process
        // round), with the BSP barrier every round.
        let mut policy = sync::Fixed { parts: vec![vec![device]] };
        let mut step = self.rounds;
        let out = data_parallel::fit_rounds(
            &self.engine,
            store,
            std::slice::from_ref(&view),
            &self.param_names,
            iter,
            &data_parallel::RoundOpts { overlap: true, epochs, shards: 1 },
            &mut policy,
            &mut step,
        );
        drop(view);
        self.rounds = step;
        out
    }

    /// Evaluate accuracy over an iterator (forward only).
    pub fn score(&self, iter: &mut dyn DataIter) -> Result<f32> {
        let exec = self.exec.as_ref().ok_or_else(|| Error::Bind("module not bound".into()))?;
        iter.reset();
        let mut acc = 0.0f64;
        let mut n = 0usize;
        while let Some(b) = iter.next_batch() {
            self.load_batch(&b.data, &b.label)?;
            exec.forward();
            acc += exec.softmax_accuracy()? as f64;
            n += 1;
        }
        if n == 0 {
            return Err(Error::Bind("iterator produced no batches".into()));
        }
        Ok((acc / n as f64) as f32)
    }
}

/// Xavier-uniform for weights, zeros for biases/betas, ones for gammas.
/// Shared by [`Module::bind`] and the data-parallel trainer's replica
/// binding, so replicas and single-module runs init identically.
pub(crate) fn init_param(
    name: &str,
    shape: &[usize],
    rng: &mut Rng,
    engine: &EngineRef,
) -> NDArray {
    if name.ends_with("_bias") || name.ends_with("_beta") {
        return NDArray::zeros_on(shape, engine.clone());
    }
    if name.ends_with("_gamma") {
        let a = NDArray::zeros_on(shape, engine.clone());
        a.copy_from_slice_sync(&vec![1.0; shape.iter().product()]);
        return a;
    }
    // fan_in/fan_out from shape: [out, in] or [f, c, k, k]
    let (fan_out, fan_in) = match shape.len() {
        4 => (shape[0] * shape[2] * shape[3], shape[1] * shape[2] * shape[3]),
        2 => (shape[0], shape[1]),
        _ => (shape[0], shape[0]),
    };
    let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
    let size: usize = shape.iter().product();
    let data: Vec<f32> = (0..size).map(|_| rng.uniform(-limit, limit)).collect();
    NDArray::from_vec_on(shape, data, engine.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::io::synth::class_clusters;
    use crate::io::ArrayDataIter;
    use crate::kvstore::{Consistency, LocalKVStore};
    use crate::optimizer::Sgd;
    use crate::symbol::Act;

    fn mlp() -> Symbol {
        Symbol::var("data")
            .fully_connected("fc1", 32)
            .activation("relu1", Act::Relu)
            .fully_connected("fc2", 4)
            .softmax_output("softmax")
    }

    fn mlp_shapes(in_dim: usize) -> HashMap<String, Vec<usize>> {
        let mut m = HashMap::new();
        m.insert("fc1_weight".into(), vec![32, in_dim]);
        m.insert("fc1_bias".into(), vec![32]);
        m.insert("fc2_weight".into(), vec![4, 32]);
        m.insert("fc2_bias".into(), vec![4]);
        m
    }

    #[test]
    fn fit_local_reaches_high_accuracy() {
        let engine = create(EngineKind::Threaded, 4);
        let ds = class_clusters(512, 4, 16, 0.3, 5);
        let mut iter = ArrayDataIter::new(
            ds.features,
            ds.labels,
            &[16],
            32,
            true,
            engine.clone(),
        );
        let mut m = Module::new(mlp(), engine);
        m.bind(32, &[16], &mlp_shapes(16), BindConfig::default(), 1).unwrap();
        let stats = m
            .fit(&mut iter, &UpdateMode::Local(Arc::new(Sgd::new(0.5))), 8)
            .unwrap();
        let last = stats.last().unwrap();
        assert!(last.accuracy > 0.9, "accuracy {:.3}", last.accuracy);
        assert!(last.loss < stats[0].loss, "loss should fall");
        // score path agrees roughly with training accuracy (same seed =
        // same class centroids = same task; fresh noise draws)
        let mut eval = ArrayDataIter::new(
            class_clusters(128, 4, 16, 0.3, 5).features,
            class_clusters(128, 4, 16, 0.3, 5).labels,
            &[16],
            32,
            false,
            m.engine_ref(),
        );
        let acc = m.score(&mut eval).unwrap();
        assert!(acc > 0.8, "eval accuracy {acc}");
    }

    #[test]
    fn fit_via_local_kvstore_matches_quality() {
        let engine = create(EngineKind::Threaded, 4);
        let ds = class_clusters(512, 4, 16, 0.3, 5);
        let mut iter = ArrayDataIter::new(
            ds.features,
            ds.labels,
            &[16],
            32,
            true,
            engine.clone(),
        );
        let store = Arc::new(LocalKVStore::new(
            engine.clone(),
            1,
            Arc::new(Sgd::new(0.5)),
            Consistency::Sequential,
        ));
        let mut m = Module::new(mlp(), engine);
        m.bind(32, &[16], &mlp_shapes(16), BindConfig::default(), 1).unwrap();
        let stats = m
            .fit(&mut iter, &UpdateMode::KvStore { store, device: 0 }, 8)
            .unwrap();
        assert!(stats.last().unwrap().accuracy > 0.9, "{:?}", stats.last());
    }

    #[test]
    fn inference_bind_has_no_grads_and_predicts() {
        let engine = create(EngineKind::Threaded, 2);
        let mut m = Module::new(mlp(), engine.clone());
        m.bind_inference(4, &[16], &mlp_shapes(16), 3).unwrap();
        // forward-only: the executor must not hold a single grad NDArray
        let exec = m.executor().unwrap();
        assert!(exec.grads().is_empty(), "inference bind allocated grads");
        for name in m.param_names() {
            assert!(exec.grad(name).is_none());
        }
        // predict produces valid probabilities and respects shape checks
        let x = NDArray::randn_on(&[4, 16], 0.0, 1.0, 7, engine.clone());
        let probs = m.predict(&x).unwrap();
        assert_eq!(probs.shape(), &[4, 4]);
        for row in probs.to_vec().chunks(4) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "{s}");
        }
        let bad = NDArray::zeros_on(&[2, 16], engine);
        assert!(m.predict(&bad).is_err());
        // score works on an inference bind too
        let ds = class_clusters(64, 4, 16, 0.3, 5);
        let mut iter = ArrayDataIter::new(ds.features, ds.labels, &[16], 4, false, m.engine_ref());
        m.score(&mut iter).unwrap();
    }

    #[test]
    fn unbound_module_errors() {
        let engine = create(EngineKind::Threaded, 2);
        let mut m = Module::new(mlp(), engine.clone());
        let ds = class_clusters(64, 4, 16, 0.3, 5);
        let mut iter =
            ArrayDataIter::new(ds.features, ds.labels, &[16], 32, false, engine);
        assert!(m
            .fit(&mut iter, &UpdateMode::Local(Arc::new(Sgd::new(0.1))), 1)
            .is_err());
    }

    impl Module {
        fn engine_ref(&self) -> EngineRef {
            self.engine.clone()
        }
    }
}

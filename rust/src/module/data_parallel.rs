//! Data-parallel multi-device training (paper §2.3 / §5): one executor
//! replica per virtual device, deterministic batch sharding, and KVStore
//! synchronization whose per-layer gradient pushes overlap the rest of
//! the backward pass.
//!
//! ## Model
//!
//! A [`Context`] is a *virtual* device: replicas do not own threads or
//! memory domains — they all schedule onto the one dependency engine,
//! whose worker pool and intra-op budget are divided among whatever
//! heavy ops the replicas keep in flight.  The trainer is therefore a
//! pure *scheduler*: its loop only issues engine ops (pull, load,
//! forward, backward, push) and the engine extracts the parallelism,
//! exactly the paper's argument that the dependency engine subsumes
//! multi-device orchestration.
//!
//! Everything policy-shaped about the round loop — shard placement,
//! barrier discipline, membership — is delegated to a
//! [`SyncPolicy`](super::sync::SyncPolicy): [`SyncMode::Bsp`] is the
//! full-barrier loop below, [`SyncMode::BoundedDelay`] lets replicas
//! run up to `k` rounds ahead against a
//! [`Consistency::BoundedDelay`](crate::kvstore::Consistency) store,
//! and [`SyncMode::Elastic`] adds weighted shard placement plus
//! join/leave membership events applied at round barriers (see
//! [`super::sync`] for the determinism story of each).
//!
//! ## Determinism contract
//!
//! The **shard count** — not the device count — defines the math.  Each
//! global batch is split by the canonical shard geometry
//! ([`shard_ranges`], the split [`crate::io::PartitionIter`]
//! materializes) into `shards` fixed
//! sub-batches; shard `s`'s gradient is delivered to KVStore part `s`
//! ([`KVStore::push_part`]), and the store reduces parts in index order.
//! Devices only decide *where* shards run, like the intra-op thread
//! budget only decides worker count: for a fixed shard count, training
//! is **bitwise identical for any device count that divides it** (and
//! for any `PALLAS_INTRA_THREADS`).  `tests/data_parallel.rs` asserts
//! this for the MLP and AlexNet.  Step-seeded ops (Dropout) draw from
//! the *round* number ([`Executor::forward_at`]), which is device-count
//! invariant by construction.
//!
//! ## Overlap
//!
//! With `overlap` on (default), every replica executor carries a
//! grad-ready hook ([`Executor::set_grad_ready_hook`]): the moment a
//! layer's gradient retires inside backward, the hook copies it into the
//! store's part staging — so the push for `fc8` is in flight while the
//! engine is still computing `conv1`'s gradients (paper §5's overlap of
//! communication with computation).  With `overlap` off, pushes are
//! engine ops reading the gradient vars, which (on the replay path)
//! queue behind the *whole* backward pass — same bitwise result, no
//! overlap; `benches/train.rs` measures the difference.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::executor::Executor;
use crate::io::{partition::shard_ranges, DataIter};
use crate::kvstore::KVStore;
use crate::ndarray::{NDArray, Storage};
use crate::symbol::Symbol;
use crate::util::Rng;

use super::sync::{
    Assignment, BoundedDelay, Bsp, Elastic, MemberEvent, MembershipState, RoundLedger,
    SyncPolicy,
};
use super::{init_param, EpochStats};

/// A lightweight virtual device: one replica slot of a data-parallel
/// trainer.  See the module docs — a `Context` names a slice of the
/// shared engine's worker/intra-op budget rather than a separate
/// hardware resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Context {
    /// Replica index (0-based).
    pub device: usize,
    /// Total replicas in the trainer.
    pub num_devices: usize,
}

impl Context {
    /// The `device`-th of `num_devices` virtual CPU devices.
    pub fn cpu(device: usize, num_devices: usize) -> Context {
        Context { device, num_devices }
    }
}

impl std::fmt::Display for Context {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cpu({}/{})", self.device, self.num_devices)
    }
}

/// One replica as the shared round loop sees it.  The trainer builds
/// these from its owned replicas; [`Module::fit`](super::Module::fit)
/// builds a single view of itself — the N=1 degeneration.  Which store
/// parts a replica pushes is no longer baked in here: the round loop
/// asks its [`SyncPolicy`] at every round barrier.
pub(crate) struct ReplicaView<'a> {
    pub exec: &'a Executor,
    pub params: &'a HashMap<String, NDArray>,
    pub data: &'a NDArray,
    pub label: &'a NDArray,
    /// Stable id for the store's per-device pull stamps.
    pub pull_device: usize,
}

/// Options for the shared round loop.
pub(crate) struct RoundOpts {
    pub overlap: bool,
    pub epochs: usize,
    /// Store parts per round, handed to [`SyncPolicy::assign`].
    pub shards: usize,
}

/// Per-replica hook state: the part list of the current assignment plus
/// per-gradient fire counters.  Swapped atomically at round barriers
/// when the policy hands out a new assignment (the ledger is drained
/// first, so no fire can race the swap).
struct HookParts {
    parts: Vec<usize>,
    fired: HashMap<String, usize>,
}

/// Clears the replicas' grad-ready hooks on scope exit (also on error
/// paths), so a later `fit` with different options starts clean.
struct HookGuard<'a> {
    replicas: &'a [ReplicaView<'a>],
    active: bool,
}

impl Drop for HookGuard<'_> {
    fn drop(&mut self) {
        if self.active {
            for r in self.replicas {
                r.exec.clear_grad_ready_hook();
            }
        }
    }
}

/// Schedule one engine op copying `rows` rows at `row_off` from a source
/// array into a replica-bound array (the shard load: one copy, no
/// intermediate allocation — the batch buffer is read in place under an
/// engine read grant).
fn load_rows(engine: &EngineRef, src: &NDArray, dst: &NDArray, row_off: usize, rows: usize) {
    let per: usize = src.shape()[1..].iter().product();
    debug_assert_eq!(dst.size(), rows * per);
    let (ss, ds) = (src.storage(), dst.storage());
    engine.push(
        "dp.load_shard",
        vec![src.var()],
        vec![dst.var()],
        Box::new(move || unsafe {
            ds.slice_mut()[..rows * per]
                .copy_from_slice(&ss.slice()[row_off * per..(row_off + rows) * per]);
        }),
    );
}

/// The synchronization round loop shared by [`DataParallelTrainer`] and
/// [`Module::fit`](super::Module::fit)'s KVStore mode: per round, ask
/// the [`SyncPolicy`] for the shard placement, split the global batch
/// into shards, run each shard on its replica (pull → load → forward →
/// backward → per-layer push), and wait at the policy's barrier — every
/// delivery for BSP, everything older than the lookahead window for
/// bounded delay.
pub(crate) fn fit_rounds(
    engine: &EngineRef,
    store: &Arc<dyn KVStore>,
    replicas: &[ReplicaView<'_>],
    param_names: &[String],
    iter: &mut dyn DataIter,
    opts: &RoundOpts,
    policy: &mut dyn SyncPolicy,
    step: &mut u64,
) -> Result<Vec<EpochStats>> {
    let grad_names: Vec<String> = param_names
        .iter()
        .filter(|n| replicas.iter().all(|r| r.exec.grad(n).is_some()))
        .cloned()
        .collect();
    if grad_names.is_empty() {
        return Err(Error::Bind("data-parallel fit: executors hold no gradients".into()));
    }

    let ledger = Arc::new(RoundLedger::new());
    let lookahead = policy.lookahead();
    let hook_parts: Vec<Arc<Mutex<HookParts>>> = replicas
        .iter()
        .map(|_| Arc::new(Mutex::new(HookParts { parts: Vec::new(), fired: HashMap::new() })))
        .collect();
    let mut guard = HookGuard { replicas, active: false };
    if opts.overlap {
        // Per-layer overlapped push: the hook fires on the engine worker
        // that just wrote a gradient's final value, copies it straight
        // into the store's part staging, and returns — the rest of
        // backward keeps running on the other workers.
        for (r, hp) in replicas.iter().zip(&hook_parts) {
            let mut gmap: HashMap<String, (Arc<Storage>, usize)> = HashMap::new();
            for name in &grad_names {
                let g = r
                    .exec
                    .grad(name)
                    .ok_or_else(|| Error::Bind(format!("no gradient for '{name}'")))?;
                gmap.insert(name.clone(), (g.storage(), g.size()));
            }
            let store = Arc::clone(store);
            let ledger = Arc::clone(&ledger);
            let hp = Arc::clone(hp);
            r.exec.set_grad_ready_hook(Arc::new(move |name: &str, round: u64, ok: bool| {
                if let Some((st, len)) = gmap.get(name) {
                    // Micro-steps of one replica run in program order
                    // (replays of one plan serialize), so the k-th fire
                    // of this gradient since the assignment was installed
                    // belongs to this replica's k-th shard.  Counters
                    // reset whenever the policy re-assigns (the ledger is
                    // drained first, so no fire can straddle the swap).
                    let part = {
                        let mut h = hp.lock().unwrap();
                        if h.parts.is_empty() {
                            // An idle replica never runs micro-steps, so
                            // this cannot fire; if it somehow does, fail
                            // the fit loudly at the barrier — completing
                            // the delivery silently could consume another
                            // replica's outstanding count and release the
                            // barrier with a push still in flight.
                            ledger.fail(
                                round,
                                Error::Bind(format!(
                                    "gradient '{name}' fired on a replica with no \
                                     assigned shards"
                                )),
                            );
                            return;
                        }
                        let f = h.fired.entry(name.to_string()).or_insert(0);
                        let k = *f % h.parts.len();
                        *f += 1;
                        h.parts[k]
                    };
                    if !ok {
                        // The writing kernel panicked: the buffer holds
                        // garbage.  Fail the fit at the round barrier
                        // rather than commit a corrupted round.
                        ledger.fail(
                            round,
                            Error::Bind(format!(
                                "backward kernel writing gradient '{name}' panicked"
                            )),
                        );
                        return;
                    }
                    // SAFETY: grad-ready hook contract (`ok` above) —
                    // this gradient's final value is written, nothing
                    // later in the pass writes it, and external readers
                    // are engine-ordered behind the pass.
                    let g = unsafe { &st.slice()[..*len] };
                    match store.push_part(name, g, part) {
                        Ok(()) => ledger.done(round),
                        Err(e) => ledger.fail(round, e),
                    }
                }
            }));
        }
        guard.active = true;
    }

    // Per-round state derived from the policy's current assignment; the
    // policy is consulted at every round barrier and this state is
    // re-derived only when the assignment actually changes.
    let mut cur: Option<Assignment> = None;
    let mut offsets: Vec<usize> = Vec::new();
    let mut k_max = 0usize;
    let mut local_shards = 0usize;
    let mut rows_needed = 0usize;
    let mut part_metrics: Vec<(f32, f32)> = Vec::new();

    let mut stats = Vec::with_capacity(opts.epochs);
    for epoch in 0..opts.epochs {
        let t0 = Instant::now();
        iter.reset();
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut batches = 0usize;
        while let Some(batch) = iter.next_batch() {
            *step += 1;
            let round = *step;
            // Round barrier, part 1: membership / placement.  A changed
            // assignment may only be installed with no delivery in
            // flight (the hook counters key off it), so drain first.
            let a = policy.assign(round, opts.shards, replicas.len())?;
            if a.parts.len() != replicas.len() {
                return Err(Error::Bind(format!(
                    "sync policy '{}' assigned {} part lists for {} replicas",
                    policy.name(),
                    a.parts.len(),
                    replicas.len()
                )));
            }
            if cur.as_ref() != Some(&a) {
                ledger.wait_all()?;
                for (hp, parts) in hook_parts.iter().zip(&a.parts) {
                    let mut h = hp.lock().unwrap();
                    h.parts = parts.clone();
                    h.fired.clear();
                }
                offsets = a.offsets();
                k_max = a.max_parts();
                local_shards = a.total_parts();
                if local_shards == 0 {
                    return Err(Error::Bind("data-parallel fit: no shards assigned".into()));
                }
                rows_needed = replicas
                    .iter()
                    .zip(&a.parts)
                    .map(|(r, p)| r.data.shape()[0] * p.len())
                    .sum();
                part_metrics = vec![(0.0f32, 0.0f32); local_shards];
                cur = Some(a);
            }
            let assign = cur.as_ref().expect("assignment installed above");

            // Per-replica shard batch (bound at replica bind time); the
            // global batch must be exactly the sum, and every shard range
            // must line up with its replica — validated up front each
            // round, *before* any push is staged, so a malformed batch
            // can never leave a round half-delivered in the store.
            let rows = batch.data.shape()[0];
            if rows != rows_needed || batch.label.size() != rows {
                return Err(Error::Bind(format!(
                    "data-parallel fit: batch of {rows} rows does not split into \
                     {local_shards} shards of the bound replica batch ({rows_needed} \
                     rows needed)"
                )));
            }
            // Feature-dimension check before any load is scheduled: a
            // mismatched copy inside an engine op would only panic on a
            // worker (and be reported-but-swallowed), not fail the fit.
            let per_src: usize = batch.data.shape()[1..].iter().product();
            let per_dst: usize = replicas[0].data.shape()[1..].iter().product();
            if per_src != per_dst {
                return Err(Error::Bind(format!(
                    "data-parallel fit: batch feature size {per_src} does not match \
                     the bound replica feature size {per_dst}"
                )));
            }
            // Canonical shard geometry (same as PartitionIter's), copied
            // straight from the batch buffer into the replica arrays —
            // one engine-scheduled copy per shard, no intermediates.
            let ranges = shard_ranges(rows, local_shards);
            for k in 0..k_max {
                for (d, r) in replicas.iter().enumerate() {
                    let parts = &assign.parts[d];
                    if k >= parts.len() {
                        continue;
                    }
                    let (row_off, n) = ranges[offsets[d] + k];
                    debug_assert_eq!(n, r.data.shape()[0]);
                    // Pull — within a round the version is unchanged, so
                    // repeats are answered from the device cache
                    // (version-stamped pull).  Under a bounded-delay
                    // store this is also the backpressure point: the
                    // pull blocks until the committed snapshot is within
                    // the staleness ceiling.
                    for name in param_names {
                        store.pull(name, &r.params[name], r.pull_device)?;
                    }
                    load_rows(engine, &batch.data, r.data, row_off, n);
                    load_rows(engine, &batch.label, r.label, row_off, n);
                    if opts.overlap {
                        ledger.add(round, grad_names.len());
                    }
                    r.exec.forward_at(round);
                    r.exec.backward_at(round)?;
                    if !opts.overlap {
                        // Non-overlapped push: one engine op per gradient
                        // reading its var — ordered after the whole
                        // backward pass on the replay path.  Same staged
                        // delivery, same bitwise result; only the timing
                        // differs.
                        for name in &grad_names {
                            let g = r.exec.grad(name).expect("checked above");
                            let (gs, glen) = (g.storage(), g.size());
                            let store2 = Arc::clone(store);
                            let ledger2 = Arc::clone(&ledger);
                            let key = name.clone();
                            let part = parts[k];
                            ledger.add(round, 1);
                            engine.push(
                                "kv.push_grad",
                                vec![g.var()],
                                vec![],
                                Box::new(move || {
                                    // SAFETY: this op holds the engine
                                    // read grant on the gradient var.
                                    let gsl = unsafe { &gs.slice()[..glen] };
                                    match store2.push_part(&key, gsl, part) {
                                        Ok(()) => ledger2.done(round),
                                        Err(e) => ledger2.fail(round, e),
                                    }
                                }),
                            );
                        }
                    }
                }
                // One synchronized head read per (replica, micro-step) —
                // before the replica's next micro-step overwrites its
                // outputs.  Stored by shard index so the epoch metric is
                // summed in shard order, independent of device count.
                for (d, r) in replicas.iter().enumerate() {
                    if k >= assign.parts[d].len() {
                        continue;
                    }
                    let (l, a) = r.exec.softmax_metrics()?;
                    part_metrics[offsets[d] + k] = (l, a);
                }
            }
            // Round barrier, part 2: the policy's delivery window.  BSP
            // (lookahead 0) waits for every delivery of this round —
            // transitively, the round's updater is scheduled before the
            // next pulls.  Bounded delay leaves up to `lookahead` rounds
            // in flight and only drains older ones.  A failed delivery
            // fails the fit here.
            ledger.wait_through(round.saturating_sub(lookahead))?;
            for &(l, a) in &part_metrics {
                loss_sum += l as f64;
                acc_sum += a as f64;
            }
            batches += 1;
        }
        // Epoch-end drain through the *store*: for a distributed store
        // this is the per-shard drain point — every shard's in-flight
        // wire ops (each serialized on its own engine connection var)
        // must land before the epoch metric is read or an inter-machine
        // barrier is issued.  For a local store it degenerates to the
        // old `engine.wait_all()`.
        store.flush();
        ledger.wait_all()?;
        if batches == 0 {
            return Err(Error::Bind("iterator produced no batches".into()));
        }
        let denom = (batches * local_shards) as f64;
        stats.push(EpochStats {
            epoch,
            loss: (loss_sum / denom) as f32,
            accuracy: (acc_sum / denom) as f32,
            seconds: t0.elapsed().as_secs_f64(),
            batches,
        });
    }
    Ok(stats)
}

/// Which [`SyncPolicy`] the trainer builds (see [`super::sync`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// Bulk-synchronous: full delivery barrier every round (PR 4's loop,
    /// bitwise-preserved).
    Bsp,
    /// Replicas run up to `k` rounds ahead; requires a store with
    /// [`Consistency::BoundedDelay`](crate::kvstore::Consistency)`(k)`.
    BoundedDelay(u64),
    /// Weighted shard placement + membership events at round barriers
    /// ([`DataParallelTrainer::join_at`] / `leave_at`).
    Elastic,
}

/// Trainer configuration (see [`DataParallelTrainer::bind`]).
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    /// Executor replicas (virtual devices).
    pub devices: usize,
    /// Parts per synchronization round — the data-parallel degree that
    /// *defines the math* (see the module docs).  Must be a multiple of
    /// `devices` for `Bsp`/`BoundedDelay` (any value for `Elastic`,
    /// which apportions by weight); `0` means `devices`.
    pub shards: usize,
    /// Per-layer gradient push from inside backward (default) vs push
    /// after the pass completes.  Bitwise-identical results either way.
    pub overlap: bool,
    /// Executor bind configuration (must build the backward pass).
    pub bind: crate::executor::BindConfig,
    /// Parameter-init seed (identical across replicas).
    pub seed: u64,
    /// Synchronization policy.
    pub sync: SyncMode,
    /// Per-replica work weights (`Elastic` only; empty = equal).  A
    /// replica with weight 3 runs three micro-steps per round for every
    /// one a weight-1 straggler runs; weight 0 idles the replica.
    pub weights: Vec<u32>,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            devices: 1,
            shards: 0,
            overlap: true,
            bind: crate::executor::BindConfig::default(),
            seed: 7,
            sync: SyncMode::Bsp,
            weights: Vec::new(),
        }
    }
}

struct Replica {
    ctx: Context,
    exec: Executor,
    params: HashMap<String, NDArray>,
    data: NDArray,
    label: NDArray,
}

/// Data-parallel trainer: N plan-replaying executor replicas bound to
/// virtual [`Context`]s, synchronized through a [`KVStore`] in rounds of
/// `shards` parts (see the module docs for the determinism and overlap
/// contracts).
pub struct DataParallelTrainer {
    engine: EngineRef,
    store: Arc<dyn KVStore>,
    replicas: Vec<Replica>,
    param_names: Vec<String>,
    shard_batch: usize,
    shards: usize,
    overlap: bool,
    policy: Box<dyn SyncPolicy>,
    step: u64,
    inited: bool,
}

impl DataParallelTrainer {
    /// Bind `cfg.devices` replicas of `symbol` at `shard_batch` rows
    /// each, all initialized identically from `cfg.seed`.  The incoming
    /// data iterator must produce global batches of `shards x
    /// shard_batch` rows; `store` must aggregate exactly `shards` parts
    /// per round ([`KVStore::num_devices`]).
    #[allow(clippy::too_many_arguments)]
    pub fn bind(
        symbol: &Symbol,
        engine: EngineRef,
        shard_batch: usize,
        feat_shape: &[usize],
        param_shapes: &HashMap<String, Vec<usize>>,
        store: Arc<dyn KVStore>,
        cfg: TrainerConfig,
    ) -> Result<DataParallelTrainer> {
        let devices = cfg.devices.max(1);
        let shards = if cfg.shards == 0 { devices } else { cfg.shards };
        if !matches!(cfg.sync, SyncMode::Elastic) && shards % devices != 0 {
            return Err(Error::Bind(format!(
                "data-parallel bind: {shards} shards not divisible by {devices} devices"
            )));
        }
        if !cfg.weights.is_empty() && !matches!(cfg.sync, SyncMode::Elastic) {
            return Err(Error::Bind(
                "data-parallel bind: per-replica weights need SyncMode::Elastic".into(),
            ));
        }
        let policy: Box<dyn SyncPolicy> = match cfg.sync {
            SyncMode::Bsp => Box::new(Bsp::new()),
            SyncMode::BoundedDelay(k) => Box::new(BoundedDelay { max_staleness: k }),
            SyncMode::Elastic => Box::new(Elastic::new(devices, cfg.weights.clone())?),
        };
        policy.check_store(store.consistency())?;
        if store.num_devices() != shards {
            return Err(Error::Bind(format!(
                "data-parallel bind: store aggregates {} parts per round, trainer \
                 produces {shards}",
                store.num_devices()
            )));
        }
        if !(cfg.bind.training && cfg.bind.grads) {
            return Err(Error::Bind(
                "data-parallel bind: BindConfig must build the backward pass".into(),
            ));
        }
        if shard_batch == 0 {
            return Err(Error::Bind("data-parallel bind: shard_batch must be >= 1".into()));
        }
        let args_list = symbol.list_arguments();
        let mut replicas = Vec::with_capacity(devices);
        let mut param_names: Vec<String> = Vec::new();
        for d in 0..devices {
            // Identical init on every replica: a fresh RNG from the same
            // seed replays the same parameter stream.
            let mut rng = Rng::seed_from_u64(cfg.seed);
            let mut args: HashMap<String, NDArray> = HashMap::new();
            let mut data_shape = vec![shard_batch];
            data_shape.extend_from_slice(feat_shape);
            let data = NDArray::zeros_on(&data_shape, engine.clone());
            args.insert("data".into(), data.clone());
            let mut label_arr: Option<NDArray> = None;
            let mut params: HashMap<String, NDArray> = HashMap::new();
            let mut names: Vec<String> = Vec::new();
            for name in &args_list {
                if name == "data" {
                    continue;
                }
                if name.ends_with("_label") {
                    let label = NDArray::zeros_on(&[shard_batch], engine.clone());
                    args.insert(name.clone(), label.clone());
                    label_arr = Some(label);
                    continue;
                }
                let shape = param_shapes
                    .get(name)
                    .ok_or_else(|| Error::Bind(format!("no shape for parameter '{name}'")))?;
                let arr = init_param(name, shape, &mut rng, &engine);
                params.insert(name.clone(), arr.clone());
                names.push(name.clone());
                args.insert(name.clone(), arr);
            }
            let label = label_arr
                .ok_or_else(|| Error::Bind("symbol has no *_label argument".into()))?;
            let grad_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            let exec = Executor::bind(symbol, engine.clone(), args, &grad_refs, cfg.bind)?;
            if d == 0 {
                param_names = names;
            }
            replicas.push(Replica {
                ctx: Context::cpu(d, devices),
                exec,
                params,
                data,
                label,
            });
        }
        Ok(DataParallelTrainer {
            engine,
            store,
            replicas,
            param_names,
            shard_batch,
            shards,
            overlap: cfg.overlap,
            policy,
            step: 0,
            inited: false,
        })
    }

    /// Replica count.
    pub fn devices(&self) -> usize {
        self.replicas.len()
    }

    /// Parts per synchronization round.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Rows per shard (each replica's bound batch size).
    pub fn shard_batch(&self) -> usize {
        self.shard_batch
    }

    /// The replica contexts.
    pub fn contexts(&self) -> Vec<Context> {
        self.replicas.iter().map(|r| r.ctx).collect()
    }

    /// A replica's executor (tests, diagnostics).
    pub fn replica_exec(&self, device: usize) -> Option<&Executor> {
        self.replicas.get(device).map(|r| &r.exec)
    }

    /// Parameter names in bind order.
    pub fn param_names(&self) -> &[String] {
        &self.param_names
    }

    /// Synchronization rounds driven so far — the round counter that
    /// [`DataParallelTrainer::join_at`] / `leave_at` rounds refer to.
    pub fn rounds(&self) -> u64 {
        self.step
    }

    /// Log a membership event: replica `device` joins the active set as
    /// of round `round` (1-based; applied at that round's barrier).  The
    /// rejoining replica pulls fresh master weights on its first
    /// micro-step, so no state transfer is needed.  `Elastic` sync only.
    pub fn join_at(&mut self, round: u64, device: usize) -> Result<()> {
        self.member_event(round, device, true)
    }

    /// Log a membership event: replica `device` leaves the active set as
    /// of round `round`; its shards are re-apportioned over the
    /// remaining replicas by weight.  `Elastic` sync only.
    pub fn leave_at(&mut self, round: u64, device: usize) -> Result<()> {
        self.member_event(round, device, false)
    }

    fn member_event(&mut self, round: u64, device: usize, join: bool) -> Result<()> {
        if device >= self.replicas.len() {
            return Err(Error::Bind(format!(
                "membership event for device {device} of {}",
                self.replicas.len()
            )));
        }
        self.policy.push_event(MemberEvent { round, device, join })
    }

    /// Train for `epochs` over `iter` (global batches of `shards x
    /// shard_batch` rows).  Registers the parameters with the store on
    /// first call (first init wins, so multi-process workers can share a
    /// distributed store).
    pub fn fit(&mut self, iter: &mut dyn DataIter, epochs: usize) -> Result<Vec<EpochStats>> {
        if !self.inited {
            for name in &self.param_names {
                // First init wins; ignore "already initialized".
                let _ = self.store.init(name, &self.replicas[0].params[name]);
            }
            self.inited = true;
        }
        let views: Vec<ReplicaView<'_>> = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaView {
                exec: &r.exec,
                params: &r.params,
                data: &r.data,
                label: &r.label,
                pull_device: i,
            })
            .collect();
        let mut step = self.step;
        let out = fit_rounds(
            &self.engine,
            &self.store,
            &views,
            &self.param_names,
            iter,
            &RoundOpts { overlap: self.overlap, epochs, shards: self.shards },
            self.policy.as_mut(),
            &mut step,
        );
        drop(views);
        self.step = step;
        out
    }

    /// Persist the full training state — master weights, per-key round
    /// versions, optimizer state, the round counter, and (for elastic
    /// runs) the membership-event log — so a later process can
    /// [`resume_from`](DataParallelTrainer::resume_from) this exact
    /// point and reproduce the uninterrupted run bit for bit.
    /// `epochs_done` records how many epochs completed; the caller
    /// fast-forwards its data iterator by the returned value on resume.
    /// Requires a store with train-state export (the local store; a
    /// distributed store recovers through the lease protocol instead).
    pub fn save_checkpoint(
        &self,
        path: impl AsRef<std::path::Path>,
        epochs_done: u64,
    ) -> Result<()> {
        let mut ts = self.store.export_train_state()?;
        ts.step = self.step;
        ts.epochs_done = epochs_done;
        if let Some(m) = self.policy.export_members() {
            ts.weights_cfg = m.weights;
            ts.active = m.active;
            ts.applied_events =
                m.applied.iter().map(|e| (e.round, e.device as u32, u8::from(e.join))).collect();
            ts.pending_events =
                m.pending.iter().map(|e| (e.round, e.device as u32, u8::from(e.join))).collect();
        }
        crate::io::checkpoint::save_train_state(path, &ts)
    }

    /// Restore a checkpoint written by
    /// [`save_checkpoint`](DataParallelTrainer::save_checkpoint) into
    /// this freshly-bound trainer: store weights/versions/updater state,
    /// the round counter, and elastic membership.  Returns the
    /// checkpoint's `epochs_done`; the caller must fast-forward its data
    /// iterator by that many epochs (one `reset()` per completed epoch
    /// for the shuffling array iterator) before calling `fit` so the
    /// resumed run consumes exactly the batches the uninterrupted run
    /// would have.
    pub fn resume_from(&mut self, path: impl AsRef<std::path::Path>) -> Result<u64> {
        let ts = crate::io::checkpoint::load_train_state(path)?;
        self.store.restore_train_state(&ts)?;
        if !ts.active.is_empty() {
            let to_ev = |t: &(u64, u32, u8)| MemberEvent {
                round: t.0,
                device: t.1 as usize,
                join: t.2 != 0,
            };
            let m = MembershipState {
                weights: ts.weights_cfg.clone(),
                active: ts.active.clone(),
                applied: ts.applied_events.iter().map(to_ev).collect(),
                pending: ts.pending_events.iter().map(to_ev).collect(),
            };
            self.policy.restore_members(&m)?;
        }
        self.step = ts.step;
        // The store now owns the restored master weights; replica params
        // are overwritten by the first round's pulls, so the fresh seed
        // init is harmless.  Skip re-registering keys with the store.
        self.inited = true;
        Ok(ts.epochs_done)
    }

    /// Pull the store's current master weights (one fresh array per
    /// parameter) — what the bitwise-equivalence tests compare.
    pub fn pull_params(&self) -> Result<HashMap<String, Vec<f32>>> {
        let probe = self.replicas.len(); // unused pull-stamp slot
        let mut out = HashMap::new();
        for name in &self.param_names {
            let shape = self.replicas[0].params[name].shape().to_vec();
            let a = NDArray::zeros_on(&shape, self.engine.clone());
            self.store.pull(name, &a, probe)?;
            out.insert(name.clone(), a.to_vec());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::io::{synth::class_clusters, ArrayDataIter};
    use crate::kvstore::{Consistency, LocalKVStore};
    use crate::models::mlp;
    use crate::optimizer::Sgd;

    #[test]
    fn context_display_and_fields() {
        let c = Context::cpu(1, 4);
        assert_eq!(c.device, 1);
        assert_eq!(c.num_devices, 4);
        assert_eq!(format!("{c}"), "cpu(1/4)");
    }

    #[test]
    fn bind_validates_config() {
        let engine = create(EngineKind::Threaded, 2);
        let model = mlp(&[16], 8, 4);
        let shapes = model.param_shapes(4).unwrap();
        let mk_store = |parts: usize| {
            Arc::new(LocalKVStore::new(
                engine.clone(),
                parts,
                Arc::new(Sgd::new(0.1)),
                Consistency::Sequential,
            )) as Arc<dyn KVStore>
        };
        // shards not divisible by devices
        let cfg = TrainerConfig { devices: 2, shards: 3, ..Default::default() };
        assert!(DataParallelTrainer::bind(
            &model.symbol, engine.clone(), 4, &[8], &shapes, mk_store(3), cfg
        )
        .is_err());
        // store part count mismatch
        let cfg = TrainerConfig { devices: 2, shards: 2, ..Default::default() };
        assert!(DataParallelTrainer::bind(
            &model.symbol, engine.clone(), 4, &[8], &shapes, mk_store(4), cfg
        )
        .is_err());
        // inference bind rejected
        let cfg = TrainerConfig {
            devices: 1,
            bind: crate::executor::BindConfig::inference(),
            ..Default::default()
        };
        assert!(DataParallelTrainer::bind(
            &model.symbol, engine.clone(), 4, &[8], &shapes, mk_store(1), cfg
        )
        .is_err());
    }

    #[test]
    fn wrong_global_batch_is_rejected() {
        let engine = create(EngineKind::Threaded, 2);
        let model = mlp(&[16], 8, 4);
        let shapes = model.param_shapes(4).unwrap();
        let store = Arc::new(LocalKVStore::new(
            engine.clone(),
            2,
            Arc::new(Sgd::new(0.1)),
            Consistency::Sequential,
        ));
        let cfg = TrainerConfig { devices: 2, ..Default::default() };
        let mut t = DataParallelTrainer::bind(
            &model.symbol,
            engine.clone(),
            4,
            &[8],
            &shapes,
            store,
            cfg,
        )
        .unwrap();
        // iterator batch 6 != shards(2) x shard_batch(4)
        let ds = class_clusters(64, 4, 8, 0.3, 3);
        let mut iter = ArrayDataIter::new(ds.features, ds.labels, &[8], 6, false, engine);
        assert!(t.fit(&mut iter, 1).is_err());
    }
}

//! Pluggable synchronization policies for the data-parallel round loop
//! (paper §2.3: the consistency spectrum, plus elastic membership for
//! heterogeneous clusters).
//!
//! The round loop in [`data_parallel`](super::data_parallel) is a pure
//! scheduler; everything policy-shaped about it is delegated here:
//!
//! * **which store parts each replica pushes** ([`SyncPolicy::assign`] —
//!   equal for [`Bsp`], weight-proportional and membership-aware for
//!   [`Elastic`]),
//! * **how far replicas may run ahead of delivery**
//!   ([`SyncPolicy::lookahead`] — 0 for BSP's full barrier, `k` for
//!   [`BoundedDelay`]),
//! * **which store consistency mode is legal**
//!   ([`SyncPolicy::check_store`]).
//!
//! ## Determinism
//!
//! The determinism contract of PR 4 survives every policy: the *shard
//! count* defines the math, and a policy only decides *where* shards run
//! and *when* the loop waits.  [`Bsp`] with equal weights reproduces the
//! pre-refactor trainer bit for bit; [`Elastic`] re-apportions whole
//! shards (never resizes them), so weighted and membership-churned runs
//! are **also** bitwise identical to the static run — rebalancing is a
//! pure function of the membership-event log ([`MemberEvent`]).
//! [`BoundedDelay`] intentionally trades determinism for pipelining:
//! replicas may observe snapshots up to `k` rounds stale
//! ([`Consistency::BoundedDelay`]), with `k = 0` degenerating to exactly
//! the sequential BSP schedule.

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};

use crate::error::{Error, Result};
use crate::kvstore::Consistency;

/// Which store parts each replica pushes in one round: `parts[d]` lists
/// the part ids replica `d` delivers, in micro-step order.  Assignments
/// are contiguous in device order (replica 0's parts precede replica
/// 1's), so the metric slot of replica `d`'s `k`-th micro-step is
/// `offsets()[d] + k` — stable whatever the policy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Assignment {
    /// Per-replica part ids.
    pub parts: Vec<Vec<usize>>,
}

impl Assignment {
    /// Total parts delivered per round (the local shard count).
    pub fn total_parts(&self) -> usize {
        self.parts.iter().map(|p| p.len()).sum()
    }

    /// Micro-steps of the busiest replica.
    pub fn max_parts(&self) -> usize {
        self.parts.iter().map(|p| p.len()).max().unwrap_or(0)
    }

    /// Metric-slot offset of each replica's first shard.
    pub fn offsets(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.parts.len());
        let mut off = 0usize;
        for p in &self.parts {
            out.push(off);
            off += p.len();
        }
        out
    }
}

/// One entry of the membership-event log: replica `device` joins or
/// leaves the active set as of round `round` (1-based; applied at the
/// round barrier before that round is issued).  Rebalancing is a pure
/// function of this log, so replaying the same log reproduces the same
/// shard placement — and, since shards define the math, the same bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemberEvent {
    /// First round the new membership applies to.
    pub round: u64,
    /// Replica (device index) affected.
    pub device: usize,
    /// `true` = join (activate), `false` = leave (deactivate).
    pub join: bool,
}

/// An elastic policy's membership as of some round, exported for
/// checkpointing: the static per-replica weights, the current active
/// set, the events already applied (the audit log a restored run can
/// replay), and the events still pending.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MembershipState {
    /// Per-replica work weights.
    pub weights: Vec<u32>,
    /// Per-replica active flags.
    pub active: Vec<bool>,
    /// Events already applied, in application order.
    pub applied: Vec<MemberEvent>,
    /// Events queued but not yet due.
    pub pending: Vec<MemberEvent>,
}

/// How the data-parallel round loop synchronizes its replicas (see the
/// module docs).  Implementations: [`Bsp`], [`BoundedDelay`],
/// [`Elastic`].
pub trait SyncPolicy: Send {
    /// Policy name for diagnostics.
    fn name(&self) -> &'static str;

    /// Rounds that may remain undelivered when the loop issues the next
    /// round: `0` is the full BSP barrier; `k` lets replicas run up to
    /// `k` rounds ahead of the slowest delivery (bounded staleness).
    fn lookahead(&self) -> u64 {
        0
    }

    /// Validate the store's consistency mode for this policy (checked at
    /// trainer bind).
    fn check_store(&self, _consistency: Consistency) -> Result<()> {
        Ok(())
    }

    /// The part assignment in effect for round `round` (1-based), with
    /// any membership events up to `round` applied.  Called at every
    /// round barrier; the loop re-derives its hook/metric state only
    /// when the returned assignment differs from the previous round's.
    /// Must be deterministic given the policy state and the event log.
    fn assign(&mut self, round: u64, shards: usize, devices: usize) -> Result<Assignment>;

    /// Queue a membership event.  Only elastic policies accept these.
    fn push_event(&mut self, ev: MemberEvent) -> Result<()> {
        let _ = ev;
        Err(Error::Bind(format!(
            "sync policy '{}' has static membership (use SyncMode::Elastic)",
            self.name()
        )))
    }

    /// Export membership for checkpointing; `None` for static policies
    /// (their assignment is a pure function of the round, so nothing
    /// needs saving).
    fn export_members(&self) -> Option<MembershipState> {
        None
    }

    /// Restore membership exported by
    /// [`export_members`](SyncPolicy::export_members).  Static policies
    /// reject this: a checkpoint carrying membership state cannot resume
    /// under a policy that ignores it.
    fn restore_members(&mut self, st: &MembershipState) -> Result<()> {
        let _ = st;
        Err(Error::Bind(format!(
            "sync policy '{}' has static membership; checkpoint carries elastic state",
            self.name()
        )))
    }
}

/// Apportion `shards` parts over replicas proportionally to `weights`,
/// as contiguous part-id ranges in device order.  Built on the same
/// largest-remainder primitive as
/// [`shard_ranges_weighted`](crate::io::shard_ranges_weighted)
/// ([`largest_remainder_counts`](crate::io::partition::largest_remainder_counts)),
/// but over whole shards rather than rows: replica batch sizes stay
/// fixed, so the executor binds survive rebalancing and the round math
/// never changes.  A zero-weight replica receives no parts (it idles).
pub fn proportional_parts(shards: usize, weights: &[u64]) -> Result<Vec<Vec<usize>>> {
    let counts = crate::io::partition::largest_remainder_counts(shards, weights)
        .map_err(|_| Error::Bind("part assignment: no active replica with weight > 0".into()))?;
    let mut out = Vec::with_capacity(weights.len());
    let mut next = 0usize;
    for n in counts {
        out.push((next..next + n).collect());
        next += n;
    }
    debug_assert_eq!(next, shards);
    Ok(out)
}

/// Bulk-synchronous parallel: the policy extracted from the PR 4 round
/// loop.  Equal contiguous part assignment, full delivery barrier every
/// round — bitwise identical to the pre-refactor trainer.
#[derive(Debug, Default)]
pub struct Bsp;

impl Bsp {
    /// A BSP policy.
    pub fn new() -> Bsp {
        Bsp
    }
}

impl SyncPolicy for Bsp {
    fn name(&self) -> &'static str {
        "bsp"
    }

    fn assign(&mut self, _round: u64, shards: usize, devices: usize) -> Result<Assignment> {
        let equal = vec![1u64; devices.max(1)];
        Ok(Assignment { parts: proportional_parts(shards, &equal)? })
    }
}

/// Bounded-delay synchronization (paper §2.3 footnote): replicas run up
/// to `max_staleness` rounds ahead of the slowest gradient delivery, and
/// pulls come from committed snapshots at most `max_staleness` rounds
/// stale ([`Consistency::BoundedDelay`]) — Eventual's pipelining with a
/// staleness ceiling.  `max_staleness = 0` is exactly sequential BSP.
#[derive(Debug)]
pub struct BoundedDelay {
    /// Rounds a replica may run ahead / a snapshot may lag.
    pub max_staleness: u64,
}

impl SyncPolicy for BoundedDelay {
    fn name(&self) -> &'static str {
        "bounded-delay"
    }

    fn lookahead(&self) -> u64 {
        self.max_staleness
    }

    fn check_store(&self, consistency: Consistency) -> Result<()> {
        match consistency {
            Consistency::BoundedDelay(k) if k == self.max_staleness => Ok(()),
            other => Err(Error::Bind(format!(
                "BoundedDelay({}) policy requires a store with \
                 Consistency::BoundedDelay({}), got {other:?}",
                self.max_staleness, self.max_staleness
            ))),
        }
    }

    fn assign(&mut self, _round: u64, shards: usize, devices: usize) -> Result<Assignment> {
        let equal = vec![1u64; devices.max(1)];
        Ok(Assignment { parts: proportional_parts(shards, &equal)? })
    }
}

/// Elastic membership with weighted work sizes: replicas carry
/// per-device weights (a straggler gets proportionally fewer shards per
/// round) and may join or leave at round barriers via the
/// membership-event log.  Shards are re-apportioned over the active set
/// with [`proportional_parts`]; a replica that rejoins pulls fresh
/// parameters on its first micro-step, so no state transfer is needed.
#[derive(Debug)]
pub struct Elastic {
    weights: Vec<u32>,
    active: Vec<bool>,
    /// Pending events, in submission order (applied in `(round, log
    /// order)`).
    events: Vec<MemberEvent>,
    /// Events already applied, in application order — the audit log a
    /// checkpoint persists so a restored run knows exactly which
    /// membership changes produced the saved active set.
    applied: Vec<MemberEvent>,
}

impl Elastic {
    /// An elastic policy over `devices` replicas.  `weights` sizes each
    /// replica's share of the round (empty = equal); all replicas start
    /// active.
    pub fn new(devices: usize, weights: Vec<u32>) -> Result<Elastic> {
        let devices = devices.max(1);
        let weights = if weights.is_empty() { vec![1; devices] } else { weights };
        if weights.len() != devices {
            return Err(Error::Bind(format!(
                "elastic sync: {} weights for {devices} devices",
                weights.len()
            )));
        }
        if weights.iter().all(|&w| w == 0) {
            return Err(Error::Bind("elastic sync: all weights are zero".into()));
        }
        Ok(Elastic {
            weights,
            active: vec![true; devices],
            events: Vec::new(),
            applied: Vec::new(),
        })
    }

    /// The currently-active replica set (diagnostics / tests).
    pub fn active(&self) -> &[bool] {
        &self.active
    }
}

impl SyncPolicy for Elastic {
    fn name(&self) -> &'static str {
        "elastic"
    }

    fn assign(&mut self, round: u64, shards: usize, devices: usize) -> Result<Assignment> {
        debug_assert_eq!(devices, self.active.len());
        // Apply the log entries due by this round, in log order.
        let mut rest = Vec::with_capacity(self.events.len());
        for ev in self.events.drain(..) {
            if ev.round <= round {
                self.active[ev.device] = ev.join;
                self.applied.push(ev);
            } else {
                rest.push(ev);
            }
        }
        self.events = rest;
        let eff: Vec<u64> = self
            .weights
            .iter()
            .zip(&self.active)
            .map(|(&w, &a)| if a { w as u64 } else { 0 })
            .collect();
        proportional_parts(shards, &eff)
            .map(|parts| Assignment { parts })
            .map_err(|_| {
                Error::Bind(format!(
                    "elastic sync: no active replica with weight > 0 at round {round}"
                ))
            })
    }

    fn push_event(&mut self, ev: MemberEvent) -> Result<()> {
        if ev.device >= self.active.len() {
            return Err(Error::Bind(format!(
                "membership event for device {} of {}",
                ev.device,
                self.active.len()
            )));
        }
        self.events.push(ev);
        Ok(())
    }

    fn export_members(&self) -> Option<MembershipState> {
        Some(MembershipState {
            weights: self.weights.clone(),
            active: self.active.clone(),
            applied: self.applied.clone(),
            pending: self.events.clone(),
        })
    }

    fn restore_members(&mut self, st: &MembershipState) -> Result<()> {
        if st.weights.len() != self.weights.len() || st.active.len() != self.active.len() {
            return Err(Error::Bind(format!(
                "elastic restore: checkpoint has {} replicas, trainer has {}",
                st.active.len(),
                self.active.len()
            )));
        }
        self.weights = st.weights.clone();
        self.active = st.active.clone();
        self.applied = st.applied.clone();
        self.events = st.pending.clone();
        Ok(())
    }
}

/// A fixed, caller-supplied assignment — [`Module::fit`](super::Module)'s
/// single-replica degeneration, where the one replica pushes an
/// arbitrary store part (its worker/device id).
pub(crate) struct Fixed {
    pub(crate) parts: Vec<Vec<usize>>,
}

impl SyncPolicy for Fixed {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn assign(&mut self, _round: u64, _shards: usize, _devices: usize) -> Result<Assignment> {
        Ok(Assignment { parts: self.parts.clone() })
    }
}

/// Tracks outstanding gradient deliveries **per round**, replacing PR 4's
/// single-round latch so policies with `lookahead > 0` can leave up to
/// `k` rounds in flight.  Also carries the first delivery error of the
/// fit: a failed push must fail `fit` at the next barrier, never silently
/// stop training (the PR 4 round-error contract).
pub(crate) struct RoundLedger {
    inner: Mutex<Ledger>,
    cv: Condvar,
}

struct Ledger {
    /// round -> deliveries still outstanding.
    outstanding: BTreeMap<u64, usize>,
    err: Option<Error>,
}

impl RoundLedger {
    pub(crate) fn new() -> RoundLedger {
        RoundLedger {
            inner: Mutex::new(Ledger { outstanding: BTreeMap::new(), err: None }),
            cv: Condvar::new(),
        }
    }

    /// Register `n` expected deliveries for `round`.
    pub(crate) fn add(&self, round: u64, n: usize) {
        if n == 0 {
            return;
        }
        *self.inner.lock().unwrap().outstanding.entry(round).or_insert(0) += n;
    }

    /// One delivery of `round` completed.
    pub(crate) fn done(&self, round: u64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(c) = g.outstanding.get_mut(&round) {
            *c -= 1;
            if *c == 0 {
                g.outstanding.remove(&round);
                self.cv.notify_all();
            }
        }
    }

    /// One delivery of `round` failed: record the first error (surfaced
    /// at the next barrier) and complete the delivery so waiters wake.
    pub(crate) fn fail(&self, round: u64, e: Error) {
        {
            let mut g = self.inner.lock().unwrap();
            if g.err.is_none() {
                g.err = Some(e);
            }
        }
        self.done(round);
    }

    fn take_err(g: &mut Ledger) -> Result<()> {
        match g.err.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Block until every delivery of rounds `<= round` has completed;
    /// surfaces the first recorded delivery error.
    pub(crate) fn wait_through(&self, round: u64) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        while g.outstanding.keys().next().is_some_and(|&r| r <= round) {
            g = self.cv.wait(g).unwrap();
        }
        Self::take_err(&mut g)
    }

    /// Block until no round has outstanding deliveries.
    pub(crate) fn wait_all(&self) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        while !g.outstanding.is_empty() {
            g = self.cv.wait(g).unwrap();
        }
        Self::take_err(&mut g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proportional_assignment_is_contiguous_and_deterministic() {
        // weights {3, 1} over 4 shards -> 3:1
        let p = proportional_parts(4, &[3, 1]).unwrap();
        assert_eq!(p, vec![vec![0, 1, 2], vec![3]]);
        // equal weights, divisible: PR 4's equal contiguous assignment
        let p = proportional_parts(4, &[1, 1]).unwrap();
        assert_eq!(p, vec![vec![0, 1], vec![2, 3]]);
        // zero-weight replica idles
        let p = proportional_parts(4, &[2, 0, 2]).unwrap();
        assert_eq!(p, vec![vec![0, 1], vec![], vec![2, 3]]);
        // ties to the lower index
        let p = proportional_parts(4, &[1, 1, 1]).unwrap();
        assert_eq!(p, vec![vec![0, 1], vec![2], vec![3]]);
        // all-zero rejected
        assert!(proportional_parts(4, &[0, 0]).is_err());
        // every part assigned exactly once, whatever the skew
        for (shards, w) in [(7usize, vec![5u64, 1, 3]), (16, vec![9, 2]), (3, vec![1, 8])] {
            let p = proportional_parts(shards, &w).unwrap();
            let flat: Vec<usize> = p.iter().flatten().copied().collect();
            assert_eq!(flat, (0..shards).collect::<Vec<_>>());
        }
    }

    #[test]
    fn elastic_applies_events_at_their_round() {
        let mut e = Elastic::new(2, vec![]).unwrap();
        e.push_event(MemberEvent { round: 3, device: 1, join: false }).unwrap();
        let a1 = e.assign(1, 4, 2).unwrap();
        assert_eq!(a1.parts, vec![vec![0, 1], vec![2, 3]]);
        let a2 = e.assign(2, 4, 2).unwrap();
        assert_eq!(a2, a1, "event not due yet");
        let a3 = e.assign(3, 4, 2).unwrap();
        assert_eq!(a3.parts, vec![vec![0, 1, 2, 3], vec![]], "device 1 left");
        assert_eq!(e.active(), &[true, false]);
        e.push_event(MemberEvent { round: 5, device: 1, join: true }).unwrap();
        let a5 = e.assign(5, 4, 2).unwrap();
        assert_eq!(a5, a1, "device 1 rejoined: assignment restored");
        // out-of-range device rejected
        assert!(e.push_event(MemberEvent { round: 9, device: 7, join: true }).is_err());
        // removing the last active replica fails the round
        e.push_event(MemberEvent { round: 6, device: 0, join: false }).unwrap();
        e.push_event(MemberEvent { round: 6, device: 1, join: false }).unwrap();
        assert!(e.assign(6, 4, 2).is_err());
    }

    #[test]
    fn elastic_membership_roundtrips_through_export() {
        // Apply one event, leave one pending, export, restore into a
        // fresh policy: subsequent assignments must match exactly.
        let mut e = Elastic::new(3, vec![2, 1, 1]).unwrap();
        e.push_event(MemberEvent { round: 2, device: 1, join: false }).unwrap();
        e.push_event(MemberEvent { round: 9, device: 1, join: true }).unwrap();
        let _ = e.assign(3, 4, 3).unwrap(); // applies the round-2 leave
        let st = e.export_members().unwrap();
        assert_eq!(st.active, vec![true, false, true]);
        assert_eq!(st.applied.len(), 1);
        assert_eq!(st.pending.len(), 1);

        let mut r = Elastic::new(3, vec![2, 1, 1]).unwrap();
        r.restore_members(&st).unwrap();
        for round in 4..12 {
            assert_eq!(
                r.assign(round, 4, 3).unwrap(),
                e.assign(round, 4, 3).unwrap(),
                "round {round}"
            );
        }
        // replica-count mismatch rejected
        let mut wrong = Elastic::new(2, vec![]).unwrap();
        assert!(wrong.restore_members(&st).is_err());
        // static policies reject membership restore outright
        assert!(Bsp::new().restore_members(&st).is_err());
        assert!(Bsp::new().export_members().is_none());
    }

    #[test]
    fn static_policies_reject_membership_events() {
        let mut b = Bsp::new();
        assert!(b.push_event(MemberEvent { round: 1, device: 0, join: false }).is_err());
        let mut bd = BoundedDelay { max_staleness: 2 };
        assert!(bd.push_event(MemberEvent { round: 1, device: 0, join: false }).is_err());
    }

    #[test]
    fn bounded_delay_store_validation() {
        let bd = BoundedDelay { max_staleness: 2 };
        assert!(bd.check_store(Consistency::BoundedDelay(2)).is_ok());
        assert!(bd.check_store(Consistency::BoundedDelay(1)).is_err());
        assert!(bd.check_store(Consistency::Sequential).is_err());
        assert!(bd.check_store(Consistency::Eventual).is_err());
        // BSP accepts any store mode (the PR 4 behavior)
        assert!(Bsp::new().check_store(Consistency::Eventual).is_ok());
    }

    #[test]
    fn ledger_waits_per_round_and_surfaces_errors() {
        let l = RoundLedger::new();
        l.add(1, 2);
        l.add(2, 1);
        l.done(1);
        l.done(1);
        l.wait_through(1).unwrap(); // round 2 still outstanding
        l.fail(2, Error::Bind("boom".into()));
        assert!(l.wait_all().is_err(), "delivery error must surface");
        l.wait_all().unwrap(); // error is taken exactly once
    }
}

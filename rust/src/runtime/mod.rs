//! The PJRT runtime (DESIGN S12): loads the HLO-text artifacts produced
//! by `python/compile/aot.py` and executes them from Rust, so Python
//! never runs on the training hot path.
//!
//! HLO **text** (not a serialized `HloModuleProto`) is the interchange
//! format: jax >= 0.5 emits protos with 64-bit instruction ids that the
//! linked xla_extension (0.5.1) rejects; the text parser reassigns ids
//! and round-trips cleanly (see `/opt/skills` aot recipe).
//!
//! The `xla` crate is not vendored in every build image, so the PJRT
//! path is gated behind the `xla-runtime` feature.  Without it this
//! module compiles an API-compatible stub whose [`Runtime::cpu`] returns
//! a [`Error::Runtime`] — callers (the CLI, `aot_e2e` tests, examples)
//! already handle that error or skip.
//!
//! ```no_run
//! use mixnet::runtime::Runtime;
//! let rt = Runtime::cpu().unwrap();
//! let programs = rt.load_dir(std::path::Path::new("artifacts")).unwrap();
//! let step = &programs["train_step"];
//! // positional f32 inputs per the manifest; outputs in manifest order
//! # let inputs: Vec<Vec<f32>> = vec![];
//! let outputs = step.run(&inputs.iter().map(|v| v.as_slice()).collect::<Vec<_>>()).unwrap();
//! ```

pub mod artifacts;

pub use artifacts::{load_manifest, Manifest, ModuleSpec, TensorKind, TensorSpec};

#[cfg(feature = "xla-runtime")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;

    use super::{load_manifest, ModuleSpec};
    use crate::error::{Error, Result};

    fn rt(e: xla::Error) -> Error {
        Error::Runtime(e.to_string())
    }

    /// A PJRT client plus compilation cache.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            Ok(Runtime { client: xla::PjRtClient::cpu().map_err(rt)? })
        }

        /// Backend platform name ("cpu" here; "tpu" on a real pod).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile one HLO-text file against `spec`.
        pub fn load_module(&self, dir: &Path, spec: &ModuleSpec) -> Result<Program> {
            let path = dir.join(&spec.hlo_file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
            )
            .map_err(rt)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(rt)?;
            Ok(Program { exe, spec: spec.clone() })
        }

        /// Load every module listed in `<dir>/manifest.txt`.
        pub fn load_dir(&self, dir: &Path) -> Result<HashMap<String, Program>> {
            let manifest = load_manifest(dir)?;
            manifest
                .modules
                .values()
                .map(|spec| Ok((spec.name.clone(), self.load_module(&manifest.dir, spec)?)))
                .collect()
        }
    }

    /// A compiled, executable module.
    pub struct Program {
        exe: xla::PjRtLoadedExecutable,
        spec: ModuleSpec,
    }

    impl Program {
        /// The module's signature.
        pub fn spec(&self) -> &ModuleSpec {
            &self.spec
        }

        /// Execute with positional f32 host buffers; returns one `Vec<f32>`
        /// per manifest output.  Input lengths are validated against the
        /// manifest shapes.
        pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            if inputs.len() != self.spec.inputs.len() {
                return Err(Error::Runtime(format!(
                    "module '{}' expects {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                )));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, ts) in inputs.iter().zip(&self.spec.inputs) {
                if data.len() != ts.size() {
                    return Err(Error::Runtime(format!(
                        "module '{}' input '{}': {} elements given, shape {:?} needs {}",
                        self.spec.name,
                        ts.name,
                        data.len(),
                        ts.shape,
                        ts.size()
                    )));
                }
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
                literals.push(if ts.shape.len() == 1 {
                    lit
                } else {
                    lit.reshape(&dims).map_err(rt)?
                });
            }
            let result = self.exe.execute::<xla::Literal>(&literals).map_err(rt)?;
            // aot.py lowers with return_tuple=True: one tuple literal holding
            // every output.
            let tuple = result[0][0].to_literal_sync().map_err(rt)?;
            let parts = tuple.to_tuple().map_err(rt)?;
            if parts.len() != self.spec.outputs.len() {
                return Err(Error::Runtime(format!(
                    "module '{}': manifest lists {} outputs, HLO returned {}",
                    self.spec.name,
                    self.spec.outputs.len(),
                    parts.len()
                )));
            }
            parts
                .into_iter()
                .zip(&self.spec.outputs)
                .map(|(lit, ts)| {
                    let v: Vec<f32> = lit.to_vec().map_err(rt)?;
                    if v.len() != ts.size() {
                        return Err(Error::Runtime(format!(
                            "module '{}' output '{}': got {} elements, expected {}",
                            self.spec.name,
                            ts.name,
                            v.len(),
                            ts.size()
                        )));
                    }
                    Ok(v)
                })
                .collect()
        }

        /// Execute by output name: convenience wrapper returning a map.
        pub fn run_named(&self, inputs: &[&[f32]]) -> Result<HashMap<String, Vec<f32>>> {
            let outs = self.run(inputs)?;
            Ok(self
                .spec
                .outputs
                .iter()
                .map(|t| t.name.clone())
                .zip(outs)
                .collect())
        }
    }
}

#[cfg(feature = "xla-runtime")]
pub use pjrt::{Program, Runtime};

/// API-compatible stub used when the `xla-runtime` feature is off: the
/// constructor fails with a descriptive error and nothing else is
/// reachable, so downstream code (CLI `runtime` subcommand, examples,
/// `aot_e2e` tests) compiles unchanged and degrades gracefully.
#[cfg(not(feature = "xla-runtime"))]
mod stub {
    use std::collections::HashMap;
    use std::path::Path;

    use super::ModuleSpec;
    use crate::error::{Error, Result};

    fn unavailable() -> Error {
        Error::Runtime(
            "mixnet was built without the `xla-runtime` feature; \
             add the `xla` crate to rust/Cargo.toml [dependencies] and \
             rebuild with `cargo build --features xla-runtime` to enable \
             the PJRT path"
                .into(),
        )
    }

    /// Stub PJRT client; construction always fails.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        /// Always returns [`Error::Runtime`] in stub builds.
        pub fn cpu() -> Result<Self> {
            Err(unavailable())
        }

        /// Backend platform name (unreachable in stub builds).
        pub fn platform(&self) -> String {
            unreachable!("stub Runtime cannot be constructed")
        }

        /// Compile one HLO-text file (unreachable in stub builds).
        pub fn load_module(&self, _dir: &Path, _spec: &ModuleSpec) -> Result<Program> {
            Err(unavailable())
        }

        /// Load every module in a manifest (unreachable in stub builds).
        pub fn load_dir(&self, _dir: &Path) -> Result<HashMap<String, Program>> {
            Err(unavailable())
        }
    }

    /// Stub compiled module; never constructed.
    pub struct Program {
        _spec: ModuleSpec,
    }

    impl Program {
        /// The module's signature.
        pub fn spec(&self) -> &ModuleSpec {
            &self._spec
        }

        /// Execute (unreachable in stub builds).
        pub fn run(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            Err(unavailable())
        }

        /// Execute by output name (unreachable in stub builds).
        pub fn run_named(&self, _inputs: &[&[f32]]) -> Result<HashMap<String, Vec<f32>>> {
            Err(unavailable())
        }
    }
}

#[cfg(not(feature = "xla-runtime"))]
pub use stub::{Program, Runtime};

#[cfg(test)]
mod tests {
    use super::*;
    use artifacts::parse_manifest;
    use std::path::Path;

    #[test]
    fn manifest_sample_roundtrip() {
        let m = parse_manifest(
            "module a\nhlo a.hlo.txt\ninput x data 2,2\noutput y 2,2\nend\n",
            Path::new("."),
        )
        .unwrap();
        assert_eq!(m.modules["a"].inputs[0].shape, vec![2, 2]);
    }

    #[cfg(not(feature = "xla-runtime"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = match Runtime::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub Runtime::cpu must fail"),
        };
        assert!(format!("{err}").contains("xla-runtime"));
    }

    /// HLO text for `f(x, y) = (x + y, x * y)` over f32[4]; written by
    /// hand so the runtime tests do not depend on `make artifacts`.
    #[cfg(feature = "xla-runtime")]
    const ADD_MUL_HLO: &str = r#"
HloModule addmul, entry_computation_layout={(f32[4]{0}, f32[4]{0})->(f32[4]{0}, f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  add = f32[4]{0} add(x, y)
  mul = f32[4]{0} multiply(x, y)
  ROOT out = (f32[4]{0}, f32[4]{0}) tuple(add, mul)
}
"#;

    #[cfg(feature = "xla-runtime")]
    fn write_artifacts() -> tempdir::TempDir {
        let dir = tempdir::TempDir::new();
        std::fs::write(dir.path().join("addmul.hlo.txt"), ADD_MUL_HLO).unwrap();
        std::fs::write(
            dir.path().join("manifest.txt"),
            "module addmul\nhlo addmul.hlo.txt\ninput x data 4\ninput y data 4\noutput sum 4\noutput prod 4\nend\n",
        )
        .unwrap();
        dir
    }

    /// Minimal tempdir (no external crate).
    #[cfg(feature = "xla-runtime")]
    mod tempdir {
        pub struct TempDir(std::path::PathBuf);
        impl TempDir {
            pub fn new() -> Self {
                let p = std::env::temp_dir().join(format!(
                    "mixnet-rt-test-{}-{:?}",
                    std::process::id(),
                    std::thread::current().id()
                ));
                std::fs::create_dir_all(&p).unwrap();
                TempDir(p)
            }
            pub fn path(&self) -> &std::path::Path {
                &self.0
            }
        }
        impl Drop for TempDir {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
    }

    #[cfg(feature = "xla-runtime")]
    #[test]
    fn load_and_execute_hlo_text() {
        let dir = write_artifacts();
        let rt = Runtime::cpu().unwrap();
        let programs = rt.load_dir(dir.path()).unwrap();
        let p = &programs["addmul"];
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [10.0f32, 20.0, 30.0, 40.0];
        let outs = p.run(&[&x, &y]).unwrap();
        assert_eq!(outs[0], vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(outs[1], vec![10.0, 40.0, 90.0, 160.0]);
        let named = p.run_named(&[&x, &y]).unwrap();
        assert_eq!(named["prod"][3], 160.0);
    }

    #[cfg(feature = "xla-runtime")]
    #[test]
    fn wrong_arity_rejected() {
        let dir = write_artifacts();
        let rt = Runtime::cpu().unwrap();
        let p = &rt.load_dir(dir.path()).unwrap()["addmul"];
        let x = [1.0f32; 4];
        assert!(p.run(&[&x]).is_err());
    }

    #[cfg(feature = "xla-runtime")]
    #[test]
    fn wrong_size_rejected() {
        let dir = write_artifacts();
        let rt = Runtime::cpu().unwrap();
        let p = &rt.load_dir(dir.path()).unwrap()["addmul"];
        let x = [1.0f32; 4];
        let y = [1.0f32; 3];
        assert!(p.run(&[&x, &y]).is_err());
    }

    #[cfg(feature = "xla-runtime")]
    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_dir(Path::new("/nonexistent-dir")) {
            Err(e) => e,
            Ok(_) => panic!("expected missing-manifest error"),
        };
        assert!(format!("{err}").contains("make artifacts"));
    }
}

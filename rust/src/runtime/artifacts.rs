//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it) and the Rust [runtime](super) (which loads it).
//!
//! One manifest (`artifacts/manifest.txt`) describes every AOT-lowered
//! module in the directory.  The format is a deliberately tiny line
//! protocol (the crate carries no serde):
//!
//! ```text
//! # mixnet artifact manifest v1
//! module <name>
//! hlo <relative-file.hlo.txt>
//! input <name> <param|data|label> <d0,d1,...>
//! output <name> <d0,d1,...>
//! end
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Role of a module input, so generic drivers know what to feed it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// Trainable parameter owned by the coordinator.
    Param,
    /// Input features of a batch.
    Data,
    /// Target labels of a batch.
    Label,
}

impl TensorKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "param" => Ok(TensorKind::Param),
            "data" => Ok(TensorKind::Data),
            "label" => Ok(TensorKind::Label),
            other => Err(Error::Runtime(format!("manifest: unknown tensor kind '{other}'"))),
        }
    }
}

/// A named f32 tensor slot of a module.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// Slot name (parameter name, "data", "loss", "grad:<param>", ...).
    pub name: String,
    /// Role (inputs only; outputs use [`TensorKind::Data`] by convention).
    pub kind: TensorKind,
    /// Dimensions.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Element count.
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered module: its HLO file plus input/output signatures in
/// positional order.
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    /// Module name ("train_step", "sgd_step", "predict", ...).
    pub name: String,
    /// HLO text file, relative to the manifest.
    pub hlo_file: PathBuf,
    /// Positional input slots.
    pub inputs: Vec<TensorSpec>,
    /// Positional output slots.
    pub outputs: Vec<TensorSpec>,
}

impl ModuleSpec {
    /// Indices of inputs with the given kind, in positional order.
    pub fn input_indices(&self, kind: TensorKind) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kind == kind)
            .map(|(i, _)| i)
            .collect()
    }

    /// Position of the output named `name`.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|t| t.name == name)
    }
}

/// All modules described by a manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Module specs by name.
    pub modules: HashMap<String, ModuleSpec>,
    /// Directory the manifest lives in (HLO paths resolve against it).
    pub dir: PathBuf,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|d| {
            d.parse::<usize>()
                .map_err(|_| Error::Runtime(format!("manifest: bad dim '{d}' in '{s}'")))
        })
        .collect()
}

/// Parse manifest text.  `dir` is where relative HLO paths resolve.
pub fn parse_manifest(text: &str, dir: &Path) -> Result<Manifest> {
    let mut manifest = Manifest { modules: HashMap::new(), dir: dir.to_path_buf() };
    let mut cur: Option<ModuleSpec> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let tag = parts.next().unwrap();
        let fail = |msg: &str| Error::Runtime(format!("manifest line {}: {msg}", lineno + 1));
        match tag {
            "module" => {
                if cur.is_some() {
                    return Err(fail("nested module (missing 'end')"));
                }
                let name = parts.next().ok_or_else(|| fail("module needs a name"))?;
                cur = Some(ModuleSpec {
                    name: name.to_string(),
                    hlo_file: PathBuf::new(),
                    inputs: vec![],
                    outputs: vec![],
                });
            }
            "hlo" => {
                let m = cur.as_mut().ok_or_else(|| fail("'hlo' outside module"))?;
                m.hlo_file = PathBuf::from(
                    parts.next().ok_or_else(|| fail("hlo needs a file"))?,
                );
            }
            "input" => {
                let m = cur.as_mut().ok_or_else(|| fail("'input' outside module"))?;
                let name = parts.next().ok_or_else(|| fail("input needs a name"))?;
                let kind = TensorKind::parse(
                    parts.next().ok_or_else(|| fail("input needs a kind"))?,
                )?;
                let shape =
                    parse_shape(parts.next().ok_or_else(|| fail("input needs a shape"))?)?;
                m.inputs.push(TensorSpec { name: name.to_string(), kind, shape });
            }
            "output" => {
                let m = cur.as_mut().ok_or_else(|| fail("'output' outside module"))?;
                let name = parts.next().ok_or_else(|| fail("output needs a name"))?;
                let shape =
                    parse_shape(parts.next().ok_or_else(|| fail("output needs a shape"))?)?;
                m.outputs.push(TensorSpec {
                    name: name.to_string(),
                    kind: TensorKind::Data,
                    shape,
                });
            }
            "end" => {
                let m = cur.take().ok_or_else(|| fail("'end' outside module"))?;
                if m.hlo_file.as_os_str().is_empty() {
                    return Err(fail("module missing 'hlo' line"));
                }
                manifest.modules.insert(m.name.clone(), m);
            }
            other => return Err(fail(&format!("unknown tag '{other}'"))),
        }
    }
    if cur.is_some() {
        return Err(Error::Runtime("manifest: unterminated module".into()));
    }
    Ok(manifest)
}

/// Load `<dir>/manifest.txt`.
pub fn load_manifest(dir: &Path) -> Result<Manifest> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        Error::Runtime(format!(
            "cannot read {} (run `make artifacts` first): {e}",
            path.display()
        ))
    })?;
    parse_manifest(&text, dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# mixnet artifact manifest v1
module train_step
hlo train_step.hlo.txt
input wte param 100,16
input data data 8,32
input labels label 8,32
output loss scalar
output grad:wte 100,16
end

module predict
hlo predict.hlo.txt
input wte param 100,16
input data data 8,32
output logits 8,32,100
end
";

    #[test]
    fn parses_sample() {
        let m = parse_manifest(SAMPLE, Path::new("/tmp")).unwrap();
        assert_eq!(m.modules.len(), 2);
        let ts = &m.modules["train_step"];
        assert_eq!(ts.inputs.len(), 3);
        assert_eq!(ts.inputs[0].kind, TensorKind::Param);
        assert_eq!(ts.inputs[1].kind, TensorKind::Data);
        assert_eq!(ts.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(ts.outputs[1].shape, vec![100, 16]);
        assert_eq!(ts.output_index("grad:wte"), Some(1));
        assert_eq!(ts.input_indices(TensorKind::Param), vec![0]);
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = "module m\nhlo f.txt\ninput x wat 1\nend\n";
        assert!(parse_manifest(bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_unterminated() {
        let bad = "module m\nhlo f.txt\n";
        assert!(parse_manifest(bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_missing_hlo() {
        let bad = "module m\nend\n";
        assert!(parse_manifest(bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn tensor_size() {
        let t = TensorSpec { name: "x".into(), kind: TensorKind::Data, shape: vec![3, 4] };
        assert_eq!(t.size(), 12);
    }
}

//! Wire protocol for the level-2 parameter server: length-framed binary
//! messages over TCP.  Hand-rolled (no serde in this image) and versioned
//! by a magic header so protocol mismatches fail loudly.
//!
//! Robustness contract: `decode`/`read_msg` never panic on adversarial
//! input — every malformed frame is an `Err` — and declared lengths are
//! bounded against the bytes actually present before any allocation is
//! sized from them.

use std::io::{Read, Write};

use crate::error::{Error, Result};

/// Protocol magic + version (v4: HelloAck carries the server's shard
/// identity so a misconfigured client fails loudly instead of silently
/// routing keys to the wrong shard; v3 added the HelloAck resume
/// floors; v2 added Push sequence numbers, Hello, Heartbeat and the
/// extended StatsReply).
pub const WIRE_MAGIC: u32 = 0x6d78_0004;

/// Hard ceiling on a frame body; `read_msg` rejects larger declared
/// lengths before allocating the receive buffer.
pub const MAX_FRAME: usize = 1 << 26; // 64 MiB

/// Parameter-server messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Register a key with its initial value (first writer wins).
    Init {
        /// Parameter key.
        key: String,
        /// Initial weight.
        value: Vec<f32>,
    },
    /// Push an (aggregated) gradient from one machine.
    Push {
        /// Parameter key.
        key: String,
        /// Gradient payload.
        value: Vec<f32>,
        /// Sender machine id.
        machine: u32,
        /// Per-machine monotonic sequence number; the server drops
        /// retransmissions whose seq it has already queued or applied.
        seq: u64,
    },
    /// Request the weight; served once `version >= after_version`.
    Pull {
        /// Parameter key.
        key: String,
        /// Minimum version to serve (0 = immediately / eventual).
        after_version: u64,
    },
    /// Weight reply.
    Value {
        /// Parameter key.
        key: String,
        /// Weight payload.
        value: Vec<f32>,
        /// Server-side update count for the key.
        version: u64,
    },
    /// Generic acknowledgement.
    Ack,
    /// Error reply.
    Err {
        /// Explanation.
        msg: String,
    },
    /// Epoch barrier: released when all active machines arrive.
    /// Retransmissions (same `id` + `machine`) are idempotent.
    Barrier {
        /// Barrier round id.
        id: u64,
        /// Sender machine id.
        machine: u32,
    },
    /// Graceful shutdown request.
    Shutdown,
    /// Request the server's traffic counters (harness observability).
    Stats,
    /// Reply to [`Msg::Stats`].
    StatsReply {
        /// Data-plane messages received since start.
        msgs: u64,
        /// Payload bytes received since start.
        bytes: u64,
        /// Retransmissions recognized and dropped (pushes + barriers).
        dedup_hits: u64,
        /// Machine leases that expired.
        lease_expiries: u64,
        /// Optimizer rounds applied across all keys.
        applies: u64,
    },
    /// Register a machine on (re)connect; refreshes its lease and, under
    /// the degrade policy, rejoins an expired machine.
    Hello {
        /// Sender machine id.
        machine: u32,
    },
    /// Lease keep-alive.
    Heartbeat {
        /// Sender machine id.
        machine: u32,
    },
    /// Reply to [`Msg::Hello`]: the floors a (re)connecting client must
    /// resume its counters above.  A restarted worker process starts its
    /// local counters at 0; without these floors its pushes would all
    /// land at or below the server's dedup floor (silently dropped as
    /// retransmissions) and its barriers would hit already-released
    /// generations (acked without synchronizing).
    HelloAck {
        /// Highest push sequence number the server has seen from this
        /// machine; the client's next push must use a larger seq.
        seq: u64,
        /// Highest barrier id the server has released; the client's next
        /// barrier must use a larger id.
        barrier: u64,
        /// This server's shard index (`0` when unsharded).
        shard: u32,
        /// Total shards in the fleet this server was launched for
        /// (`1` when unsharded).  A client dialing shard `i` of `N`
        /// verifies `(shard, shards) == (i, N)` whenever `shards > 1`,
        /// so a harness that wires an address to the wrong slot fails
        /// at connect instead of scattering keys.
        shards: u32,
    },
}

impl Msg {
    fn code(&self) -> u8 {
        match self {
            Msg::Init { .. } => 0,
            Msg::Push { .. } => 1,
            Msg::Pull { .. } => 2,
            Msg::Value { .. } => 3,
            Msg::Ack => 4,
            Msg::Err { .. } => 5,
            Msg::Barrier { .. } => 6,
            Msg::Shutdown => 7,
            Msg::Stats => 8,
            Msg::StatsReply { .. } => 9,
            Msg::Hello { .. } => 10,
            Msg::Heartbeat { .. } => 11,
            Msg::HelloAck { .. } => 12,
        }
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    buf.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(Error::kv("wire: truncated message"));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        // Bound the declared length against the bytes actually present
        // before `to_vec` sizes an allocation from it.
        if n > self.remaining() {
            return Err(Error::kv("wire: string length exceeds frame"));
        }
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| Error::kv("wire: bad utf8"))
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        // 4*n could overflow on 32-bit targets and would otherwise size a
        // Vec from attacker-declared input; check against remaining first.
        if n > self.remaining() / 4 {
            return Err(Error::kv("wire: f32 array length exceeds frame"));
        }
        let raw = self.take(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Encode a message to its framed byte representation.
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut body = Vec::new();
    body.push(msg.code());
    match msg {
        Msg::Init { key, value } => {
            put_str(&mut body, key);
            put_f32s(&mut body, value);
        }
        Msg::Push { key, value, machine, seq } => {
            put_str(&mut body, key);
            put_f32s(&mut body, value);
            body.extend_from_slice(&machine.to_le_bytes());
            body.extend_from_slice(&seq.to_le_bytes());
        }
        Msg::Pull { key, after_version } => {
            put_str(&mut body, key);
            body.extend_from_slice(&after_version.to_le_bytes());
        }
        Msg::Value { key, value, version } => {
            put_str(&mut body, key);
            put_f32s(&mut body, value);
            body.extend_from_slice(&version.to_le_bytes());
        }
        Msg::Ack | Msg::Shutdown | Msg::Stats => {}
        Msg::Err { msg } => put_str(&mut body, msg),
        Msg::Barrier { id, machine } => {
            body.extend_from_slice(&id.to_le_bytes());
            body.extend_from_slice(&machine.to_le_bytes());
        }
        Msg::StatsReply { msgs, bytes, dedup_hits, lease_expiries, applies } => {
            body.extend_from_slice(&msgs.to_le_bytes());
            body.extend_from_slice(&bytes.to_le_bytes());
            body.extend_from_slice(&dedup_hits.to_le_bytes());
            body.extend_from_slice(&lease_expiries.to_le_bytes());
            body.extend_from_slice(&applies.to_le_bytes());
        }
        Msg::Hello { machine } | Msg::Heartbeat { machine } => {
            body.extend_from_slice(&machine.to_le_bytes());
        }
        Msg::HelloAck { seq, barrier, shard, shards } => {
            body.extend_from_slice(&seq.to_le_bytes());
            body.extend_from_slice(&barrier.to_le_bytes());
            body.extend_from_slice(&shard.to_le_bytes());
            body.extend_from_slice(&shards.to_le_bytes());
        }
    }
    let mut out = Vec::with_capacity(12 + body.len());
    out.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Decode one message from a body buffer (without the 8-byte frame
/// header).  Never panics: every malformed input is an `Err`.
pub fn decode(body: &[u8]) -> Result<Msg> {
    let mut c = Cursor { b: body, pos: 0 };
    let code = c.take(1)?[0];
    Ok(match code {
        0 => Msg::Init { key: c.string()?, value: c.f32s()? },
        1 => Msg::Push { key: c.string()?, value: c.f32s()?, machine: c.u32()?, seq: c.u64()? },
        2 => Msg::Pull { key: c.string()?, after_version: c.u64()? },
        3 => Msg::Value { key: c.string()?, value: c.f32s()?, version: c.u64()? },
        4 => Msg::Ack,
        5 => Msg::Err { msg: c.string()? },
        6 => Msg::Barrier { id: c.u64()?, machine: c.u32()? },
        7 => Msg::Shutdown,
        8 => Msg::Stats,
        9 => Msg::StatsReply {
            msgs: c.u64()?,
            bytes: c.u64()?,
            dedup_hits: c.u64()?,
            lease_expiries: c.u64()?,
            applies: c.u64()?,
        },
        10 => Msg::Hello { machine: c.u32()? },
        11 => Msg::Heartbeat { machine: c.u32()? },
        12 => Msg::HelloAck {
            seq: c.u64()?,
            barrier: c.u64()?,
            shard: c.u32()?,
            shards: c.u32()?,
        },
        other => return Err(Error::kv(format!("wire: unknown opcode {other}"))),
    })
}

/// Write one framed message to a stream.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    let bytes = encode(msg);
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one framed message from a stream.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg> {
    let mut hdr = [0u8; 8];
    r.read_exact(&mut hdr)?;
    let magic = u32::from_le_bytes(hdr[..4].try_into().unwrap());
    if magic != WIRE_MAGIC {
        return Err(Error::kv(format!("wire: bad magic {magic:#x}")));
    }
    let len = u32::from_le_bytes(hdr[4..].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(Error::kv(format!("wire: oversized frame {len}")));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode(&body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn roundtrip(m: Msg) {
        let enc = encode(&m);
        let dec = decode(&enc[8..]).unwrap();
        assert_eq!(m, dec);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Init { key: "w1".into(), value: vec![1.0, -2.5] });
        roundtrip(Msg::Push { key: "w".into(), value: vec![0.0; 17], machine: 3, seq: 99 });
        roundtrip(Msg::Pull { key: "k".into(), after_version: 42 });
        roundtrip(Msg::Value { key: "k".into(), value: vec![9.0], version: 7 });
        roundtrip(Msg::Ack);
        roundtrip(Msg::Err { msg: "boom".into() });
        roundtrip(Msg::Barrier { id: 5, machine: 1 });
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Stats);
        roundtrip(Msg::StatsReply {
            msgs: 123,
            bytes: 456789,
            dedup_hits: 3,
            lease_expiries: 1,
            applies: 40,
        });
        roundtrip(Msg::Hello { machine: 2 });
        roundtrip(Msg::Heartbeat { machine: 0 });
        roundtrip(Msg::HelloAck { seq: 57, barrier: 12, shard: 2, shards: 4 });
    }

    #[test]
    fn empty_payload_ok() {
        roundtrip(Msg::Init { key: "".into(), value: vec![] });
    }

    #[test]
    fn stream_roundtrip() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Pull { key: "a".into(), after_version: 1 }).unwrap();
        write_msg(&mut buf, &Msg::Ack).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_msg(&mut r).unwrap(), Msg::Pull { key: "a".into(), after_version: 1 });
        assert_eq!(read_msg(&mut r).unwrap(), Msg::Ack);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = encode(&Msg::Ack);
        buf[0] ^= 0xff;
        let mut r = &buf[..];
        assert!(read_msg(&mut r).is_err());
    }

    #[test]
    fn truncated_body_rejected() {
        let enc = encode(&Msg::Init { key: "w".into(), value: vec![1.0] });
        assert!(decode(&enc[8..enc.len() - 2]).is_err());
    }

    /// A frame declaring more payload than the body holds must error
    /// before any allocation is sized from the declared count.
    #[test]
    fn declared_length_bounded_by_frame() {
        // Push with f32 count u32::MAX but only 4 bytes of payload.
        let mut body = vec![1u8]; // opcode Push
        put_str(&mut body, "k");
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        body.extend_from_slice(&[0u8; 4]);
        assert!(decode(&body).is_err());

        // Err with a huge declared string length.
        let mut body = vec![5u8];
        body.extend_from_slice(&0xffff_ff00u32.to_le_bytes());
        body.extend_from_slice(b"hi");
        assert!(decode(&body).is_err());
    }

    #[test]
    fn oversized_frame_rejected_before_allocation() {
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
        hdr.extend_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
        let mut r = &hdr[..];
        assert!(read_msg(&mut r).is_err());
    }

    /// Arbitrary byte bodies must decode to `Err` or `Ok`, never panic.
    #[test]
    fn decode_never_panics_on_arbitrary_bytes() {
        check(
            "wire-decode-total",
            2000,
            |r| {
                let n = r.below(96);
                (0..n).map(|_| r.next_u64() as u8).collect::<Vec<u8>>()
            },
            |bytes| {
                let _ = decode(bytes);
                true
            },
        );
    }

    /// Random corruptions of valid frames must also never panic.
    #[test]
    fn decode_never_panics_on_corrupted_frames() {
        check(
            "wire-decode-corrupt",
            2000,
            |r| {
                let msg = match r.below(4) {
                    0 => Msg::Push {
                        key: "weight".into(),
                        value: vec![1.0; 8],
                        machine: 1,
                        seq: 7,
                    },
                    1 => Msg::Value { key: "weight".into(), value: vec![2.0; 8], version: 3 },
                    2 => Msg::Err { msg: "some failure".into() },
                    _ => Msg::Init { key: "weight".into(), value: vec![0.5; 8] },
                };
                let mut body = encode(&msg)[8..].to_vec();
                for _ in 0..1 + r.below(4) {
                    let i = r.below(body.len());
                    body[i] = r.next_u64() as u8;
                }
                if r.below(3) == 0 {
                    let cut = r.below(body.len() + 1);
                    body.truncate(cut);
                }
                body
            },
            |bytes| {
                let _ = decode(bytes);
                true
            },
        );
    }
}

//! The level-2 (inter-machine) parameter server (paper §3.3, Figure 5).
//!
//! One thread per connection; shared state guarded by a mutex + condvar.
//! Pushes from the `num_machines` level-1 aggregators are queued per
//! machine and per round: a round applies once every *active* machine has
//! a pending push, the contributions are reduced in machine-index order
//! (bitwise-deterministic regardless of arrival order), the server-side
//! SGD updater runs, and the key's version advances.  Pulls carry an
//! `after_version` watermark: sequential consistency waits for the full
//! watermark (`rounds`), **bounded-delay** consistency waits for
//! `rounds - k` (the client computes the relaxed watermark, so one wire
//! primitive serves the whole §2.3 consistency spectrum), and eventual
//! consistency passes 0 and is served immediately.
//!
//! Fault tolerance: pushes carry per-machine monotonic sequence numbers,
//! so a retransmitted push (client retry after a lost ack) is recognized
//! and dropped — retries are idempotent and gradients are never applied
//! twice.  Barriers are idempotent by (id, machine).  When configured
//! with a lease ([`ServerConfig`]), a machine that stops heartbeating is
//! expired: under [`ExpiryPolicy::FailRound`] the server poisons itself
//! and every parked or future request errors (BSP semantics — fail fast);
//! under [`ExpiryPolicy::Degrade`] the machine is removed from the active
//! set, in-flight rounds and barriers are re-evaluated against the
//! survivors, and training continues (elastic semantics).  A rejoining
//! machine announces itself with `Hello` and is folded back in: its
//! stale pending queue is dropped and the `HelloAck` reply carries the
//! machine's push-seq and released-barrier high-water marks, so a
//! restarted process (local counters back at 0) resumes numbering above
//! them instead of colliding with the dedup floors.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::fault::{inject_send, FaultPlan};
use super::wire::{read_msg, write_msg, Msg};
use super::{lock, wait};
use crate::error::{Error, Result};

/// Server-side updater configuration (plain-SGD on raw f32 buffers; the
/// server has no engine — it is the paper's dedicated server process).
#[derive(Debug, Clone, Copy)]
pub struct ServerUpdater {
    /// Learning rate.
    pub lr: f32,
    /// Momentum coefficient.
    pub momentum: f32,
    /// Weight decay.
    pub weight_decay: f32,
    /// Gradient rescale (1/num_machines/num_devices typically).
    pub rescale: f32,
}

impl Default for ServerUpdater {
    fn default() -> Self {
        ServerUpdater { lr: 0.05, momentum: 0.9, weight_decay: 1e-4, rescale: 1.0 }
    }
}

/// What to do when a machine's lease expires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpiryPolicy {
    /// Poison the server: parked and future requests error out.  The
    /// right semantics for BSP runs, where a lost machine means the
    /// round can never complete correctly.
    FailRound,
    /// Drop the machine from the active set and keep going with the
    /// survivors (elastic graceful degradation).
    Degrade,
}

/// Lease / fault-injection configuration for [`PsServer::start_with`].
#[derive(Clone)]
pub struct ServerConfig {
    /// Heartbeat lease; `None` disables expiry entirely.
    pub lease: Option<Duration>,
    /// Grace period after server start for a machine that has never
    /// connected (it cannot heartbeat before it exists).
    pub join_grace: Duration,
    /// Policy applied when a lease expires.
    pub expiry: ExpiryPolicy,
    /// Optional fault plan injected into server replies (drops, delays,
    /// truncations; duplicates are suppressed on replies).
    pub fault: Option<Arc<FaultPlan>>,
    /// Shard identity `(index, total)` when this server is one shard of
    /// a partitioned key space (`server --shard I/N`).  Advertised in
    /// every `HelloAck` so a client dialing the wrong slot fails at
    /// connect, and prefixed to log lines so a fleet's interleaved
    /// stderr stays attributable.  `None` = unsharded (reports `0/1`).
    pub shard: Option<(u32, u32)>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            lease: None,
            join_grace: Duration::from_secs(10),
            expiry: ExpiryPolicy::FailRound,
            fault: None,
            shard: None,
        }
    }
}

impl ServerConfig {
    /// Build from environment knobs: `PALLAS_KV_LEASE_MS`,
    /// `PALLAS_KV_LEASE_POLICY` (`fail` | `degrade`),
    /// `PALLAS_KV_JOIN_GRACE_MS`, and the `PALLAS_FAULT_*` family.
    pub fn from_env() -> ServerConfig {
        fn envu(name: &str) -> Option<u64> {
            std::env::var(name).ok()?.trim().parse().ok()
        }
        let lease = envu("PALLAS_KV_LEASE_MS").map(Duration::from_millis);
        let join_grace = envu("PALLAS_KV_JOIN_GRACE_MS")
            .map(Duration::from_millis)
            .or(lease.map(|l| l * 5))
            .unwrap_or(Duration::from_secs(10));
        let expiry = match std::env::var("PALLAS_KV_LEASE_POLICY").as_deref() {
            Ok("degrade") => ExpiryPolicy::Degrade,
            _ => ExpiryPolicy::FailRound,
        };
        let shard = std::env::var("PALLAS_KV_SHARD").ok().and_then(|v| parse_shard(&v).ok());
        ServerConfig { lease, join_grace, expiry, fault: FaultPlan::from_env(), shard }
    }
}

/// Log-line prefix carrying the shard identity, so N shard processes
/// interleaving on one stderr stay attributable.
fn log_tag(cfg: &ServerConfig) -> String {
    match cfg.shard {
        Some((i, n)) => format!("[mixnet-ps {i}/{n}]"),
        None => "[mixnet-ps]".to_string(),
    }
}

/// Parse a shard spec of the form `I/N` (e.g. `1/4`), validating
/// `I < N` and `N >= 1`.  Shared by `ServerConfig::from_env`
/// (`PALLAS_KV_SHARD`) and the CLI (`server --shard I/N`).
pub fn parse_shard(spec: &str) -> Result<(u32, u32)> {
    let mut it = spec.trim().splitn(2, '/');
    let parse = |s: Option<&str>| -> Option<u32> { s?.trim().parse().ok() };
    match (parse(it.next()), parse(it.next())) {
        (Some(i), Some(n)) if n >= 1 && i < n => Ok((i, n)),
        _ => Err(Error::kv(format!("bad shard spec '{spec}' (want I/N with I < N)"))),
    }
}

struct KeyState {
    weight: Vec<f32>,
    velocity: Vec<f32>,
    /// Per-machine FIFO of (seq, gradient) awaiting their round.
    pending: Vec<VecDeque<(u64, Vec<f32>)>>,
    /// Highest sequence number applied per machine (dedup floor).
    applied_seq: Vec<u64>,
    version: u64,
}

struct MachineState {
    last_seen: Instant,
    /// Has this machine ever contacted the server?
    joined: bool,
    /// Is it part of the active set (rounds + barriers wait on it)?
    active: bool,
    /// Highest push sequence number ever received from this machine —
    /// the resume floor returned in `HelloAck` so a restarted worker
    /// (whose local counter is back at 0) numbers its pushes above every
    /// seq the dead incarnation used instead of colliding with the
    /// per-key dedup floors.
    max_seq: u64,
}

struct ServerState {
    keys: HashMap<String, KeyState>,
    /// Arrived machines per barrier id (idempotent by machine).
    barriers: HashMap<u64, HashSet<u32>>,
    barrier_gen: HashMap<u64, u64>,
    machines: Vec<MachineState>,
    /// Set once a lease expiry fails the run (FailRound policy); every
    /// request afterwards errors with this message.
    fault: Option<String>,
    /// Join/leave log, in the order the server observed them.
    membership: Vec<(u32, bool)>,
    /// Highest barrier id ever released — the resume floor returned in
    /// `HelloAck` so a restarted worker's barrier counter fast-forwards
    /// past generations that would otherwise ack without synchronizing.
    barrier_hwm: u64,
}

struct Shared {
    state: Mutex<ServerState>,
    cv: Condvar,
    updater: ServerUpdater,
    num_machines: usize,
    cfg: ServerConfig,
    started: Instant,
    stop: AtomicBool,
    msgs_in: AtomicU64,
    bytes_in: AtomicU64,
    dedup_hits: AtomicU64,
    lease_expiries: AtomicU64,
    applies: AtomicU64,
}

/// A running parameter server.
pub struct PsServer {
    addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept_thread: Option<JoinHandle<()>>,
}

impl PsServer {
    /// Bind on `127.0.0.1:port` (0 = ephemeral) and start serving
    /// `num_machines` level-1 clients, with lease/fault behavior taken
    /// from the environment (see [`ServerConfig::from_env`]; leases stay
    /// off unless `PALLAS_KV_LEASE_MS` is set).
    pub fn start(port: u16, num_machines: usize, updater: ServerUpdater) -> Result<PsServer> {
        PsServer::start_with(port, num_machines, updater, ServerConfig::from_env())
    }

    /// [`PsServer::start`] with an explicit [`ServerConfig`].
    pub fn start_with(
        port: u16,
        num_machines: usize,
        updater: ServerUpdater,
        cfg: ServerConfig,
    ) -> Result<PsServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let num_machines = num_machines.max(1);
        let now = Instant::now();
        let machines = (0..num_machines)
            .map(|_| MachineState { last_seen: now, joined: false, active: true, max_seq: 0 })
            .collect();
        let shared = Arc::new(Shared {
            state: Mutex::new(ServerState {
                keys: HashMap::new(),
                barriers: HashMap::new(),
                barrier_gen: HashMap::new(),
                machines,
                fault: None,
                membership: Vec::new(),
                barrier_hwm: 0,
            }),
            cv: Condvar::new(),
            updater,
            num_machines,
            cfg,
            started: now,
            stop: AtomicBool::new(false),
            msgs_in: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            dedup_hits: AtomicU64::new(0),
            lease_expiries: AtomicU64::new(0),
            applies: AtomicU64::new(0),
        });
        let accept_shared = Arc::clone(&shared);
        let accept_thread = std::thread::Builder::new()
            .name("mixnet-ps-accept".into())
            .spawn(move || {
                let mut conns: Vec<JoinHandle<()>> = Vec::new();
                loop {
                    if accept_shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    check_leases(&accept_shared);
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let s = Arc::clone(&accept_shared);
                            let spawned = std::thread::Builder::new()
                                .name("mixnet-ps-conn".into())
                                .spawn(move || serve_conn(stream, s));
                            match spawned {
                                Ok(h) => conns.push(h),
                                // Out of threads: drop the connection;
                                // the client will retry.
                                Err(e) => eprintln!("[mixnet-ps] spawn conn failed: {e}"),
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })
            .map_err(|e| Error::kv(format!("spawn accept thread: {e}")))?;
        Ok(PsServer { addr, shared, accept_thread: Some(accept_thread) })
    }

    /// Address clients should connect to.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Total data-plane messages received (bandwidth accounting for
    /// E3/E5; Hello/Heartbeat control frames are not counted).
    pub fn messages_received(&self) -> u64 {
        self.shared.msgs_in.load(Ordering::Relaxed)
    }

    /// Total payload bytes received.
    pub fn bytes_received(&self) -> u64 {
        self.shared.bytes_in.load(Ordering::Relaxed)
    }

    /// Retransmissions recognized and dropped (pushes + barriers).
    pub fn dedup_hits(&self) -> u64 {
        self.shared.dedup_hits.load(Ordering::Relaxed)
    }

    /// Number of machine leases that expired.
    pub fn lease_expiries(&self) -> u64 {
        self.shared.lease_expiries.load(Ordering::Relaxed)
    }

    /// Optimizer rounds applied across all keys.
    pub fn rounds_applied(&self) -> u64 {
        self.shared.applies.load(Ordering::Relaxed)
    }

    /// Join/leave events observed so far, in order.
    pub fn membership_events(&self) -> Vec<(u32, bool)> {
        lock(&self.shared.state).membership.clone()
    }

    /// Stop accepting and shut down (open connections end on their next
    /// message or disconnect).
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for PsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Can a round apply for this key?  Every active machine must have a
/// pending push (inactive backlogs ride along but never gate progress).
fn round_ready(ks: &KeyState, active: &[bool]) -> bool {
    let mut any_active = false;
    for (m, &a) in active.iter().enumerate() {
        if a {
            any_active = true;
            if ks.pending[m].is_empty() {
                return false;
            }
        }
    }
    any_active
}

/// Pop one pending push per machine (machine-index order — the reduction
/// order is deterministic no matter how pushes arrived), apply the
/// server-side SGD update, and advance the version.
fn apply_round(upd: &ServerUpdater, ks: &mut KeyState) {
    let prof = crate::profile::SpanTimer::start();
    let n = ks.weight.len();
    let mut accum = vec![0.0f32; n];
    for m in 0..ks.pending.len() {
        if let Some((seq, v)) = ks.pending[m].pop_front() {
            for (a, x) in accum.iter_mut().zip(&v) {
                *a += *x;
            }
            if seq > ks.applied_seq[m] {
                ks.applied_seq[m] = seq;
            }
        }
    }
    for i in 0..n {
        let g = upd.rescale * accum[i] + upd.weight_decay * ks.weight[i];
        if upd.momentum != 0.0 {
            ks.velocity[i] = upd.momentum * ks.velocity[i] - upd.lr * g;
            ks.weight[i] += ks.velocity[i];
        } else {
            ks.weight[i] -= upd.lr * g;
        }
    }
    ks.version += 1;
    // `a` = key length, `b` = resulting version of the applied round.
    prof.finish(crate::profile::Category::KvServer, "kv.apply_round", 0, n as u64, ks.version);
}

/// Apply every key round that is ready (cascading: one apply can unblock
/// the next queued round).  Returns true if anything applied.
fn try_apply(shared: &Shared, st: &mut ServerState) -> bool {
    let active: Vec<bool> = st.machines.iter().map(|m| m.active).collect();
    let mut any = false;
    for ks in st.keys.values_mut() {
        while round_ready(ks, &active) {
            apply_round(&shared.updater, ks);
            shared.applies.fetch_add(1, Ordering::Relaxed);
            any = true;
        }
    }
    any
}

/// Release every barrier whose arrival set covers the active machines.
fn release_ready_barriers(st: &mut ServerState) -> bool {
    let active: Vec<u32> = st
        .machines
        .iter()
        .enumerate()
        .filter_map(|(i, m)| m.active.then_some(i as u32))
        .collect();
    let ids: Vec<u64> = st.barriers.keys().copied().collect();
    let mut released = false;
    for id in ids {
        let ready = {
            let arrived = &st.barriers[&id];
            !arrived.is_empty() && active.iter().all(|m| arrived.contains(m))
        };
        if ready {
            st.barriers.remove(&id);
            *st.barrier_gen.entry(id).or_insert(0) += 1;
            st.barrier_hwm = st.barrier_hwm.max(id);
            released = true;
        }
    }
    released
}

/// Refresh a machine's lease on any inbound traffic from it.  `m` must
/// already be validated against `num_machines` (see [`check_machine`]).
fn touch(st: &mut ServerState, m: usize) {
    st.machines[m].last_seen = Instant::now();
    st.machines[m].joined = true;
}

/// Validate a wire machine id.  Out-of-range ids are rejected with an
/// error rather than wrapped: a misconfigured worker must not alias
/// another machine's lease, dedup floor, or pending queue.
fn check_machine(machine: u32, num_machines: usize) -> std::result::Result<usize, Msg> {
    let m = machine as usize;
    if m >= num_machines {
        return Err(Msg::Err {
            msg: format!("machine id {machine} out of range (num_machines={num_machines})"),
        });
    }
    Ok(m)
}

/// Expire machines whose lease lapsed (runs on the accept thread).
fn check_leases(shared: &Shared) {
    let Some(lease) = shared.cfg.lease else { return };
    let now = Instant::now();
    let mut st = lock(&shared.state);
    let mut changed = false;
    for m in 0..st.machines.len() {
        let (joined, active, last_seen) = {
            let ms = &st.machines[m];
            (ms.joined, ms.active, ms.last_seen)
        };
        if !active {
            continue;
        }
        let deadline =
            if joined { last_seen + lease } else { shared.started + shared.cfg.join_grace };
        if now < deadline {
            continue;
        }
        shared.lease_expiries.fetch_add(1, Ordering::Relaxed);
        st.machines[m].active = false;
        changed = true;
        match shared.cfg.expiry {
            ExpiryPolicy::FailRound => {
                eprintln!(
                    "{} lease expired: machine {m}; failing round (bsp)",
                    log_tag(&shared.cfg)
                );
                st.fault = Some(format!("machine {m} lease expired; round failed"));
            }
            ExpiryPolicy::Degrade => {
                st.membership.push((m as u32, false));
                let left = st.machines.iter().filter(|x| x.active).count();
                eprintln!(
                    "{} lease expired: machine {m} leaves; {left} machine(s) remain",
                    log_tag(&shared.cfg)
                );
                if left == 0 {
                    st.fault = Some("all machines lost their lease".into());
                } else {
                    try_apply(shared, &mut st);
                    release_ready_barriers(&mut st);
                }
            }
        }
    }
    if changed {
        shared.cv.notify_all();
    }
}

/// Write one reply through the (optional) fault layer.  Returns false
/// when the connection must be torn down.
fn send_reply(w: &mut TcpStream, msg: &Msg, plan: &Option<Arc<FaultPlan>>) -> bool {
    let res = match plan {
        Some(p) => inject_send(w, msg, p, false).map(|_| ()),
        None => write_msg(w, msg),
    };
    res.is_ok()
}

fn serve_conn(stream: TcpStream, shared: Arc<Shared>) {
    let Ok(mut reader) = stream.try_clone() else { return };
    let mut writer = stream;
    let plan = shared.cfg.fault.clone();
    loop {
        // Poll for the next frame with a short timeout so shutdown() can
        // reap connections that are idle (blocked with no inbound data);
        // once a frame starts arriving, read it without a deadline.
        reader.set_read_timeout(Some(Duration::from_millis(50))).ok();
        let mut first = [0u8; 1];
        match reader.peek(&mut first) {
            Ok(0) => return, // EOF
            Ok(_) => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        reader.set_read_timeout(None).ok();
        let msg = match read_msg(&mut reader) {
            Ok(m) => m,
            Err(_) => return, // disconnect or malformed frame
        };
        match &msg {
            // Control-plane frames are free: they must not skew the
            // bandwidth accounting the scaling benches assert on.
            Msg::Hello { .. } | Msg::Heartbeat { .. } => {}
            _ => {
                shared.msgs_in.fetch_add(1, Ordering::Relaxed);
            }
        }
        match msg {
            Msg::Init { key, value } => {
                shared.bytes_in.fetch_add(4 * value.len() as u64, Ordering::Relaxed);
                let mut st = lock(&shared.state);
                let n = shared.num_machines;
                st.keys.entry(key).or_insert_with(|| KeyState {
                    velocity: vec![0.0; value.len()],
                    pending: (0..n).map(|_| VecDeque::new()).collect(),
                    applied_seq: vec![0; n],
                    version: 0,
                    weight: value,
                });
                drop(st);
                if !send_reply(&mut writer, &Msg::Ack, &plan) {
                    return;
                }
            }
            Msg::Push { key, value, machine, seq } => {
                shared.bytes_in.fetch_add(4 * value.len() as u64, Ordering::Relaxed);
                let m = match check_machine(machine, shared.num_machines) {
                    Ok(m) => m,
                    Err(reply) => {
                        if !send_reply(&mut writer, &reply, &plan) {
                            return;
                        }
                        continue;
                    }
                };
                let mut st = lock(&shared.state);
                touch(&mut st, m);
                st.machines[m].max_seq = st.machines[m].max_seq.max(seq);
                let reply = if let Some(f) = st.fault.clone() {
                    Msg::Err { msg: f }
                } else {
                    match st.keys.get_mut(&key) {
                        None => Msg::Err { msg: format!("unknown key '{key}'") },
                        Some(ks) => {
                            let floor = ks
                                .pending[m]
                                .back()
                                .map(|&(s, _)| s)
                                .unwrap_or(ks.applied_seq[m]);
                            if seq != 0 && seq <= floor {
                                // Retransmission of a push we already
                                // queued or applied: idempotent.
                                shared.dedup_hits.fetch_add(1, Ordering::Relaxed);
                                Msg::Ack
                            } else if value.len() != ks.weight.len() {
                                Msg::Err {
                                    msg: format!(
                                        "push size {} != {}",
                                        value.len(),
                                        ks.weight.len()
                                    ),
                                }
                            } else {
                                ks.pending[m].push_back((seq, value));
                                if try_apply(&shared, &mut st) {
                                    shared.cv.notify_all();
                                }
                                Msg::Ack
                            }
                        }
                    }
                };
                drop(st);
                if !send_reply(&mut writer, &reply, &plan) {
                    return;
                }
            }
            Msg::Pull { key, after_version } => {
                let mut st = lock(&shared.state);
                loop {
                    if let Some(f) = st.fault.clone() {
                        drop(st);
                        if !send_reply(&mut writer, &Msg::Err { msg: f }, &plan) {
                            return;
                        }
                        break;
                    }
                    match st.keys.get(&key) {
                        None => {
                            drop(st);
                            let reply = Msg::Err { msg: format!("unknown key '{key}'") };
                            if !send_reply(&mut writer, &reply, &plan) {
                                return;
                            }
                            break;
                        }
                        Some(ks) if ks.version >= after_version => {
                            let reply = Msg::Value {
                                key: key.clone(),
                                value: ks.weight.clone(),
                                version: ks.version,
                            };
                            drop(st);
                            if !send_reply(&mut writer, &reply, &plan) {
                                return;
                            }
                            break;
                        }
                        Some(_) => {
                            if shared.stop.load(Ordering::SeqCst) {
                                return;
                            }
                            st = wait(&shared.cv, st);
                        }
                    }
                }
            }
            Msg::Barrier { id, machine } => {
                let m = match check_machine(machine, shared.num_machines) {
                    Ok(m) => m,
                    Err(reply) => {
                        if !send_reply(&mut writer, &reply, &plan) {
                            return;
                        }
                        continue;
                    }
                };
                let mut st = lock(&shared.state);
                touch(&mut st, m);
                if let Some(f) = st.fault.clone() {
                    drop(st);
                    if !send_reply(&mut writer, &Msg::Err { msg: f }, &plan) {
                        return;
                    }
                    continue;
                }
                if *st.barrier_gen.get(&id).unwrap_or(&0) >= 1 {
                    // Retransmission after the barrier already released
                    // (the ack was lost): idempotent.
                    shared.dedup_hits.fetch_add(1, Ordering::Relaxed);
                    drop(st);
                    if !send_reply(&mut writer, &Msg::Ack, &plan) {
                        return;
                    }
                    continue;
                }
                if !st.barriers.entry(id).or_default().insert(machine) {
                    shared.dedup_hits.fetch_add(1, Ordering::Relaxed);
                }
                if release_ready_barriers(&mut st) {
                    shared.cv.notify_all();
                }
                let mut failed = None;
                while *st.barrier_gen.get(&id).unwrap_or(&0) == 0 {
                    if shared.stop.load(Ordering::SeqCst) {
                        return;
                    }
                    if let Some(f) = st.fault.clone() {
                        failed = Some(f);
                        break;
                    }
                    st = wait(&shared.cv, st);
                }
                drop(st);
                let reply = match failed {
                    Some(f) => Msg::Err { msg: f },
                    None => Msg::Ack,
                };
                if !send_reply(&mut writer, &reply, &plan) {
                    return;
                }
            }
            Msg::Hello { machine } => {
                let m = match check_machine(machine, shared.num_machines) {
                    Ok(m) => m,
                    Err(reply) => {
                        if !send_reply(&mut writer, &reply, &plan) {
                            return;
                        }
                        continue;
                    }
                };
                let mut st = lock(&shared.state);
                touch(&mut st, m);
                if !st.machines[m].active {
                    // Rejoin after a lease expiry: the old incarnation is
                    // gone, so drop any gradients it left queued — the
                    // new incarnation starts its rounds fresh (its seq
                    // floor is preserved in `max_seq`, which already
                    // covers every queued seq).
                    st.machines[m].active = true;
                    for ks in st.keys.values_mut() {
                        ks.pending[m].clear();
                    }
                    st.membership.push((machine, true));
                    eprintln!("{} machine {machine} rejoins", log_tag(&shared.cfg));
                }
                let (shard, shards) = shared.cfg.shard.unwrap_or((0, 1));
                let reply = Msg::HelloAck {
                    seq: st.machines[m].max_seq,
                    barrier: st.barrier_hwm,
                    shard,
                    shards,
                };
                drop(st);
                if !send_reply(&mut writer, &reply, &plan) {
                    return;
                }
            }
            Msg::Heartbeat { machine } => {
                let m = match check_machine(machine, shared.num_machines) {
                    Ok(m) => m,
                    Err(reply) => {
                        if !send_reply(&mut writer, &reply, &plan) {
                            return;
                        }
                        continue;
                    }
                };
                let mut st = lock(&shared.state);
                touch(&mut st, m);
                drop(st);
                if !send_reply(&mut writer, &Msg::Ack, &plan) {
                    return;
                }
            }
            Msg::Stats => {
                let reply = Msg::StatsReply {
                    msgs: shared.msgs_in.load(Ordering::Relaxed),
                    bytes: shared.bytes_in.load(Ordering::Relaxed),
                    dedup_hits: shared.dedup_hits.load(Ordering::Relaxed),
                    lease_expiries: shared.lease_expiries.load(Ordering::Relaxed),
                    applies: shared.applies.load(Ordering::Relaxed),
                };
                if !send_reply(&mut writer, &reply, &plan) {
                    return;
                }
            }
            Msg::Shutdown => {
                shared.stop.store(true, Ordering::SeqCst);
                shared.cv.notify_all();
                let _ = send_reply(&mut writer, &Msg::Ack, &plan);
                return;
            }
            other => {
                let reply = Msg::Err { msg: format!("unexpected message {other:?}") };
                if !send_reply(&mut writer, &reply, &plan) {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::wire::{read_msg, write_msg};

    fn connect(addr: std::net::SocketAddr) -> TcpStream {
        TcpStream::connect(addr).unwrap()
    }

    fn rpc(stream: &mut TcpStream, msg: &Msg) -> Msg {
        write_msg(stream, msg).unwrap();
        read_msg(stream).unwrap()
    }

    fn push(key: &str, value: Vec<f32>, machine: u32, seq: u64) -> Msg {
        Msg::Push { key: key.into(), value, machine, seq }
    }

    #[test]
    fn init_push_pull_one_machine() {
        let srv = PsServer::start(
            0,
            1,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
        )
        .unwrap();
        let mut c = connect(srv.addr());
        assert_eq!(rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![1.0, 2.0] }), Msg::Ack);
        assert_eq!(rpc(&mut c, &push("w", vec![0.5, 0.5], 0, 1)), Msg::Ack);
        match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 1 }) {
            Msg::Value { value, version, .. } => {
                assert_eq!(value, vec![0.5, 1.5]);
                assert_eq!(version, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn round_waits_for_all_machines() {
        let srv = PsServer::start(
            0,
            2,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
        )
        .unwrap();
        let addr = srv.addr();
        let mut c0 = connect(addr);
        rpc(&mut c0, &Msg::Init { key: "w".into(), value: vec![0.0] });
        rpc(&mut c0, &push("w", vec![1.0], 0, 1));
        // a sequential pull (after_version=1) must block until machine 1
        // pushes; do it from a thread.
        let h = std::thread::spawn(move || {
            let mut c = connect(addr);
            match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 1 }) {
                Msg::Value { value, .. } => value[0],
                other => panic!("{other:?}"),
            }
        });
        std::thread::sleep(Duration::from_millis(50));
        assert!(!h.is_finished(), "pull must wait for the round");
        let mut c1 = connect(addr);
        rpc(&mut c1, &push("w", vec![2.0], 1, 1));
        let got = h.join().unwrap();
        assert_eq!(got, -3.0); // w = 0 - 1*(1+2)
    }

    #[test]
    fn eventual_pull_returns_immediately() {
        let srv = PsServer::start(0, 2, ServerUpdater::default()).unwrap();
        let mut c = connect(srv.addr());
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![5.0] });
        rpc(&mut c, &push("w", vec![1.0], 0, 1));
        match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 0 }) {
            Msg::Value { value, version, .. } => {
                assert_eq!(value, vec![5.0]);
                assert_eq!(version, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_key_errors() {
        let srv = PsServer::start(0, 1, ServerUpdater::default()).unwrap();
        let mut c = connect(srv.addr());
        match rpc(&mut c, &push("nope", vec![1.0], 0, 1)) {
            Msg::Err { .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn barrier_releases_all_machines() {
        let srv = PsServer::start(0, 3, ServerUpdater::default()).unwrap();
        let addr = srv.addr();
        let hs: Vec<_> = (0..3u32)
            .map(|m| {
                std::thread::spawn(move || {
                    let mut c = connect(addr);
                    rpc(&mut c, &Msg::Barrier { id: 1, machine: m });
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn message_accounting() {
        let srv = PsServer::start(0, 1, ServerUpdater::default()).unwrap();
        let mut c = connect(srv.addr());
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![0.0; 100] });
        rpc(&mut c, &push("w", vec![0.0; 100], 0, 1));
        assert_eq!(srv.messages_received(), 2);
        assert_eq!(srv.bytes_received(), 800);
        // the same counters over the wire (harness observability)
        match rpc(&mut c, &Msg::Stats) {
            Msg::StatsReply { msgs, bytes, .. } => {
                assert_eq!(msgs, 3, "init + push + stats itself");
                assert_eq!(bytes, 800);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bounded_delay_watermark_is_served_without_full_round() {
        // 2 machines; only machine 0 has pushed.  A pull at watermark
        // rounds-k = 0 (client-side bounded-delay relaxation) must be
        // served immediately with the pre-round weight, while the full
        // sequential watermark would park.
        let srv = PsServer::start(
            0,
            2,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
        )
        .unwrap();
        let mut c = connect(srv.addr());
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![3.0] });
        rpc(&mut c, &push("w", vec![1.0], 0, 1));
        match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 0 }) {
            Msg::Value { value, version, .. } => {
                assert_eq!(value, vec![3.0]);
                assert_eq!(version, 0, "round incomplete: version unchanged");
            }
            other => panic!("{other:?}"),
        }
    }

    /// A retransmitted push (same machine, same seq) must not contribute
    /// a second gradient.
    #[test]
    fn duplicate_push_is_deduplicated() {
        let srv = PsServer::start(
            0,
            1,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
        )
        .unwrap();
        let mut c = connect(srv.addr());
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![0.0] });
        assert_eq!(rpc(&mut c, &push("w", vec![1.0], 0, 1)), Msg::Ack);
        assert_eq!(rpc(&mut c, &push("w", vec![1.0], 0, 1)), Msg::Ack, "retry still acks");
        assert_eq!(srv.dedup_hits(), 1);
        assert_eq!(srv.rounds_applied(), 1, "exactly one apply despite two deliveries");
        match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 1 }) {
            Msg::Value { value, version, .. } => {
                assert_eq!(value, vec![-1.0], "gradient applied once");
                assert_eq!(version, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    /// A machine running ahead queues per round: its surplus pushes must
    /// pair with peers' later pushes, not blend into the current round.
    #[test]
    fn out_of_round_pushes_queue_separately() {
        let srv = PsServer::start(
            0,
            2,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
        )
        .unwrap();
        let mut c0 = connect(srv.addr());
        let mut c1 = connect(srv.addr());
        rpc(&mut c0, &Msg::Init { key: "w".into(), value: vec![0.0] });
        // machine 0 is two rounds ahead
        rpc(&mut c0, &push("w", vec![1.0], 0, 1));
        rpc(&mut c0, &push("w", vec![10.0], 0, 2));
        rpc(&mut c1, &push("w", vec![2.0], 1, 1)); // completes round 1: w = -3
        rpc(&mut c1, &push("w", vec![20.0], 1, 2)); // completes round 2: w = -33
        match rpc(&mut c0, &Msg::Pull { key: "w".into(), after_version: 2 }) {
            Msg::Value { value, version, .. } => {
                assert_eq!(value, vec![-33.0], "rounds must apply separately in order");
                assert_eq!(version, 2);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(srv.rounds_applied(), 2);
    }

    /// Under the degrade policy, an expired machine stops gating rounds
    /// and barriers; the survivors keep training.
    #[test]
    fn degrade_policy_expires_silent_machine() {
        let cfg = ServerConfig {
            lease: Some(Duration::from_millis(150)),
            join_grace: Duration::from_millis(300),
            expiry: ExpiryPolicy::Degrade,
            fault: None,
            shard: None,
        };
        let srv = PsServer::start_with(
            0,
            2,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
            cfg,
        )
        .unwrap();
        let mut c = connect(srv.addr());
        rpc(&mut c, &Msg::Hello { machine: 0 });
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![0.0] });
        rpc(&mut c, &push("w", vec![1.0], 0, 1));
        // machine 1 never shows up; its join grace lapses and the round
        // completes with machine 0 alone.
        match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 1 }) {
            Msg::Value { value, version, .. } => {
                assert_eq!(value, vec![-1.0]);
                assert_eq!(version, 1);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(srv.lease_expiries(), 1);
        assert_eq!(srv.membership_events(), vec![(1, false)]);
    }

    /// `Hello` answers with the machine's push-seq and released-barrier
    /// high-water marks, so a restarted worker (local counters back at
    /// 0) resumes numbering above the server's dedup floors instead of
    /// having every push silently swallowed as a retransmission.
    #[test]
    fn hello_ack_reports_resume_floors() {
        let srv = PsServer::start(
            0,
            1,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
        )
        .unwrap();
        let mut c = connect(srv.addr());
        assert_eq!(
            rpc(&mut c, &Msg::Hello { machine: 0 }),
            Msg::HelloAck { seq: 0, barrier: 0, shard: 0, shards: 1 }
        );
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![0.0] });
        rpc(&mut c, &push("w", vec![1.0], 0, 1));
        rpc(&mut c, &push("w", vec![1.0], 0, 2));
        rpc(&mut c, &Msg::Barrier { id: 1, machine: 0 });
        // "kill -9 + restart": a fresh connection's Hello reports the
        // floors the dead incarnation reached.
        let mut c2 = connect(srv.addr());
        assert_eq!(
            rpc(&mut c2, &Msg::Hello { machine: 0 }),
            Msg::HelloAck { seq: 2, barrier: 1, shard: 0, shards: 1 }
        );
        // A push at the floor is still a retransmission; one above it is
        // fresh work and must apply.
        assert_eq!(rpc(&mut c2, &push("w", vec![1.0], 0, 2)), Msg::Ack);
        assert_eq!(srv.dedup_hits(), 1);
        assert_eq!(rpc(&mut c2, &push("w", vec![1.0], 0, 3)), Msg::Ack);
        assert_eq!(srv.rounds_applied(), 3);
    }

    /// An out-of-range machine id is rejected with an error instead of
    /// wrapping onto another machine's lease/dedup/queue state.
    #[test]
    fn out_of_range_machine_id_rejected() {
        let srv = PsServer::start(0, 2, ServerUpdater::default()).unwrap();
        let mut c = connect(srv.addr());
        for msg in [
            push("w", vec![1.0], 2, 1),
            Msg::Barrier { id: 1, machine: 7 },
            Msg::Hello { machine: 2 },
            Msg::Heartbeat { machine: 99 },
        ] {
            match rpc(&mut c, &msg) {
                Msg::Err { msg } => assert!(msg.contains("out of range"), "{msg}"),
                other => panic!("{other:?}"),
            }
        }
        // no state was touched on behalf of machine 0 or 1
        assert_eq!(srv.dedup_hits(), 0);
        assert_eq!(srv.membership_events(), vec![]);
    }

    /// After a degrade-policy expiry, rejoining drops the dead
    /// incarnation's queued pushes: the next round pairs the survivors
    /// with the NEW incarnation's gradient, not a stale one.
    #[test]
    fn rejoin_clears_stale_backlog() {
        let cfg = ServerConfig {
            lease: Some(Duration::from_millis(400)),
            join_grace: Duration::from_millis(800),
            expiry: ExpiryPolicy::Degrade,
            fault: None,
            shard: None,
        };
        let srv = PsServer::start_with(
            0,
            2,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
            cfg,
        )
        .unwrap();
        let mut c0 = connect(srv.addr());
        let mut c1 = connect(srv.addr());
        rpc(&mut c0, &Msg::Hello { machine: 0 });
        rpc(&mut c1, &Msg::Hello { machine: 1 });
        rpc(&mut c0, &Msg::Init { key: "w".into(), value: vec![0.0] });
        // machine 1 queues a push that never completes a round, then
        // dies silently; machine 0 heartbeats through the expiry.
        rpc(&mut c1, &push("w", vec![5.0], 1, 1));
        for _ in 0..200 {
            if srv.lease_expiries() >= 1 {
                break;
            }
            rpc(&mut c0, &Msg::Heartbeat { machine: 0 });
            std::thread::sleep(Duration::from_millis(25));
        }
        assert_eq!(srv.lease_expiries(), 1, "machine 1 never expired");
        // restart of machine 1: rejoin clears the stale queued gradient
        // and reports the seq floor to resume above.
        let mut c1b = connect(srv.addr());
        assert_eq!(
            rpc(&mut c1b, &Msg::Hello { machine: 1 }),
            Msg::HelloAck { seq: 1, barrier: 0, shard: 0, shards: 1 }
        );
        rpc(&mut c1b, &push("w", vec![2.0], 1, 2));
        rpc(&mut c0, &push("w", vec![1.0], 0, 1));
        match rpc(&mut c0, &Msg::Pull { key: "w".into(), after_version: 1 }) {
            Msg::Value { value, .. } => {
                assert_eq!(value, vec![-3.0], "round must use the NEW gradient, not the stale 5.0");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(
            srv.membership_events(),
            vec![(1, false), (1, true)],
            "leave + rejoin must both be logged"
        );
    }

    /// Under the fail-round policy an expired lease poisons the server:
    /// parked pulls and later requests error instead of hanging.
    #[test]
    fn fail_round_policy_errors_parked_requests() {
        let cfg = ServerConfig {
            lease: Some(Duration::from_millis(150)),
            join_grace: Duration::from_millis(300),
            expiry: ExpiryPolicy::FailRound,
            fault: None,
            shard: None,
        };
        let srv = PsServer::start_with(0, 2, ServerUpdater::default(), cfg).unwrap();
        let mut c = connect(srv.addr());
        rpc(&mut c, &Msg::Hello { machine: 0 });
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![0.0] });
        rpc(&mut c, &push("w", vec![1.0], 0, 1));
        // machine 1 never arrives: the parked sequential pull must fail
        // once the lease lapses, not hang forever.
        match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 1 }) {
            Msg::Err { msg } => assert!(msg.contains("lease"), "{msg}"),
            other => panic!("{other:?}"),
        }
        assert!(srv.lease_expiries() >= 1);
    }
}

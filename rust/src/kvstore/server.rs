//! The level-2 (inter-machine) parameter server (paper §3.3, Figure 5).
//!
//! One thread per connection; shared state guarded by a mutex + condvar.
//! Pushes from the `num_machines` level-1 aggregators are summed per
//! round, the server-side SGD updater is applied, and the key's version
//! advances.  Pulls carry an `after_version` watermark: sequential
//! consistency waits for the full watermark (`rounds`), **bounded-delay**
//! consistency waits for `rounds - k` (the client computes the relaxed
//! watermark, so one wire primitive serves the whole §2.3 consistency
//! spectrum), and eventual consistency passes 0 and is served
//! immediately.

use std::collections::HashMap;
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::wire::{read_msg, write_msg, Msg};
use crate::error::Result;

/// Server-side updater configuration (plain-SGD on raw f32 buffers; the
/// server has no engine — it is the paper's dedicated server process).
#[derive(Debug, Clone, Copy)]
pub struct ServerUpdater {
    /// Learning rate.
    pub lr: f32,
    /// Momentum coefficient.
    pub momentum: f32,
    /// Weight decay.
    pub weight_decay: f32,
    /// Gradient rescale (1/num_machines/num_devices typically).
    pub rescale: f32,
}

impl Default for ServerUpdater {
    fn default() -> Self {
        ServerUpdater { lr: 0.05, momentum: 0.9, weight_decay: 1e-4, rescale: 1.0 }
    }
}

struct KeyState {
    weight: Vec<f32>,
    velocity: Vec<f32>,
    accum: Vec<f32>,
    pushed_by: Vec<bool>,
    pushed: usize,
    version: u64,
}

#[derive(Default)]
struct ServerState {
    keys: HashMap<String, KeyState>,
    barriers: HashMap<u64, usize>,
    barrier_gen: HashMap<u64, u64>,
}

struct Shared {
    state: Mutex<ServerState>,
    cv: Condvar,
    updater: ServerUpdater,
    num_machines: usize,
    stop: AtomicBool,
    msgs_in: AtomicU64,
    bytes_in: AtomicU64,
}

/// A running parameter server.
pub struct PsServer {
    addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept_thread: Option<JoinHandle<()>>,
}

impl PsServer {
    /// Bind on `127.0.0.1:port` (0 = ephemeral) and start serving
    /// `num_machines` level-1 clients.
    pub fn start(port: u16, num_machines: usize, updater: ServerUpdater) -> Result<PsServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            state: Mutex::new(ServerState::default()),
            cv: Condvar::new(),
            updater,
            num_machines: num_machines.max(1),
            stop: AtomicBool::new(false),
            msgs_in: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
        });
        let accept_shared = Arc::clone(&shared);
        let accept_thread = std::thread::Builder::new()
            .name("mixnet-ps-accept".into())
            .spawn(move || {
                let mut conns: Vec<JoinHandle<()>> = Vec::new();
                loop {
                    if accept_shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let s = Arc::clone(&accept_shared);
                            conns.push(
                                std::thread::Builder::new()
                                    .name("mixnet-ps-conn".into())
                                    .spawn(move || serve_conn(stream, s))
                                    .expect("spawn conn"),
                            );
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })
            .expect("spawn accept");
        Ok(PsServer { addr, shared, accept_thread: Some(accept_thread) })
    }

    /// Address clients should connect to.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Total messages received (bandwidth accounting for E3/E5).
    pub fn messages_received(&self) -> u64 {
        self.shared.msgs_in.load(Ordering::Relaxed)
    }

    /// Total payload bytes received.
    pub fn bytes_received(&self) -> u64 {
        self.shared.bytes_in.load(Ordering::Relaxed)
    }

    /// Stop accepting and shut down (open connections end on their next
    /// message or disconnect).
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for PsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn apply_update(upd: &ServerUpdater, st: &mut KeyState) {
    let n = st.weight.len();
    for i in 0..n {
        let g = upd.rescale * st.accum[i] + upd.weight_decay * st.weight[i];
        if upd.momentum != 0.0 {
            st.velocity[i] = upd.momentum * st.velocity[i] - upd.lr * g;
            st.weight[i] += st.velocity[i];
        } else {
            st.weight[i] -= upd.lr * g;
        }
    }
    st.accum.iter_mut().for_each(|v| *v = 0.0);
    st.pushed = 0;
    st.pushed_by.iter_mut().for_each(|b| *b = false);
    st.version += 1;
}

fn serve_conn(stream: TcpStream, shared: Arc<Shared>) {
    let mut reader = stream.try_clone().expect("clone stream");
    let mut writer = stream;
    loop {
        // Poll for the next frame with a short timeout so shutdown() can
        // reap connections that are idle (blocked with no inbound data);
        // once a frame starts arriving, read it without a deadline.
        reader.set_read_timeout(Some(std::time::Duration::from_millis(50))).ok();
        let mut first = [0u8; 1];
        match reader.peek(&mut first) {
            Ok(0) => return, // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        reader.set_read_timeout(None).ok();
        let msg = match read_msg(&mut reader) {
            Ok(m) => m,
            Err(_) => return, // disconnect
        };
        shared.msgs_in.fetch_add(1, Ordering::Relaxed);
        match msg {
            Msg::Init { key, value } => {
                shared.bytes_in.fetch_add(4 * value.len() as u64, Ordering::Relaxed);
                let mut st = shared.state.lock().unwrap();
                st.keys.entry(key).or_insert_with(|| KeyState {
                    velocity: vec![0.0; value.len()],
                    accum: vec![0.0; value.len()],
                    pushed_by: vec![false; shared.num_machines],
                    pushed: 0,
                    version: 0,
                    weight: value,
                });
                drop(st);
                let _ = write_msg(&mut writer, &Msg::Ack);
            }
            Msg::Push { key, value, machine } => {
                shared.bytes_in.fetch_add(4 * value.len() as u64, Ordering::Relaxed);
                let mut st = shared.state.lock().unwrap();
                let reply = match st.keys.get_mut(&key) {
                    None => Msg::Err { msg: format!("unknown key '{key}'") },
                    Some(ks) => {
                        let m = machine as usize % shared.num_machines;
                        if !ks.pushed_by[m] {
                            ks.pushed_by[m] = true;
                            ks.pushed += 1;
                        }
                        for (a, v) in ks.accum.iter_mut().zip(&value) {
                            *a += v;
                        }
                        if ks.pushed == shared.num_machines {
                            apply_update(&shared.updater, ks);
                            shared.cv.notify_all();
                        }
                        Msg::Ack
                    }
                };
                drop(st);
                let _ = write_msg(&mut writer, &reply);
            }
            Msg::Pull { key, after_version } => {
                let mut st = shared.state.lock().unwrap();
                loop {
                    match st.keys.get(&key) {
                        None => {
                            drop(st);
                            let _ = write_msg(
                                &mut writer,
                                &Msg::Err { msg: format!("unknown key '{key}'") },
                            );
                            break;
                        }
                        Some(ks) if ks.version >= after_version => {
                            let reply = Msg::Value {
                                key: key.clone(),
                                value: ks.weight.clone(),
                                version: ks.version,
                            };
                            drop(st);
                            let _ = write_msg(&mut writer, &reply);
                            break;
                        }
                        Some(_) => {
                            if shared.stop.load(Ordering::SeqCst) {
                                return;
                            }
                            st = shared.cv.wait(st).unwrap();
                        }
                    }
                }
            }
            Msg::Barrier { id, machine: _ } => {
                let mut st = shared.state.lock().unwrap();
                let gen = *st.barrier_gen.entry(id).or_insert(0);
                *st.barriers.entry(id).or_insert(0) += 1;
                if *st.barriers.get(&id).unwrap() >= shared.num_machines {
                    st.barriers.insert(id, 0);
                    *st.barrier_gen.entry(id).or_insert(0) += 1;
                    shared.cv.notify_all();
                } else {
                    while *st.barrier_gen.get(&id).unwrap_or(&0) == gen {
                        if shared.stop.load(Ordering::SeqCst) {
                            return;
                        }
                        st = shared.cv.wait(st).unwrap();
                    }
                }
                drop(st);
                let _ = write_msg(&mut writer, &Msg::Ack);
            }
            Msg::Stats => {
                let reply = Msg::StatsReply {
                    msgs: shared.msgs_in.load(Ordering::Relaxed),
                    bytes: shared.bytes_in.load(Ordering::Relaxed),
                };
                let _ = write_msg(&mut writer, &reply);
            }
            Msg::Shutdown => {
                shared.stop.store(true, Ordering::SeqCst);
                shared.cv.notify_all();
                let _ = write_msg(&mut writer, &Msg::Ack);
                return;
            }
            other => {
                let _ = write_msg(
                    &mut writer,
                    &Msg::Err { msg: format!("unexpected message {other:?}") },
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::wire::{read_msg, write_msg};

    fn connect(addr: std::net::SocketAddr) -> TcpStream {
        TcpStream::connect(addr).unwrap()
    }

    fn rpc(stream: &mut TcpStream, msg: &Msg) -> Msg {
        write_msg(stream, msg).unwrap();
        read_msg(stream).unwrap()
    }

    #[test]
    fn init_push_pull_one_machine() {
        let srv = PsServer::start(
            0,
            1,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
        )
        .unwrap();
        let mut c = connect(srv.addr());
        assert_eq!(rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![1.0, 2.0] }), Msg::Ack);
        assert_eq!(
            rpc(&mut c, &Msg::Push { key: "w".into(), value: vec![0.5, 0.5], machine: 0 }),
            Msg::Ack
        );
        match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 1 }) {
            Msg::Value { value, version, .. } => {
                assert_eq!(value, vec![0.5, 1.5]);
                assert_eq!(version, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn round_waits_for_all_machines() {
        let srv = PsServer::start(
            0,
            2,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
        )
        .unwrap();
        let addr = srv.addr();
        let mut c0 = connect(addr);
        rpc(&mut c0, &Msg::Init { key: "w".into(), value: vec![0.0] });
        rpc(&mut c0, &Msg::Push { key: "w".into(), value: vec![1.0], machine: 0 });
        // a sequential pull (after_version=1) must block until machine 1
        // pushes; do it from a thread.
        let h = std::thread::spawn(move || {
            let mut c = connect(addr);
            match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 1 }) {
                Msg::Value { value, .. } => value[0],
                other => panic!("{other:?}"),
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!h.is_finished(), "pull must wait for the round");
        let mut c1 = connect(addr);
        rpc(&mut c1, &Msg::Push { key: "w".into(), value: vec![2.0], machine: 1 });
        let got = h.join().unwrap();
        assert_eq!(got, -3.0); // w = 0 - 1*(1+2)
    }

    #[test]
    fn eventual_pull_returns_immediately() {
        let srv = PsServer::start(0, 2, ServerUpdater::default()).unwrap();
        let mut c = connect(srv.addr());
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![5.0] });
        rpc(&mut c, &Msg::Push { key: "w".into(), value: vec![1.0], machine: 0 });
        match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 0 }) {
            Msg::Value { value, version, .. } => {
                assert_eq!(value, vec![5.0]);
                assert_eq!(version, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_key_errors() {
        let srv = PsServer::start(0, 1, ServerUpdater::default()).unwrap();
        let mut c = connect(srv.addr());
        match rpc(&mut c, &Msg::Push { key: "nope".into(), value: vec![1.0], machine: 0 }) {
            Msg::Err { .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn barrier_releases_all_machines() {
        let srv = PsServer::start(0, 3, ServerUpdater::default()).unwrap();
        let addr = srv.addr();
        let hs: Vec<_> = (0..3u32)
            .map(|m| {
                std::thread::spawn(move || {
                    let mut c = connect(addr);
                    rpc(&mut c, &Msg::Barrier { id: 1, machine: m });
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn message_accounting() {
        let srv = PsServer::start(0, 1, ServerUpdater::default()).unwrap();
        let mut c = connect(srv.addr());
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![0.0; 100] });
        rpc(&mut c, &Msg::Push { key: "w".into(), value: vec![0.0; 100], machine: 0 });
        assert_eq!(srv.messages_received(), 2);
        assert_eq!(srv.bytes_received(), 800);
        // the same counters over the wire (harness observability)
        match rpc(&mut c, &Msg::Stats) {
            Msg::StatsReply { msgs, bytes } => {
                assert_eq!(msgs, 3, "init + push + stats itself");
                assert_eq!(bytes, 800);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bounded_delay_watermark_is_served_without_full_round() {
        // 2 machines; only machine 0 has pushed.  A pull at watermark
        // rounds-k = 0 (client-side bounded-delay relaxation) must be
        // served immediately with the pre-round weight, while the full
        // sequential watermark would park.
        let srv = PsServer::start(
            0,
            2,
            ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 },
        )
        .unwrap();
        let mut c = connect(srv.addr());
        rpc(&mut c, &Msg::Init { key: "w".into(), value: vec![3.0] });
        rpc(&mut c, &Msg::Push { key: "w".into(), value: vec![1.0], machine: 0 });
        match rpc(&mut c, &Msg::Pull { key: "w".into(), after_version: 0 }) {
            Msg::Value { value, version, .. } => {
                assert_eq!(value, vec![3.0]);
                assert_eq!(version, 0, "round incomplete: version unchanged");
            }
            other => panic!("{other:?}"),
        }
    }
}

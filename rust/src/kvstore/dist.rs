//! The two-level distributed KVStore client (paper §3.3, Figure 5).
//!
//! Each *machine* (process or thread group) owns one [`DistKVStore`]: a
//! level-1 aggregator for its local devices whose **merged** gradient is
//! forwarded to the level-2 [`PsServer`](super::server::PsServer) — one
//! message per round instead of one per device, the bandwidth reduction
//! the paper credits to the two-level structure.
//!
//! Network I/O runs inside engine operations, so pushes and pulls overlap
//! with compute exactly like any other scheduled op (§3.3: *"the strategy
//! ... makes the data synchronization work seamless with computation"*).
//!
//! Fault tolerance: every RPC runs under a deadline and a retry loop with
//! capped exponential backoff + jitter; a failed attempt tears the
//! connection down and redials, re-announcing the machine with `Hello`
//! (the `HelloAck` reply fast-forwards the local push-seq and barrier
//! counters above the server's floors, so a restarted worker process
//! rejoins cleanly instead of colliding with the dedup state its dead
//! incarnation left behind).
//! Retries are idempotent — pushes carry per-machine monotonic sequence
//! numbers and the server deduplicates, barriers are idempotent by
//! (id, machine), and pulls/inits are naturally re-executable.  Errors
//! inside engine-scheduled ops are captured in a slot and surface from
//! the next store call instead of being silently dropped.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::fault::{inject_send, FaultPlan};
use super::wire::{read_msg, write_msg, Msg};
use super::{lock, Consistency, KVStore, PartStage};
use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::ndarray::NDArray;
use crate::util::Rng;

/// Timeout / retry / heartbeat knobs for [`DistKVStore`].
#[derive(Debug, Clone, Copy)]
pub struct RetryCfg {
    /// TCP connect deadline.
    pub connect_timeout: Duration,
    /// Read/write deadline for ordinary RPCs (init, push, stats).
    pub op_timeout: Duration,
    /// Read deadline for RPCs that legitimately park on the server
    /// (sequential pulls, barriers) — must exceed the longest stall a
    /// healthy run can produce.
    pub park_timeout: Duration,
    /// Retry attempts after the first failure before giving up.
    pub max_retries: u32,
    /// First backoff step; doubles per attempt.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Lease keep-alive interval (`None` = no heartbeat thread).
    pub heartbeat: Option<Duration>,
}

impl Default for RetryCfg {
    fn default() -> Self {
        RetryCfg {
            connect_timeout: Duration::from_millis(3000),
            op_timeout: Duration::from_millis(10_000),
            park_timeout: Duration::from_millis(60_000),
            max_retries: 4,
            backoff_base: Duration::from_millis(20),
            backoff_cap: Duration::from_millis(1000),
            heartbeat: None,
        }
    }
}

impl RetryCfg {
    /// Defaults overridden by `PALLAS_KV_*` environment knobs:
    /// `PALLAS_KV_CONNECT_TIMEOUT_MS`, `PALLAS_KV_TIMEOUT_MS`,
    /// `PALLAS_KV_PARK_TIMEOUT_MS`, `PALLAS_KV_RETRIES`,
    /// `PALLAS_KV_BACKOFF_MS`, `PALLAS_KV_BACKOFF_CAP_MS`,
    /// `PALLAS_KV_HEARTBEAT_MS`.
    pub fn from_env() -> RetryCfg {
        fn envu(name: &str) -> Option<u64> {
            std::env::var(name).ok()?.trim().parse().ok()
        }
        let mut cfg = RetryCfg::default();
        if let Some(ms) = envu("PALLAS_KV_CONNECT_TIMEOUT_MS") {
            cfg.connect_timeout = Duration::from_millis(ms);
        }
        if let Some(ms) = envu("PALLAS_KV_TIMEOUT_MS") {
            cfg.op_timeout = Duration::from_millis(ms);
        }
        if let Some(ms) = envu("PALLAS_KV_PARK_TIMEOUT_MS") {
            cfg.park_timeout = Duration::from_millis(ms);
        }
        if let Some(n) = envu("PALLAS_KV_RETRIES") {
            cfg.max_retries = n as u32;
        }
        if let Some(ms) = envu("PALLAS_KV_BACKOFF_MS") {
            cfg.backoff_base = Duration::from_millis(ms);
        }
        if let Some(ms) = envu("PALLAS_KV_BACKOFF_CAP_MS") {
            cfg.backoff_cap = Duration::from_millis(ms);
        }
        if let Some(ms) = envu("PALLAS_KV_HEARTBEAT_MS") {
            cfg.heartbeat = Some(Duration::from_millis(ms));
        }
        cfg
    }
}

/// Client-side transport counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// RPC attempts repeated after a transport failure.
    pub retries: u64,
    /// Connections re-established after the first dial.
    pub reconnects: u64,
}

/// Server-side counters fetched over the wire (see `Msg::StatsReply`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServerStats {
    /// Data-plane messages received.
    pub msgs: u64,
    /// Payload bytes received.
    pub bytes: u64,
    /// Retransmissions recognized and dropped.
    pub dedup_hits: u64,
    /// Machine leases expired.
    pub lease_expiries: u64,
    /// Optimizer rounds applied.
    pub applies: u64,
}

/// Last fetched weight per key (version-stamped): within one round every
/// device pulls the same watermark, so only the first pull pays an RPC
/// — the rest copy from this cache (the distributed analogue of
/// `LocalKVStore`'s version-stamped pulls).  Sequential and
/// bounded-delay only; eventual pulls always refetch for freshness.
struct PullCache {
    /// Server version of the cached bytes (`u64::MAX` = empty).
    version: u64,
    data: Vec<f32>,
}

struct KeyState {
    /// Level-1 accumulation buffer (legacy arrival-order path).
    accum: NDArray,
    pushed: usize,
    /// Device-sliced staging for the current round (`push_part` path).
    stage: PartStage,
    /// Number of completed level-2 push rounds (the pull watermark).
    rounds: u64,
    shape: Vec<usize>,
    cache: Arc<Mutex<PullCache>>,
}

/// Does `reply` pair with `req`?  A mismatch means the stream desynced
/// — the connection is torn down and the RPC retried rather than
/// mis-paired.  Desync from duplicated request frames is prevented at
/// the source: `inject_send` reports how many copies it wrote and
/// `try_rpc` drains one reply per copy.  `Err` is accepted as a reply to
/// any request (the server can answer anything with it), but `try_rpc`
/// tears the connection down before surfacing it so a queued stale `Err`
/// can never be attributed to a later RPC on the same stream.
fn reply_matches(req: &Msg, reply: &Msg) -> bool {
    if matches!(reply, Msg::Err { .. }) {
        return true;
    }
    match req {
        // Key equality matters: a duplicated Pull leaves an extra Value
        // in the socket that must not satisfy a later Pull for another
        // key.
        Msg::Pull { key, .. } => matches!(reply, Msg::Value { key: k, .. } if k == key),
        Msg::Stats => matches!(reply, Msg::StatsReply { .. }),
        _ => matches!(reply, Msg::Ack),
    }
}

/// One client connection with reconnect + retry.
struct Conn {
    addr: std::net::SocketAddr,
    cfg: RetryCfg,
    plan: Option<Arc<FaultPlan>>,
    /// Machine id announced with `Hello` on every (re)dial — registers
    /// the lease and folds a previously-expired machine back in.
    hello: Option<u32>,
    /// The store's push-seq counter, fast-forwarded from the `HelloAck`
    /// floor on every dial so a restarted process never reuses sequence
    /// numbers the server already dedups on.
    seq: Arc<AtomicU64>,
    /// The store's barrier-id counter, fast-forwarded likewise so a
    /// restarted process does not re-issue already-released barrier ids
    /// (which would ack without synchronizing).
    barrier: Arc<AtomicU64>,
    stream: Mutex<Option<TcpStream>>,
    jitter: Mutex<Rng>,
    retries: Arc<AtomicU64>,
    reconnects: Arc<AtomicU64>,
    ever_connected: AtomicBool,
}

impl Conn {
    fn new(
        addr: std::net::SocketAddr,
        cfg: RetryCfg,
        plan: Option<Arc<FaultPlan>>,
        hello: Option<u32>,
        seq: Arc<AtomicU64>,
        barrier: Arc<AtomicU64>,
        retries: Arc<AtomicU64>,
        reconnects: Arc<AtomicU64>,
    ) -> Conn {
        let seed = 0xbac0_0ff ^ u64::from(hello.unwrap_or(0));
        Conn {
            addr,
            cfg,
            plan,
            hello,
            seq,
            barrier,
            stream: Mutex::new(None),
            jitter: Mutex::new(Rng::seed_from_u64(seed)),
            retries,
            reconnects,
            ever_connected: AtomicBool::new(false),
        }
    }

    /// Dial the server (with deadline), announce the machine, and store
    /// the stream into `slot`.
    fn dial(&self, slot: &mut Option<TcpStream>) -> Result<()> {
        let mut s = TcpStream::connect_timeout(&self.addr, self.cfg.connect_timeout)?;
        s.set_nodelay(true).ok();
        if self.ever_connected.swap(true, Ordering::Relaxed) {
            self.reconnects.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(machine) = self.hello {
            // Registration is sent clean (never through the fault plan):
            // it models the OS-level connect handshake, and injecting
            // here would make every redial a coin flip.
            s.set_write_timeout(Some(self.cfg.op_timeout)).ok();
            s.set_read_timeout(Some(self.cfg.op_timeout)).ok();
            write_msg(&mut s, &Msg::Hello { machine })?;
            match read_msg(&mut s)? {
                Msg::HelloAck { seq, barrier } => {
                    // Resume counters above the server's floors.  On a
                    // live redial these are no-ops (our counters are
                    // already past them); on a process restart they jump
                    // the fresh counters past the dead incarnation's.
                    self.seq.fetch_max(seq, Ordering::Relaxed);
                    self.barrier.fetch_max(barrier, Ordering::Relaxed);
                }
                other => return Err(Error::kv(format!("hello: unexpected reply {other:?}"))),
            }
        }
        *slot = Some(s);
        Ok(())
    }

    /// Eagerly establish the connection (used at construction so a bad
    /// address fails fast).
    fn ensure_connected(&self) -> Result<()> {
        let mut slot = lock(&self.stream);
        if slot.is_none() {
            self.dial(&mut slot)?;
        }
        Ok(())
    }

    /// One attempt: send through the fault layer, then read one reply
    /// per frame copy actually written (a duplicated request is answered
    /// twice — draining the extra reply keeps the stream in sync, so no
    /// stale reply can be mis-paired with a later RPC).  Any failure
    /// poisons the stream so the next attempt redials.
    fn try_rpc(&self, msg: &Msg, deadline: Duration) -> Result<Msg> {
        let mut slot = lock(&self.stream);
        if slot.is_none() {
            self.dial(&mut slot)?;
        }
        let s = slot.as_mut().ok_or_else(|| Error::kv("not connected"))?;
        s.set_write_timeout(Some(self.cfg.op_timeout)).ok();
        s.set_read_timeout(Some(deadline)).ok();
        let copies = match &self.plan {
            Some(p) => inject_send(s, msg, p, true),
            None => write_msg(s, msg).map(|()| 1),
        };
        let copies = match copies {
            Ok(n) => n,
            Err(e) => {
                *slot = None;
                return Err(e);
            }
        };
        // A dropped frame (0 copies) still reads once: the read times
        // out, the stream is torn down, and the retry loop redials.
        let mut reply = read_msg(s);
        for _ in 1..copies {
            if reply.is_err() {
                break;
            }
            reply = read_msg(s); // drain the duplicate's reply; keep the last
        }
        match reply {
            Ok(reply) if reply_matches(msg, &reply) => {
                if matches!(reply, Msg::Err { .. }) {
                    // Semantic error: surface it, but start the next RPC
                    // on a fresh stream so a desynced/stale Err can never
                    // leak into a later request's reply slot.
                    *slot = None;
                }
                Ok(reply)
            }
            Ok(reply) => {
                *slot = None;
                Err(Error::kv(format!("desynced reply {reply:?} to {msg:?}")))
            }
            Err(e) => {
                *slot = None;
                Err(e)
            }
        }
    }

    /// RPC with retry: transport failures redial with capped exponential
    /// backoff + jitter; a server `Err` reply is semantic and terminal.
    /// The whole retry loop is one client-RPC span (`a` = attempts
    /// taken, so redials show up as long spans with `a > 1`).
    fn rpc_deadline(&self, msg: &Msg, deadline: Duration) -> Result<Msg> {
        let prof = crate::profile::SpanTimer::start();
        let mut attempt = 0u32;
        let out = loop {
            match self.try_rpc(msg, deadline) {
                Ok(Msg::Err { msg }) => break Err(Error::kv(format!("server: {msg}"))),
                Ok(reply) => break Ok(reply),
                Err(e) => {
                    attempt += 1;
                    if attempt > self.cfg.max_retries {
                        break Err(Error::kv(format!(
                            "rpc failed after {attempt} attempt(s): {e}"
                        )));
                    }
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    let base = self
                        .cfg
                        .backoff_base
                        .saturating_mul(1u32 << (attempt - 1).min(16))
                        .min(self.cfg.backoff_cap);
                    let jitter_ms = {
                        let mut r = lock(&self.jitter);
                        let half = (base.as_millis() as u64 / 2).max(1);
                        r.next_u64() % half
                    };
                    std::thread::sleep(base + Duration::from_millis(jitter_ms));
                }
            }
        };
        let name = rpc_span_name(msg);
        prof.finish(crate::profile::Category::KvClient, name, 0, u64::from(attempt) + 1, 0);
        out
    }

    /// Ordinary RPC (short deadline).
    fn rpc(&self, msg: &Msg) -> Result<Msg> {
        self.rpc_deadline(msg, self.cfg.op_timeout)
    }

    /// RPC that may legitimately park on the server (long deadline).
    fn rpc_park(&self, msg: &Msg) -> Result<Msg> {
        self.rpc_deadline(msg, self.cfg.park_timeout)
    }
}

/// Trace-span name for a client RPC, by request kind.
fn rpc_span_name(msg: &Msg) -> &'static str {
    match msg {
        Msg::Init { .. } => "kv.rpc.init",
        Msg::Push { .. } => "kv.rpc.push",
        Msg::Pull { .. } => "kv.rpc.pull",
        Msg::Barrier { .. } => "kv.rpc.barrier",
        Msg::Stats => "kv.rpc.stats",
        Msg::Hello { .. } => "kv.rpc.hello",
        Msg::Heartbeat { .. } => "kv.rpc.heartbeat",
        Msg::Shutdown => "kv.rpc.shutdown",
        _ => "kv.rpc.other",
    }
}

/// Client-side two-level KVStore.
pub struct DistKVStore {
    engine: EngineRef,
    machine: u32,
    num_devices: usize,
    /// Factor applied to the level-1 merged gradient before it is
    /// shipped (see [`DistKVStore::with_grad_rescale`]).
    grad_rescale: f32,
    consistency: Consistency,
    keys: Mutex<HashMap<String, KeyState>>,
    /// Connection used by engine ops (push/pull).
    conn: Arc<Conn>,
    /// Separate connection for barriers so a parked barrier cannot block
    /// in-flight pull replies.
    barrier_conn: Arc<Conn>,
    /// Barrier-id counter (shared with the connections so `HelloAck` can
    /// fast-forward it past already-released generations on redial).
    barrier_round: Arc<AtomicU64>,
    /// Per-machine monotonic sequence number stamped on every level-2
    /// push (the server's dedup key for retried frames); shared with the
    /// connections so `HelloAck` can fast-forward it above the server's
    /// floor when this process is a restart of a dead worker.
    seq: Arc<AtomicU64>,
    /// First error raised inside an engine-scheduled push/pull op; taken
    /// and returned by the next public store call.
    async_err: Arc<Mutex<Option<Error>>>,
    retries: Arc<AtomicU64>,
    reconnects: Arc<AtomicU64>,
    hb_stop: Arc<AtomicBool>,
    hb_thread: Option<JoinHandle<()>>,
    /// Engine tag owning the wire connection: every push/pull engine op
    /// *writes* it, so network ops execute in issue order.  Without this
    /// a later pull (which the server may park until the round completes)
    /// could run before the push that completes the round — holding the
    /// connection mutex and deadlocking the machine against itself.
    conn_var: crate::engine::VarHandle,
}

impl DistKVStore {
    /// Connect to the level-2 server with retry/fault behavior from the
    /// environment (see [`RetryCfg::from_env`] and
    /// [`FaultPlan::from_env`]).
    pub fn connect(
        addr: std::net::SocketAddr,
        machine: u32,
        num_devices: usize,
        consistency: Consistency,
        engine: EngineRef,
    ) -> Result<DistKVStore> {
        DistKVStore::connect_with(
            addr,
            machine,
            num_devices,
            consistency,
            engine,
            RetryCfg::from_env(),
            FaultPlan::from_env(),
        )
    }

    /// [`DistKVStore::connect`] with explicit retry config and fault
    /// plan (the chaos-test entry point).
    pub fn connect_with(
        addr: std::net::SocketAddr,
        machine: u32,
        num_devices: usize,
        consistency: Consistency,
        engine: EngineRef,
        cfg: RetryCfg,
        plan: Option<Arc<FaultPlan>>,
    ) -> Result<DistKVStore> {
        let retries = Arc::new(AtomicU64::new(0));
        let reconnects = Arc::new(AtomicU64::new(0));
        let seq = Arc::new(AtomicU64::new(0));
        let barrier_round = Arc::new(AtomicU64::new(0));
        let conn = Arc::new(Conn::new(
            addr,
            cfg,
            plan.clone(),
            Some(machine),
            Arc::clone(&seq),
            Arc::clone(&barrier_round),
            Arc::clone(&retries),
            Arc::clone(&reconnects),
        ));
        // Barriers park by design; their connection is kept clean of
        // fault injection on dial (hello) but shares the plan for
        // request frames.
        let barrier_conn = Arc::new(Conn::new(
            addr,
            cfg,
            plan,
            Some(machine),
            Arc::clone(&seq),
            Arc::clone(&barrier_round),
            Arc::clone(&retries),
            Arc::clone(&reconnects),
        ));
        conn.ensure_connected()?;
        barrier_conn.ensure_connected()?;
        let hb_stop = Arc::new(AtomicBool::new(false));
        let hb_thread = cfg.heartbeat.map(|interval| {
            let stop = Arc::clone(&hb_stop);
            std::thread::Builder::new()
                .name("mixnet-kv-heartbeat".into())
                .spawn(move || heartbeat_loop(addr, machine, interval, stop))
                .ok()
        });
        let conn_var = engine.new_var();
        Ok(DistKVStore {
            engine,
            machine,
            num_devices: num_devices.max(1),
            grad_rescale: 1.0,
            consistency,
            keys: Mutex::new(HashMap::new()),
            conn,
            barrier_conn,
            barrier_round,
            seq,
            async_err: Arc::new(Mutex::new(None)),
            retries,
            reconnects,
            hb_stop,
            hb_thread: hb_thread.flatten(),
            conn_var,
        })
    }

    /// Scale the level-1 merged gradient by `f` before shipping it.
    ///
    /// The merge is a *sum* over the machine's device shards; with
    /// mean-normalized per-shard gradients that sum is `devices x` the
    /// global-batch mean, so a data-parallel worker passes
    /// `1.0 / devices` to keep the server-side learning rate meaningful
    /// independent of the local device count (the local trainer achieves
    /// the same via its updater's `rescale`).
    pub fn with_grad_rescale(mut self, f: f32) -> Self {
        self.grad_rescale = f;
        self
    }

    /// The server's receive/dedup/lease counters — harness observability
    /// (uses the barrier connection: a plain synchronous RPC that must
    /// not interleave with engine-scheduled push/pull frames on the main
    /// connection).
    pub fn server_stats(&self) -> Result<ServerStats> {
        match self.barrier_conn.rpc(&Msg::Stats)? {
            Msg::StatsReply { msgs, bytes, dedup_hits, lease_expiries, applies } => {
                Ok(ServerStats { msgs, bytes, dedup_hits, lease_expiries, applies })
            }
            other => Err(Error::kv(format!("stats: unexpected reply {other:?}"))),
        }
    }

    /// Client-side retry/reconnect counters.
    pub fn client_stats(&self) -> ClientStats {
        ClientStats {
            retries: self.retries.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
        }
    }

    /// Surface (and clear) the first error captured inside an
    /// engine-scheduled push/pull op.
    fn take_async_err(&self) -> Result<()> {
        match lock(&self.async_err).take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Epoch barrier across machines (monotonic id; retransmissions
    /// after a lost ack are idempotent server-side, and a restarted
    /// process resumes ids above the server's released floor).
    pub fn barrier(&self) -> Result<()> {
        self.take_async_err()?;
        let id = self.barrier_round.fetch_add(1, Ordering::Relaxed) + 1;
        match self.barrier_conn.rpc_park(&Msg::Barrier { id, machine: self.machine })? {
            Msg::Ack => Ok(()),
            other => Err(Error::kv(format!("barrier: unexpected reply {other:?}"))),
        }
    }
}

impl Drop for DistKVStore {
    fn drop(&mut self) {
        self.hb_stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.hb_thread.take() {
            let _ = t.join();
        }
    }
}

/// Lease keep-alive loop: its own connection (never fault-injected, so
/// injected chaos on the data path cannot spuriously expire a live
/// machine), reconnecting on failure at heartbeat cadence.
fn heartbeat_loop(
    addr: std::net::SocketAddr,
    machine: u32,
    interval: Duration,
    stop: Arc<AtomicBool>,
) {
    let mut stream: Option<TcpStream> = None;
    let mut elapsed = Duration::ZERO;
    let tick = Duration::from_millis(10);
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        elapsed += tick;
        if elapsed < interval {
            continue;
        }
        elapsed = Duration::ZERO;
        if stream.is_none() {
            if let Ok(s) = TcpStream::connect_timeout(&addr, interval) {
                s.set_nodelay(true).ok();
                s.set_read_timeout(Some(interval)).ok();
                s.set_write_timeout(Some(interval)).ok();
                stream = Some(s);
            } else {
                continue;
            }
        }
        if let Some(s) = stream.as_mut() {
            let ok = write_msg(s, &Msg::Heartbeat { machine })
                .and_then(|_| read_msg(s))
                .is_ok();
            if !ok {
                stream = None;
            }
        }
    }
}

impl KVStore for DistKVStore {
    fn init(&self, key: &str, value: &NDArray) -> Result<()> {
        self.take_async_err()?;
        {
            let mut keys = lock(&self.keys);
            if keys.contains_key(key) {
                return Err(Error::kv(format!("key '{key}' already initialized")));
            }
            keys.insert(
                key.to_string(),
                KeyState {
                    accum: NDArray::zeros_on(value.shape(), self.engine.clone()),
                    pushed: 0,
                    stage: PartStage::new(self.num_devices),
                    rounds: 0,
                    shape: value.shape().to_vec(),
                    cache: Arc::new(Mutex::new(PullCache {
                        version: u64::MAX,
                        data: Vec::new(),
                    })),
                },
            );
        }
        // Synchronous init (first writer wins on the server).
        match self.conn.rpc(&Msg::Init { key: key.to_string(), value: value.to_vec() })? {
            Msg::Ack => Ok(()),
            other => Err(Error::kv(format!("init: unexpected reply {other:?}"))),
        }
    }

    fn push(&self, key: &str, grad: &NDArray, _device: usize) -> Result<()> {
        self.take_async_err()?;
        let mut keys = lock(&self.keys);
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        if st.stage.in_progress() {
            return Err(Error::kv(format!("key '{key}': round mixes push and push_part")));
        }
        if st.pushed == 0 {
            st.accum.zero_();
        }
        st.accum.add_(grad); // level-1 aggregation (engine op)
        st.pushed += 1;
        if st.pushed == self.num_devices {
            st.pushed = 0;
            st.rounds += 1;
            // level-2: ship ONE aggregated message, inside an engine op
            // reading the accumulation buffer.
            let conn = Arc::clone(&self.conn);
            let err_slot = Arc::clone(&self.async_err);
            let key = key.to_string();
            let machine = self.machine;
            let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
            let rescale = self.grad_rescale;
            let accum = st.accum.clone();
            let storage = accum.storage();
            self.engine.push(
                "kv.dist_push",
                vec![accum.var()],
                vec![self.conn_var],
                Box::new(move || {
                    let mut value = unsafe { storage.slice() }.to_vec();
                    if rescale != 1.0 {
                        for v in value.iter_mut() {
                            *v *= rescale;
                        }
                    }
                    if let Err(e) = conn.rpc(&Msg::Push { key, value, machine, seq }) {
                        let mut g = lock(&err_slot);
                        g.get_or_insert(e);
                    }
                }),
            );
        }
        Ok(())
    }

    fn push_part(&self, key: &str, grad: &[f32], part: usize) -> Result<()> {
        self.take_async_err()?;
        let mut keys = lock(&self.keys);
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        if st.pushed > 0 {
            return Err(Error::kv(format!("key '{key}': round mixes push and push_part")));
        }
        let n: usize = st.shape.iter().product();
        let parts = match st.stage.stage(key, grad, part, n)? {
            None => return Ok(()),
            Some(parts) => parts,
        };
        st.rounds += 1;
        // Round complete: ship ONE aggregated message, reduced in part
        // order inside the wire op (writes only the connection var, so
        // the transfer overlaps whatever backward is still running —
        // there is no dependency on any gradient var).
        let conn = Arc::clone(&self.conn);
        let err_slot = Arc::clone(&self.async_err);
        let key = key.to_string();
        let machine = self.machine;
        let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let rescale = self.grad_rescale;
        self.engine.push(
            "kv.dist_push_parts",
            vec![],
            vec![self.conn_var],
            Box::new(move || {
                let mut value: Vec<f32> = Vec::new();
                for (i, part) in parts.into_iter().enumerate() {
                    if i == 0 {
                        value = part.to_vec();
                    } else {
                        for (d, s) in value.iter_mut().zip(part.iter()) {
                            *d += *s;
                        }
                    }
                    crate::ndarray::pool::global().release(part);
                }
                if rescale != 1.0 {
                    for v in value.iter_mut() {
                        *v *= rescale;
                    }
                }
                if let Err(e) = conn.rpc(&Msg::Push { key, value, machine, seq }) {
                    let mut g = lock(&err_slot);
                    g.get_or_insert(e);
                }
            }),
        );
        Ok(())
    }

    fn pull(&self, key: &str, out: &NDArray, _device: usize) -> Result<()> {
        self.take_async_err()?;
        let (after_version, shape, cache) = {
            let keys = lock(&self.keys);
            let st = keys.get(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
            let v = match self.consistency {
                Consistency::Sequential => st.rounds,
                // Staleness ceiling: the server parks the pull until its
                // version reaches `rounds - k` — the level-2 analogue of
                // the local store's snapshot wait (server.rs watermark).
                Consistency::BoundedDelay(k) => st.rounds.saturating_sub(k),
                Consistency::Eventual => 0,
            };
            (v, st.shape.clone(), Arc::clone(&st.cache))
        };
        if out.shape() != shape.as_slice() {
            return Err(Error::kv(format!(
                "pull '{key}': out shape {:?} != {:?}",
                out.shape(),
                shape
            )));
        }
        // Sequential / bounded-delay pulls within one round all wait on
        // the same watermark: serve repeats (other devices' pulls of
        // this round) from the version-stamped cache when the cached
        // server version already satisfies the watermark, so only one
        // RPC crosses the wire per (key, round).  Eventual pulls always
        // refetch — their whole point is best-effort freshness.
        let use_cache = self.consistency != Consistency::Eventual;
        let conn = Arc::clone(&self.conn);
        let err_slot = Arc::clone(&self.async_err);
        let key = key.to_string();
        let storage = out.storage();
        self.engine.push(
            "kv.dist_pull",
            vec![],
            vec![out.var(), self.conn_var],
            Box::new(move || {
                if use_cache {
                    let c = lock(&cache);
                    if c.version != u64::MAX
                        && c.version >= after_version
                        && c.data.len() == storage.len()
                    {
                        unsafe { storage.slice_mut() }.copy_from_slice(&c.data);
                        return;
                    }
                }
                match conn.rpc_park(&Msg::Pull { key: key.clone(), after_version }) {
                    Ok(Msg::Value { value, version, .. }) => {
                        let dst = unsafe { storage.slice_mut() };
                        if dst.len() == value.len() {
                            dst.copy_from_slice(&value);
                            if use_cache {
                                let mut c = lock(&cache);
                                c.version = version;
                                c.data = value;
                            }
                        } else {
                            let mut g = lock(&err_slot);
                            g.get_or_insert(Error::kv(format!(
                                "pull '{key}': got {} values, expected {}",
                                value.len(),
                                dst.len()
                            )));
                        }
                    }
                    Ok(other) => {
                        let mut g = lock(&err_slot);
                        g.get_or_insert(Error::kv(format!(
                            "pull '{key}': unexpected reply {other:?}"
                        )));
                    }
                    Err(e) => {
                        // Connection failure after retries: leave the
                        // buffer untouched and surface the error.
                        let mut g = lock(&err_slot);
                        g.get_or_insert(e);
                    }
                }
            }),
        );
        Ok(())
    }

    fn flush(&self) {
        self.engine.wait_all();
    }

    fn num_devices(&self) -> usize {
        self.num_devices
    }

    fn consistency(&self) -> Consistency {
        self.consistency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::kvstore::server::{PsServer, ServerUpdater};

    fn plain_updater() -> ServerUpdater {
        ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 }
    }

    #[test]
    fn single_machine_push_pull() {
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 1, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::from_vec_on(&[2], vec![1.0, 1.0], engine.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[2], vec![0.25, 0.5], engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[2], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.75, 0.5]);
    }

    #[test]
    fn level1_aggregation_reduces_messages() {
        // 4 local devices, 1 machine: the server must see ONE push per
        // round (plus the init).
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 4, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::zeros_on(&[8], engine.clone())).unwrap();
        for d in 0..4 {
            kv.push("w", &NDArray::from_vec_on(&[8], vec![1.0; 8], engine.clone()), d).unwrap();
        }
        let out = NDArray::zeros_on(&[8], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // w = 0 - (1+1+1+1) = -4 everywhere
        assert_eq!(out.to_vec(), vec![-4.0; 8]);
        // messages: 1 init + 1 aggregated push + 1 pull = 3
        assert_eq!(srv.messages_received(), 3, "level-1 must aggregate");
    }

    #[test]
    fn two_machines_synchronous_round() {
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let addr = srv.addr();
        let handles: Vec<_> = (0..2u32)
            .map(|m| {
                std::thread::spawn(move || {
                    let engine = create(EngineKind::Threaded, 2);
                    let kv = DistKVStore::connect(
                        addr,
                        m,
                        1,
                        Consistency::Sequential,
                        engine.clone(),
                    )
                    .unwrap();
                    kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
                    kv.push(
                        "w",
                        &NDArray::from_vec_on(&[1], vec![(m + 1) as f32], engine.clone()),
                        0,
                    )
                    .unwrap();
                    let out = NDArray::zeros_on(&[1], engine.clone());
                    kv.pull("w", &out, 0).unwrap();
                    kv.flush();
                    out.to_vec()[0]
                })
            })
            .collect();
        for h in handles {
            // w = 0 - (1 + 2) = -3 for both machines
            assert_eq!(h.join().unwrap(), -3.0);
        }
    }

    #[test]
    fn staged_parts_ship_one_aggregated_message() {
        // push_part deliveries in any order: one wire message per round,
        // reduced in part order.
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 3, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::zeros_on(&[2], engine.clone())).unwrap();
        for part in [2usize, 0, 1] {
            kv.push_part("w", &[part as f32, 1.0], part).unwrap();
        }
        let out = NDArray::zeros_on(&[2], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // lr=1: w = 0 - (0+1+2) and 0 - (1+1+1)
        assert_eq!(out.to_vec(), vec![-3.0, -3.0]);
        assert_eq!(srv.messages_received(), 3, "init + 1 aggregated push + pull");
    }

    #[test]
    fn grad_rescale_scales_the_wire_message() {
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 2, Consistency::Sequential, engine.clone())
                .unwrap()
                .with_grad_rescale(0.5);
        kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
        kv.push_part("w", &[3.0], 0).unwrap();
        kv.push_part("w", &[5.0], 1).unwrap();
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // lr=1: w = 0 - 0.5 * (3 + 5)
        assert_eq!(out.to_vec(), vec![-4.0]);
    }

    #[test]
    fn bounded_delay_pull_relaxes_the_watermark() {
        // 2 machines expected; only this machine pushes.  A sequential
        // pull would park on the incomplete round; BoundedDelay(1)
        // relaxes the watermark to rounds-1 = 0 and returns the last
        // committed weight immediately — staleness <= 1 round.
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv = DistKVStore::connect(
            srv.addr(),
            0,
            1,
            Consistency::BoundedDelay(1),
            engine.clone(),
        )
        .unwrap();
        kv.init("w", &NDArray::from_vec_on(&[1], vec![6.0], engine.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush(); // must NOT deadlock despite the incomplete round
        assert_eq!(out.to_vec(), vec![6.0]);
        let stats = kv.server_stats().unwrap();
        assert!(stats.msgs >= 3, "init + push + pull crossed the wire");
    }

    #[test]
    fn eventual_pull_is_stale_but_fast() {
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 1, Consistency::Eventual, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::from_vec_on(&[1], vec![9.0], engine.clone())).unwrap();
        // push once: round incomplete at the server (2 machines expected)
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush(); // must NOT deadlock despite the incomplete round
        assert_eq!(out.to_vec(), vec![9.0]);
    }

    #[test]
    fn barrier_synchronizes_machines() {
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let addr = srv.addr();
        let t0 = std::time::Instant::now();
        let hs: Vec<_> = (0..2u32)
            .map(|m| {
                std::thread::spawn(move || {
                    let engine = create(EngineKind::Threaded, 2);
                    let kv =
                        DistKVStore::connect(addr, m, 1, Consistency::Sequential, engine)
                            .unwrap();
                    if m == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(60));
                    }
                    kv.barrier().unwrap();
                    t0.elapsed()
                })
            })
            .collect();
        let times: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        // both exit the barrier only after the slow machine arrives
        for t in times {
            assert!(t >= std::time::Duration::from_millis(55), "{t:?}");
        }
    }

    #[test]
    fn pull_shape_mismatch_rejected() {
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 1, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::zeros_on(&[4], engine.clone())).unwrap();
        let bad = NDArray::zeros_on(&[5], engine);
        assert!(kv.pull("w", &bad, 0).is_err());
    }

    /// With no server, connect must fail fast (bounded by the connect
    /// timeout), not hang.
    #[test]
    fn connect_fails_fast_without_server() {
        let engine = create(EngineKind::Threaded, 2);
        let cfg = RetryCfg {
            connect_timeout: Duration::from_millis(200),
            ..RetryCfg::default()
        };
        let addr: std::net::SocketAddr = "127.0.0.1:9".parse().unwrap(); // discard port
        let t0 = std::time::Instant::now();
        let res = DistKVStore::connect_with(
            addr,
            0,
            1,
            Consistency::Sequential,
            engine,
            cfg,
            None,
        );
        assert!(res.is_err());
        assert!(t0.elapsed() < Duration::from_secs(5), "must not hang");
    }

    /// When the server dies mid-run, retries are exhausted and the error
    /// surfaces from the store instead of hanging or panicking.
    #[test]
    fn retries_exhaust_and_surface_error() {
        let mut srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let cfg = RetryCfg {
            connect_timeout: Duration::from_millis(200),
            op_timeout: Duration::from_millis(200),
            park_timeout: Duration::from_millis(200),
            max_retries: 2,
            backoff_base: Duration::from_millis(5),
            ..RetryCfg::default()
        };
        let kv = DistKVStore::connect_with(
            srv.addr(),
            0,
            1,
            Consistency::Sequential,
            engine.clone(),
            cfg,
            None,
        )
        .unwrap();
        kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
        srv.shutdown();
        drop(srv);
        let err = kv.barrier();
        assert!(err.is_err(), "barrier against a dead server must error");
        assert!(kv.client_stats().retries > 0, "the client must have retried first");
    }
}

//! The two-level distributed KVStore client (paper §3.3, Figure 5).
//!
//! Each *machine* (process or thread group) owns one [`DistKVStore`]: a
//! level-1 aggregator for its local devices whose **merged** gradient is
//! forwarded to the level-2 [`PsServer`](super::server::PsServer) — one
//! message per round instead of one per device, the bandwidth reduction
//! the paper credits to the two-level structure.
//!
//! Network I/O runs inside engine operations, so pushes and pulls overlap
//! with compute exactly like any other scheduled op (§3.3: *"the strategy
//! ... makes the data synchronization work seamless with computation"*).

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

use super::wire::{read_msg, write_msg, Msg};
use super::{Consistency, KVStore, PartStage};
use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::ndarray::NDArray;

/// Last fetched weight per key (version-stamped): within one round every
/// device pulls the same watermark, so only the first pull pays an RPC
/// — the rest copy from this cache (the distributed analogue of
/// `LocalKVStore`'s version-stamped pulls).  Sequential and
/// bounded-delay only; eventual pulls always refetch for freshness.
struct PullCache {
    /// Server version of the cached bytes (`u64::MAX` = empty).
    version: u64,
    data: Vec<f32>,
}

struct KeyState {
    /// Level-1 accumulation buffer (legacy arrival-order path).
    accum: NDArray,
    pushed: usize,
    /// Device-sliced staging for the current round (`push_part` path).
    stage: PartStage,
    /// Number of completed level-2 push rounds (the pull watermark).
    rounds: u64,
    shape: Vec<usize>,
    cache: Arc<Mutex<PullCache>>,
}

struct Conn {
    stream: Mutex<TcpStream>,
}

impl Conn {
    fn rpc(&self, msg: &Msg) -> Result<Msg> {
        let mut s = self.stream.lock().unwrap();
        write_msg(&mut *s, msg)?;
        read_msg(&mut *s)
    }
}

/// Client-side two-level KVStore.
pub struct DistKVStore {
    engine: EngineRef,
    machine: u32,
    num_devices: usize,
    /// Factor applied to the level-1 merged gradient before it is
    /// shipped (see [`DistKVStore::with_grad_rescale`]).
    grad_rescale: f32,
    consistency: Consistency,
    keys: Mutex<HashMap<String, KeyState>>,
    /// Connection used by engine ops (push/pull).
    conn: Arc<Conn>,
    /// Separate connection for barriers so a parked barrier cannot block
    /// in-flight pull replies.
    barrier_conn: Arc<Conn>,
    barrier_round: Mutex<u64>,
    /// Engine tag owning the wire connection: every push/pull engine op
    /// *writes* it, so network ops execute in issue order.  Without this
    /// a later pull (which the server may park until the round completes)
    /// could run before the push that completes the round — holding the
    /// connection mutex and deadlocking the machine against itself.
    conn_var: crate::engine::VarHandle,
}

impl DistKVStore {
    /// Connect to the level-2 server.
    pub fn connect(
        addr: std::net::SocketAddr,
        machine: u32,
        num_devices: usize,
        consistency: Consistency,
        engine: EngineRef,
    ) -> Result<DistKVStore> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let b = TcpStream::connect(addr)?;
        b.set_nodelay(true).ok();
        let conn_var = engine.new_var();
        Ok(DistKVStore {
            engine,
            machine,
            num_devices: num_devices.max(1),
            grad_rescale: 1.0,
            consistency,
            keys: Mutex::new(HashMap::new()),
            conn: Arc::new(Conn { stream: Mutex::new(stream) }),
            barrier_conn: Arc::new(Conn { stream: Mutex::new(b) }),
            barrier_round: Mutex::new(0),
            conn_var,
        })
    }

    /// Scale the level-1 merged gradient by `f` before shipping it.
    ///
    /// The merge is a *sum* over the machine's device shards; with
    /// mean-normalized per-shard gradients that sum is `devices x` the
    /// global-batch mean, so a data-parallel worker passes
    /// `1.0 / devices` to keep the server-side learning rate meaningful
    /// independent of the local device count (the local trainer achieves
    /// the same via its updater's `rescale`).
    pub fn with_grad_rescale(mut self, f: f32) -> Self {
        self.grad_rescale = f;
        self
    }

    /// The server's `(messages, bytes)` received counters — harness
    /// observability (uses the barrier connection: a plain synchronous
    /// RPC that must not interleave with engine-scheduled push/pull
    /// frames on the main connection).
    pub fn server_stats(&self) -> Result<(u64, u64)> {
        match self.barrier_conn.rpc(&Msg::Stats)? {
            Msg::StatsReply { msgs, bytes } => Ok((msgs, bytes)),
            other => Err(Error::kv(format!("stats: unexpected reply {other:?}"))),
        }
    }

    /// Epoch barrier across machines (round-robin id).
    pub fn barrier(&self) -> Result<()> {
        let id = {
            let mut r = self.barrier_round.lock().unwrap();
            *r += 1;
            *r
        };
        match self.barrier_conn.rpc(&Msg::Barrier { id, machine: self.machine })? {
            Msg::Ack => Ok(()),
            other => Err(Error::kv(format!("barrier: unexpected reply {other:?}"))),
        }
    }
}

impl KVStore for DistKVStore {
    fn init(&self, key: &str, value: &NDArray) -> Result<()> {
        {
            let mut keys = self.keys.lock().unwrap();
            if keys.contains_key(key) {
                return Err(Error::kv(format!("key '{key}' already initialized")));
            }
            keys.insert(
                key.to_string(),
                KeyState {
                    accum: NDArray::zeros_on(value.shape(), self.engine.clone()),
                    pushed: 0,
                    stage: PartStage::new(self.num_devices),
                    rounds: 0,
                    shape: value.shape().to_vec(),
                    cache: Arc::new(Mutex::new(PullCache {
                        version: u64::MAX,
                        data: Vec::new(),
                    })),
                },
            );
        }
        // Synchronous init (first writer wins on the server).
        match self.conn.rpc(&Msg::Init { key: key.to_string(), value: value.to_vec() })? {
            Msg::Ack => Ok(()),
            other => Err(Error::kv(format!("init: unexpected reply {other:?}"))),
        }
    }

    fn push(&self, key: &str, grad: &NDArray, _device: usize) -> Result<()> {
        let mut keys = self.keys.lock().unwrap();
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        if st.stage.in_progress() {
            return Err(Error::kv(format!("key '{key}': round mixes push and push_part")));
        }
        if st.pushed == 0 {
            st.accum.zero_();
        }
        st.accum.add_(grad); // level-1 aggregation (engine op)
        st.pushed += 1;
        if st.pushed == self.num_devices {
            st.pushed = 0;
            st.rounds += 1;
            // level-2: ship ONE aggregated message, inside an engine op
            // reading the accumulation buffer.
            let conn = Arc::clone(&self.conn);
            let key = key.to_string();
            let machine = self.machine;
            let rescale = self.grad_rescale;
            let accum = st.accum.clone();
            let storage = accum.storage();
            self.engine.push(
                "kv.dist_push",
                vec![accum.var()],
                vec![self.conn_var],
                Box::new(move || {
                    let mut value = unsafe { storage.slice() }.to_vec();
                    if rescale != 1.0 {
                        for v in value.iter_mut() {
                            *v *= rescale;
                        }
                    }
                    let _ = conn.rpc(&Msg::Push { key, value, machine });
                }),
            );
        }
        Ok(())
    }

    fn push_part(&self, key: &str, grad: &[f32], part: usize) -> Result<()> {
        let mut keys = self.keys.lock().unwrap();
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        if st.pushed > 0 {
            return Err(Error::kv(format!("key '{key}': round mixes push and push_part")));
        }
        let n: usize = st.shape.iter().product();
        let parts = match st.stage.stage(key, grad, part, n)? {
            None => return Ok(()),
            Some(parts) => parts,
        };
        st.rounds += 1;
        // Round complete: ship ONE aggregated message, reduced in part
        // order inside the wire op (writes only the connection var, so
        // the transfer overlaps whatever backward is still running —
        // there is no dependency on any gradient var).
        let conn = Arc::clone(&self.conn);
        let key = key.to_string();
        let machine = self.machine;
        let rescale = self.grad_rescale;
        self.engine.push(
            "kv.dist_push_parts",
            vec![],
            vec![self.conn_var],
            Box::new(move || {
                let mut value: Vec<f32> = Vec::new();
                for (i, part) in parts.into_iter().enumerate() {
                    if i == 0 {
                        value = part.to_vec();
                    } else {
                        for (d, s) in value.iter_mut().zip(part.iter()) {
                            *d += *s;
                        }
                    }
                    crate::ndarray::pool::global().release(part);
                }
                if rescale != 1.0 {
                    for v in value.iter_mut() {
                        *v *= rescale;
                    }
                }
                let _ = conn.rpc(&Msg::Push { key, value, machine });
            }),
        );
        Ok(())
    }

    fn pull(&self, key: &str, out: &NDArray, _device: usize) -> Result<()> {
        let (after_version, shape, cache) = {
            let keys = self.keys.lock().unwrap();
            let st =
                keys.get(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
            let v = match self.consistency {
                Consistency::Sequential => st.rounds,
                // Staleness ceiling: the server parks the pull until its
                // version reaches `rounds - k` — the level-2 analogue of
                // the local store's snapshot wait (server.rs watermark).
                Consistency::BoundedDelay(k) => st.rounds.saturating_sub(k),
                Consistency::Eventual => 0,
            };
            (v, st.shape.clone(), Arc::clone(&st.cache))
        };
        if out.shape() != shape.as_slice() {
            return Err(Error::kv(format!(
                "pull '{key}': out shape {:?} != {:?}",
                out.shape(),
                shape
            )));
        }
        // Sequential / bounded-delay pulls within one round all wait on
        // the same watermark: serve repeats (other devices' pulls of
        // this round) from the version-stamped cache when the cached
        // server version already satisfies the watermark, so only one
        // RPC crosses the wire per (key, round).  Eventual pulls always
        // refetch — their whole point is best-effort freshness.
        let use_cache = self.consistency != Consistency::Eventual;
        let conn = Arc::clone(&self.conn);
        let key = key.to_string();
        let storage = out.storage();
        self.engine.push(
            "kv.dist_pull",
            vec![],
            vec![out.var(), self.conn_var],
            Box::new(move || {
                if use_cache {
                    let c = cache.lock().unwrap();
                    if c.version != u64::MAX
                        && c.version >= after_version
                        && c.data.len() == storage.len()
                    {
                        unsafe { storage.slice_mut() }.copy_from_slice(&c.data);
                        return;
                    }
                }
                match conn.rpc(&Msg::Pull { key: key.clone(), after_version }) {
                    Ok(Msg::Value { value, version, .. }) => {
                        let dst = unsafe { storage.slice_mut() };
                        if dst.len() == value.len() {
                            dst.copy_from_slice(&value);
                            if use_cache {
                                let mut c = cache.lock().unwrap();
                                c.version = version;
                                c.data = value;
                            }
                        }
                    }
                    _ => { /* connection failure: leave buffer untouched */ }
                }
            }),
        );
        Ok(())
    }

    fn flush(&self) {
        self.engine.wait_all();
    }

    fn num_devices(&self) -> usize {
        self.num_devices
    }

    fn consistency(&self) -> Consistency {
        self.consistency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::kvstore::server::{PsServer, ServerUpdater};

    fn plain_updater() -> ServerUpdater {
        ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 }
    }

    #[test]
    fn single_machine_push_pull() {
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 1, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::from_vec_on(&[2], vec![1.0, 1.0], engine.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[2], vec![0.25, 0.5], engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[2], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.75, 0.5]);
    }

    #[test]
    fn level1_aggregation_reduces_messages() {
        // 4 local devices, 1 machine: the server must see ONE push per
        // round (plus the init).
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 4, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::zeros_on(&[8], engine.clone())).unwrap();
        for d in 0..4 {
            kv.push("w", &NDArray::from_vec_on(&[8], vec![1.0; 8], engine.clone()), d).unwrap();
        }
        let out = NDArray::zeros_on(&[8], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // w = 0 - (1+1+1+1) = -4 everywhere
        assert_eq!(out.to_vec(), vec![-4.0; 8]);
        // messages: 1 init + 1 aggregated push + 1 pull = 3
        assert_eq!(srv.messages_received(), 3, "level-1 must aggregate");
    }

    #[test]
    fn two_machines_synchronous_round() {
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let addr = srv.addr();
        let handles: Vec<_> = (0..2u32)
            .map(|m| {
                std::thread::spawn(move || {
                    let engine = create(EngineKind::Threaded, 2);
                    let kv = DistKVStore::connect(
                        addr,
                        m,
                        1,
                        Consistency::Sequential,
                        engine.clone(),
                    )
                    .unwrap();
                    kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
                    kv.push(
                        "w",
                        &NDArray::from_vec_on(&[1], vec![(m + 1) as f32], engine.clone()),
                        0,
                    )
                    .unwrap();
                    let out = NDArray::zeros_on(&[1], engine.clone());
                    kv.pull("w", &out, 0).unwrap();
                    kv.flush();
                    out.to_vec()[0]
                })
            })
            .collect();
        for h in handles {
            // w = 0 - (1 + 2) = -3 for both machines
            assert_eq!(h.join().unwrap(), -3.0);
        }
    }

    #[test]
    fn staged_parts_ship_one_aggregated_message() {
        // push_part deliveries in any order: one wire message per round,
        // reduced in part order.
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 3, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::zeros_on(&[2], engine.clone())).unwrap();
        for part in [2usize, 0, 1] {
            kv.push_part("w", &[part as f32, 1.0], part).unwrap();
        }
        let out = NDArray::zeros_on(&[2], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // lr=1: w = 0 - (0+1+2) and 0 - (1+1+1)
        assert_eq!(out.to_vec(), vec![-3.0, -3.0]);
        assert_eq!(srv.messages_received(), 3, "init + 1 aggregated push + pull");
    }

    #[test]
    fn grad_rescale_scales_the_wire_message() {
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 2, Consistency::Sequential, engine.clone())
                .unwrap()
                .with_grad_rescale(0.5);
        kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
        kv.push_part("w", &[3.0], 0).unwrap();
        kv.push_part("w", &[5.0], 1).unwrap();
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // lr=1: w = 0 - 0.5 * (3 + 5)
        assert_eq!(out.to_vec(), vec![-4.0]);
    }

    #[test]
    fn bounded_delay_pull_relaxes_the_watermark() {
        // 2 machines expected; only this machine pushes.  A sequential
        // pull would park on the incomplete round; BoundedDelay(1)
        // relaxes the watermark to rounds-1 = 0 and returns the last
        // committed weight immediately — staleness <= 1 round.
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv = DistKVStore::connect(
            srv.addr(),
            0,
            1,
            Consistency::BoundedDelay(1),
            engine.clone(),
        )
        .unwrap();
        kv.init("w", &NDArray::from_vec_on(&[1], vec![6.0], engine.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush(); // must NOT deadlock despite the incomplete round
        assert_eq!(out.to_vec(), vec![6.0]);
        let (msgs, _bytes) = kv.server_stats().unwrap();
        assert!(msgs >= 3, "init + push + pull crossed the wire");
    }

    #[test]
    fn eventual_pull_is_stale_but_fast() {
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 1, Consistency::Eventual, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::from_vec_on(&[1], vec![9.0], engine.clone())).unwrap();
        // push once: round incomplete at the server (2 machines expected)
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush(); // must NOT deadlock despite the incomplete round
        assert_eq!(out.to_vec(), vec![9.0]);
    }

    #[test]
    fn barrier_synchronizes_machines() {
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let addr = srv.addr();
        let t0 = std::time::Instant::now();
        let hs: Vec<_> = (0..2u32)
            .map(|m| {
                std::thread::spawn(move || {
                    let engine = create(EngineKind::Threaded, 2);
                    let kv =
                        DistKVStore::connect(addr, m, 1, Consistency::Sequential, engine)
                            .unwrap();
                    if m == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(60));
                    }
                    kv.barrier().unwrap();
                    t0.elapsed()
                })
            })
            .collect();
        let times: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        // both exit the barrier only after the slow machine arrives
        for t in times {
            assert!(t >= std::time::Duration::from_millis(55), "{t:?}");
        }
    }

    #[test]
    fn pull_shape_mismatch_rejected() {
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 1, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::zeros_on(&[4], engine.clone())).unwrap();
        let bad = NDArray::zeros_on(&[5], engine);
        assert!(kv.pull("w", &bad, 0).is_err());
    }
}

//! The two-level distributed KVStore client (paper §3.3, Figure 5),
//! sharded across N parameter-server processes (ISSUE 10).
//!
//! Each *machine* (process or thread group) owns one [`DistKVStore`]: a
//! level-1 aggregator for its local devices whose **merged** gradient is
//! forwarded to the level-2 [`PsServer`](super::server::PsServer) fleet —
//! one message per round *per shard* instead of one per device, the
//! bandwidth reduction the paper credits to the two-level structure.
//!
//! Sharding: a static [`ShardRouter`] maps every key to its home shard
//! (or, for oversized keys, to one contiguous sub-range per shard), and
//! the store holds one connection pair per shard.  Pushes, pulls, and
//! barriers fan out to the shards concurrently: each shard has its own
//! engine connection var, so the engine schedules cross-shard wire ops
//! independently while keeping per-shard round order.  All of the
//! fault-tolerance machinery below is **per shard** — each shard
//! connection has its own seq/barrier counters, retry/reconnect
//! counters, and (under chaos testing) its own forked fault plan, so a
//! retry storm on shard 1 cannot stall shard 0 and a killed shard under
//! the Degrade policy degrades only its own key range.
//!
//! Network I/O runs inside engine operations, so pushes and pulls overlap
//! with compute exactly like any other scheduled op (§3.3: *"the strategy
//! ... makes the data synchronization work seamless with computation"*).
//!
//! Fault tolerance: every RPC runs under a deadline and a retry loop with
//! capped exponential backoff + jitter; a failed attempt tears the
//! connection down and redials, re-announcing the machine with `Hello`
//! (the `HelloAck` reply fast-forwards the local push-seq and barrier
//! counters above the server's floors, so a restarted worker process
//! rejoins cleanly instead of colliding with the dedup state its dead
//! incarnation left behind).  The `HelloAck` also carries the server's
//! shard identity, so a client dialing a misconfigured address list
//! fails at connect instead of silently routing keys to the wrong shard.
//! Retries are idempotent — pushes carry per-machine monotonic sequence
//! numbers and the server deduplicates, barriers are idempotent by
//! (id, machine), and pulls/inits are naturally re-executable.  Errors
//! inside engine-scheduled ops are captured in a slot and surface from
//! the next public store call instead of being silently dropped.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::fault::{inject_send, FaultPlan};
use super::shard::{KeyPlacement, ShardRouter};
use super::wire::{read_msg, write_msg, Msg};
use super::{lock, Consistency, KVStore, PartStage};
use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::ndarray::NDArray;
use crate::util::Rng;

/// Timeout / retry / heartbeat knobs for [`DistKVStore`].
#[derive(Debug, Clone, Copy)]
pub struct RetryCfg {
    /// TCP connect deadline.
    pub connect_timeout: Duration,
    /// Read/write deadline for ordinary RPCs (init, push, stats).
    pub op_timeout: Duration,
    /// Read deadline for RPCs that legitimately park on the server
    /// (sequential pulls, barriers) — must exceed the longest stall a
    /// healthy run can produce.
    pub park_timeout: Duration,
    /// Retry attempts after the first failure before giving up.
    pub max_retries: u32,
    /// First backoff step; doubles per attempt.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Lease keep-alive interval (`None` = no heartbeat thread).
    pub heartbeat: Option<Duration>,
}

impl Default for RetryCfg {
    fn default() -> Self {
        RetryCfg {
            connect_timeout: Duration::from_millis(3000),
            op_timeout: Duration::from_millis(10_000),
            park_timeout: Duration::from_millis(60_000),
            max_retries: 4,
            backoff_base: Duration::from_millis(20),
            backoff_cap: Duration::from_millis(1000),
            heartbeat: None,
        }
    }
}

impl RetryCfg {
    /// Defaults overridden by `PALLAS_KV_*` environment knobs:
    /// `PALLAS_KV_CONNECT_TIMEOUT_MS`, `PALLAS_KV_TIMEOUT_MS`,
    /// `PALLAS_KV_PARK_TIMEOUT_MS`, `PALLAS_KV_RETRIES`,
    /// `PALLAS_KV_BACKOFF_MS`, `PALLAS_KV_BACKOFF_CAP_MS`,
    /// `PALLAS_KV_HEARTBEAT_MS`.
    pub fn from_env() -> RetryCfg {
        fn envu(name: &str) -> Option<u64> {
            std::env::var(name).ok()?.trim().parse().ok()
        }
        let mut cfg = RetryCfg::default();
        if let Some(ms) = envu("PALLAS_KV_CONNECT_TIMEOUT_MS") {
            cfg.connect_timeout = Duration::from_millis(ms);
        }
        if let Some(ms) = envu("PALLAS_KV_TIMEOUT_MS") {
            cfg.op_timeout = Duration::from_millis(ms);
        }
        if let Some(ms) = envu("PALLAS_KV_PARK_TIMEOUT_MS") {
            cfg.park_timeout = Duration::from_millis(ms);
        }
        if let Some(n) = envu("PALLAS_KV_RETRIES") {
            cfg.max_retries = n as u32;
        }
        if let Some(ms) = envu("PALLAS_KV_BACKOFF_MS") {
            cfg.backoff_base = Duration::from_millis(ms);
        }
        if let Some(ms) = envu("PALLAS_KV_BACKOFF_CAP_MS") {
            cfg.backoff_cap = Duration::from_millis(ms);
        }
        if let Some(ms) = envu("PALLAS_KV_HEARTBEAT_MS") {
            cfg.heartbeat = Some(Duration::from_millis(ms));
        }
        cfg
    }
}

/// Per-shard client transport counters (see [`ClientStats::shards`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Last heartbeat round-trip to this shard succeeded (always `true`
    /// when no heartbeat thread runs — liveness is then only probed by
    /// the data path itself).
    pub alive: bool,
    /// Successful heartbeat round-trips to this shard.
    pub heartbeats: u64,
    /// RPC attempts repeated after a transport failure, this shard only.
    pub retries: u64,
    /// Connections re-established after the first dial, this shard only.
    pub reconnects: u64,
}

/// Client-side transport counters: fleet-wide sums plus the per-shard
/// breakdown (so a retry storm is attributable to the shard causing it).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// RPC attempts repeated after a transport failure (all shards).
    pub retries: u64,
    /// Connections re-established after the first dial (all shards).
    pub reconnects: u64,
    /// Per-shard liveness/retry/reconnect counters, in shard order.
    pub shards: Vec<ShardStats>,
}

/// Server-side counters fetched over the wire (see `Msg::StatsReply`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServerStats {
    /// Data-plane messages received.
    pub msgs: u64,
    /// Payload bytes received.
    pub bytes: u64,
    /// Retransmissions recognized and dropped.
    pub dedup_hits: u64,
    /// Machine leases expired.
    pub lease_expiries: u64,
    /// Optimizer rounds applied.
    pub applies: u64,
}

impl ServerStats {
    fn add(&mut self, o: &ServerStats) {
        self.msgs += o.msgs;
        self.bytes += o.bytes;
        self.dedup_hits += o.dedup_hits;
        self.lease_expiries += o.lease_expiries;
        self.applies += o.applies;
    }
}

/// Last fetched weight per key (version-stamped): within one round every
/// device pulls the same watermark, so only the first pull pays an RPC
/// — the rest copy from this cache (the distributed analogue of
/// `LocalKVStore`'s version-stamped pulls).  For split keys the cache
/// holds the *assembled* full value at the minimum shard version.
/// Sequential and bounded-delay only; eventual pulls always refetch for
/// freshness.
struct PullCache {
    /// Server version of the cached bytes (`u64::MAX` = empty).
    version: u64,
    data: Vec<f32>,
}

struct KeyState {
    /// Level-1 accumulation buffer (legacy arrival-order path).
    accum: NDArray,
    pushed: usize,
    /// Device-sliced staging for the current round (`push_part` path).
    stage: PartStage,
    /// Number of completed level-2 push rounds (the pull watermark).
    rounds: u64,
    shape: Vec<usize>,
    /// Static placement from the router: home shard, or per-shard
    /// sub-ranges for oversized keys.
    placement: KeyPlacement,
    cache: Arc<Mutex<PullCache>>,
}

/// Does `reply` pair with `req`?  A mismatch means the stream desynced
/// — the connection is torn down and the RPC retried rather than
/// mis-paired.  Desync from duplicated request frames is prevented at
/// the source: `inject_send` reports how many copies it wrote and
/// `try_rpc` drains one reply per copy.  `Err` is accepted as a reply to
/// any request (the server can answer anything with it), but `try_rpc`
/// tears the connection down before surfacing it so a queued stale `Err`
/// can never be attributed to a later RPC on the same stream.
fn reply_matches(req: &Msg, reply: &Msg) -> bool {
    if matches!(reply, Msg::Err { .. }) {
        return true;
    }
    match req {
        // Key equality matters: a duplicated Pull leaves an extra Value
        // in the socket that must not satisfy a later Pull for another
        // key.
        Msg::Pull { key, .. } => matches!(reply, Msg::Value { key: k, .. } if k == key),
        Msg::Stats => matches!(reply, Msg::StatsReply { .. }),
        _ => matches!(reply, Msg::Ack),
    }
}

/// Counters and resume floors shared by the connection pair of one
/// shard.  Deliberately per-shard (not per-store): each shard server
/// keeps its own dedup floors and barrier generations, so the local
/// counters that mirror them must be independent too — that is what
/// isolates a retry storm or a restart on one shard from the others.
#[derive(Clone)]
struct ConnShared {
    /// Push sequence counter for this shard, fast-forwarded from its
    /// `HelloAck` floor on every dial.
    seq: Arc<AtomicU64>,
    /// Barrier-id counter for this shard, fast-forwarded likewise.
    barrier: Arc<AtomicU64>,
    retries: Arc<AtomicU64>,
    reconnects: Arc<AtomicU64>,
}

impl ConnShared {
    fn new() -> ConnShared {
        ConnShared {
            seq: Arc::new(AtomicU64::new(0)),
            barrier: Arc::new(AtomicU64::new(0)),
            retries: Arc::new(AtomicU64::new(0)),
            reconnects: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// One client connection with reconnect + retry.
struct Conn {
    addr: std::net::SocketAddr,
    cfg: RetryCfg,
    plan: Option<Arc<FaultPlan>>,
    /// Machine id announced with `Hello` on every (re)dial — registers
    /// the lease and folds a previously-expired machine back in.
    hello: Option<u32>,
    /// The `(slot, total)` shard identity this connection expects the
    /// server to advertise in its `HelloAck`.  Enforced only when the
    /// server reports running sharded (`shards > 1`): a harness that
    /// wires shard addresses in the wrong order then fails at connect
    /// instead of silently scattering the key space.
    expect_shard: Option<(u32, u32)>,
    /// Per-shard counters shared with the sibling connection.
    shared: ConnShared,
    stream: Mutex<Option<TcpStream>>,
    jitter: Mutex<Rng>,
    ever_connected: AtomicBool,
}

impl Conn {
    fn new(
        addr: std::net::SocketAddr,
        cfg: RetryCfg,
        plan: Option<Arc<FaultPlan>>,
        hello: Option<u32>,
        expect_shard: Option<(u32, u32)>,
        shared: ConnShared,
    ) -> Conn {
        // Decorrelate backoff jitter across machines *and* shards, so a
        // fleet-wide stall does not retry in lockstep.
        let seed = 0xbac0_0ff
            ^ u64::from(hello.unwrap_or(0))
            ^ (u64::from(expect_shard.map_or(0, |(i, _)| i)) << 32);
        Conn {
            addr,
            cfg,
            plan,
            hello,
            expect_shard,
            shared,
            stream: Mutex::new(None),
            jitter: Mutex::new(Rng::seed_from_u64(seed)),
            ever_connected: AtomicBool::new(false),
        }
    }

    /// Dial the server (with deadline), announce the machine, and store
    /// the stream into `slot`.
    fn dial(&self, slot: &mut Option<TcpStream>) -> Result<()> {
        let mut s = TcpStream::connect_timeout(&self.addr, self.cfg.connect_timeout)?;
        s.set_nodelay(true).ok();
        if self.ever_connected.swap(true, Ordering::Relaxed) {
            self.shared.reconnects.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(machine) = self.hello {
            // Registration is sent clean (never through the fault plan):
            // it models the OS-level connect handshake, and injecting
            // here would make every redial a coin flip.
            s.set_write_timeout(Some(self.cfg.op_timeout)).ok();
            s.set_read_timeout(Some(self.cfg.op_timeout)).ok();
            write_msg(&mut s, &Msg::Hello { machine })?;
            match read_msg(&mut s)? {
                Msg::HelloAck { seq, barrier, shard, shards } => {
                    if shards > 1 {
                        let want = self.expect_shard.unwrap_or((0, 1));
                        if (shard, shards) != want {
                            return Err(Error::kv(format!(
                                "shard mismatch at {}: dialed as slot {}/{} but server \
                                 reports {shard}/{shards} — shard address list misordered?",
                                self.addr, want.0, want.1
                            )));
                        }
                    }
                    // Resume counters above the server's floors.  On a
                    // live redial these are no-ops (our counters are
                    // already past them); on a process restart they jump
                    // the fresh counters past the dead incarnation's.
                    self.shared.seq.fetch_max(seq, Ordering::Relaxed);
                    self.shared.barrier.fetch_max(barrier, Ordering::Relaxed);
                }
                other => return Err(Error::kv(format!("hello: unexpected reply {other:?}"))),
            }
        }
        *slot = Some(s);
        Ok(())
    }

    /// Eagerly establish the connection (used at construction so a bad
    /// address fails fast).
    fn ensure_connected(&self) -> Result<()> {
        let mut slot = lock(&self.stream);
        if slot.is_none() {
            self.dial(&mut slot)?;
        }
        Ok(())
    }

    /// One attempt: send through the fault layer, then read one reply
    /// per frame copy actually written (a duplicated request is answered
    /// twice — draining the extra reply keeps the stream in sync, so no
    /// stale reply can be mis-paired with a later RPC).  Any failure
    /// poisons the stream so the next attempt redials.
    fn try_rpc(&self, msg: &Msg, deadline: Duration) -> Result<Msg> {
        let mut slot = lock(&self.stream);
        if slot.is_none() {
            self.dial(&mut slot)?;
        }
        let s = slot.as_mut().ok_or_else(|| Error::kv("not connected"))?;
        s.set_write_timeout(Some(self.cfg.op_timeout)).ok();
        s.set_read_timeout(Some(deadline)).ok();
        let copies = match &self.plan {
            Some(p) => inject_send(s, msg, p, true),
            None => write_msg(s, msg).map(|()| 1),
        };
        let copies = match copies {
            Ok(n) => n,
            Err(e) => {
                *slot = None;
                return Err(e);
            }
        };
        // A dropped frame (0 copies) still reads once: the read times
        // out, the stream is torn down, and the retry loop redials.
        let mut reply = read_msg(s);
        for _ in 1..copies {
            if reply.is_err() {
                break;
            }
            reply = read_msg(s); // drain the duplicate's reply; keep the last
        }
        match reply {
            Ok(reply) if reply_matches(msg, &reply) => {
                if matches!(reply, Msg::Err { .. }) {
                    // Semantic error: surface it, but start the next RPC
                    // on a fresh stream so a desynced/stale Err can never
                    // leak into a later request's reply slot.
                    *slot = None;
                }
                Ok(reply)
            }
            Ok(reply) => {
                *slot = None;
                Err(Error::kv(format!("desynced reply {reply:?} to {msg:?}")))
            }
            Err(e) => {
                *slot = None;
                Err(e)
            }
        }
    }

    /// RPC with retry: transport failures redial with capped exponential
    /// backoff + jitter; a server `Err` reply is semantic and terminal.
    /// The whole retry loop is one client-RPC span (`a` = attempts
    /// taken, so redials show up as long spans with `a > 1`).
    fn rpc_deadline(&self, msg: &Msg, deadline: Duration) -> Result<Msg> {
        let prof = crate::profile::SpanTimer::start();
        let mut attempt = 0u32;
        let out = loop {
            match self.try_rpc(msg, deadline) {
                Ok(Msg::Err { msg }) => break Err(Error::kv(format!("server: {msg}"))),
                Ok(reply) => break Ok(reply),
                Err(e) => {
                    attempt += 1;
                    if attempt > self.cfg.max_retries {
                        break Err(Error::kv(format!(
                            "rpc failed after {attempt} attempt(s): {e}"
                        )));
                    }
                    self.shared.retries.fetch_add(1, Ordering::Relaxed);
                    let base = self
                        .cfg
                        .backoff_base
                        .saturating_mul(1u32 << (attempt - 1).min(16))
                        .min(self.cfg.backoff_cap);
                    let jitter_ms = {
                        let mut r = lock(&self.jitter);
                        let half = (base.as_millis() as u64 / 2).max(1);
                        r.next_u64() % half
                    };
                    std::thread::sleep(base + Duration::from_millis(jitter_ms));
                }
            }
        };
        let name = rpc_span_name(msg);
        prof.finish(crate::profile::Category::KvClient, name, 0, u64::from(attempt) + 1, 0);
        out
    }

    /// Ordinary RPC (short deadline).
    fn rpc(&self, msg: &Msg) -> Result<Msg> {
        self.rpc_deadline(msg, self.cfg.op_timeout)
    }

    /// RPC that may legitimately park on the server (long deadline).
    fn rpc_park(&self, msg: &Msg) -> Result<Msg> {
        self.rpc_deadline(msg, self.cfg.park_timeout)
    }
}

/// Trace-span name for a client RPC, by request kind.
fn rpc_span_name(msg: &Msg) -> &'static str {
    match msg {
        Msg::Init { .. } => "kv.rpc.init",
        Msg::Push { .. } => "kv.rpc.push",
        Msg::Pull { .. } => "kv.rpc.pull",
        Msg::Barrier { .. } => "kv.rpc.barrier",
        Msg::Stats => "kv.rpc.stats",
        Msg::Hello { .. } => "kv.rpc.hello",
        Msg::Heartbeat { .. } => "kv.rpc.heartbeat",
        Msg::Shutdown => "kv.rpc.shutdown",
        _ => "kv.rpc.other",
    }
}

/// Heartbeat-observed liveness of one shard, updated by the multiplexed
/// heartbeat loop and read by [`DistKVStore::client_stats`].
struct ShardHealth {
    alive: AtomicBool,
    beats: AtomicU64,
}

impl ShardHealth {
    fn new() -> ShardHealth {
        // The store only constructs after every shard dialed
        // successfully, so "alive until proven otherwise" is accurate.
        ShardHealth { alive: AtomicBool::new(true), beats: AtomicU64::new(0) }
    }
}

/// Everything the client holds for one shard: the data/barrier
/// connection pair, the shard's own counters, its heartbeat-observed
/// health, and the engine var that orders this shard's wire ops.
struct ShardConn {
    /// Connection used by engine ops (push/pull).
    conn: Arc<Conn>,
    /// Separate connection for barriers so a parked barrier cannot block
    /// in-flight pull replies.
    barrier_conn: Arc<Conn>,
    /// Per-shard seq/barrier/retry/reconnect counters (shared by the
    /// connection pair, fast-forwarded from this shard's `HelloAck`).
    shared: ConnShared,
    health: Arc<ShardHealth>,
    /// Engine tag owning this shard's wire connection: every push/pull
    /// op touching the shard *writes* it, so the shard's network ops
    /// execute in issue order — while ops bound for different shards
    /// (different vars) schedule freely in parallel.  Without this a
    /// later pull (which the server may park until the round completes)
    /// could run before the push that completes the round — holding the
    /// connection mutex and deadlocking the machine against itself.
    conn_var: crate::engine::VarHandle,
}

/// Client-side two-level KVStore over a sharded server fleet.
pub struct DistKVStore {
    engine: EngineRef,
    machine: u32,
    num_devices: usize,
    /// Factor applied to the level-1 merged gradient before it is
    /// shipped (see [`DistKVStore::with_grad_rescale`]).
    grad_rescale: f32,
    /// Simulated per-message wire transfer time, paid inside each push
    /// op while it holds its shard's connection var
    /// (`PALLAS_KV_WIRE_DELAY_US`, default 0).  Transfers to the SAME
    /// shard serialize behind it, transfers to different shards overlap
    /// — the serialized-wire model `scripts/dist_train.sh` uses to
    /// measure the shard-scaling curve deterministically.
    wire_delay: Duration,
    consistency: Consistency,
    /// Static key -> shard map, identical on every worker.
    router: ShardRouter,
    keys: Mutex<HashMap<String, KeyState>>,
    /// One connection pair + counters per shard, in shard order.
    shards: Vec<ShardConn>,
    /// First error raised inside an engine-scheduled push/pull op; taken
    /// and returned by the next public store call.
    async_err: Arc<Mutex<Option<Error>>>,
    hb_stop: Arc<AtomicBool>,
    hb_thread: Option<JoinHandle<()>>,
}

impl DistKVStore {
    /// Connect to a single level-2 server with retry/fault behavior from
    /// the environment (see [`RetryCfg::from_env`] and
    /// [`FaultPlan::from_env`]).
    pub fn connect(
        addr: std::net::SocketAddr,
        machine: u32,
        num_devices: usize,
        consistency: Consistency,
        engine: EngineRef,
    ) -> Result<DistKVStore> {
        DistKVStore::connect_multi(&[addr], machine, num_devices, consistency, engine)
    }

    /// Connect to a sharded server fleet: `addrs[i]` must be shard `i`
    /// of `addrs.len()` (the ordered list *is* the router contract the
    /// harness and every worker share).  Retry/fault/split knobs come
    /// from the environment; under chaos testing each shard gets its own
    /// deterministic fork of the fault plan (salted by shard index, so
    /// one shard's chaos schedule is independent of its neighbours' —
    /// and a 1-shard fleet replays the unsharded schedule exactly).
    pub fn connect_multi(
        addrs: &[std::net::SocketAddr],
        machine: u32,
        num_devices: usize,
        consistency: Consistency,
        engine: EngineRef,
    ) -> Result<DistKVStore> {
        let plans = (0..addrs.len())
            .map(|i| FaultPlan::from_env().map(|p| Arc::new(p.fork(i as u64))))
            .collect();
        DistKVStore::connect_sharded(
            addrs,
            machine,
            num_devices,
            consistency,
            engine,
            RetryCfg::from_env(),
            plans,
            ShardRouter::from_env(addrs.len()),
        )
    }

    /// [`DistKVStore::connect`] with explicit retry config and fault
    /// plan (the single-shard chaos-test entry point).
    pub fn connect_with(
        addr: std::net::SocketAddr,
        machine: u32,
        num_devices: usize,
        consistency: Consistency,
        engine: EngineRef,
        cfg: RetryCfg,
        plan: Option<Arc<FaultPlan>>,
    ) -> Result<DistKVStore> {
        DistKVStore::connect_sharded(
            &[addr],
            machine,
            num_devices,
            consistency,
            engine,
            cfg,
            vec![plan],
            ShardRouter::new(1),
        )
    }

    /// Fully explicit constructor: one address and one optional fault
    /// plan per shard, plus the router (which must agree on the shard
    /// count).  Every connection is established eagerly so a dead or
    /// misordered shard fails here, not mid-epoch.
    #[allow(clippy::too_many_arguments)] // the per-shard chaos-test entry point
    pub fn connect_sharded(
        addrs: &[std::net::SocketAddr],
        machine: u32,
        num_devices: usize,
        consistency: Consistency,
        engine: EngineRef,
        cfg: RetryCfg,
        plans: Vec<Option<Arc<FaultPlan>>>,
        router: ShardRouter,
    ) -> Result<DistKVStore> {
        if addrs.is_empty() {
            return Err(Error::kv("connect_sharded: empty shard address list"));
        }
        if plans.len() != addrs.len() {
            return Err(Error::kv(format!(
                "connect_sharded: {} fault plan(s) for {} shard(s)",
                plans.len(),
                addrs.len()
            )));
        }
        if router.shards() != addrs.len() {
            return Err(Error::kv(format!(
                "connect_sharded: router spans {} shard(s), address list has {}",
                router.shards(),
                addrs.len()
            )));
        }
        let total = addrs.len() as u32;
        let mut shards = Vec::with_capacity(addrs.len());
        for (i, (&addr, plan)) in addrs.iter().zip(plans.into_iter()).enumerate() {
            let shared = ConnShared::new();
            let expect = Some((i as u32, total));
            let conn = Arc::new(Conn::new(
                addr,
                cfg,
                plan.clone(),
                Some(machine),
                expect,
                shared.clone(),
            ));
            // Barriers park by design; their connection is kept clean of
            // fault injection on dial (hello) but shares the plan for
            // request frames.
            let barrier_conn =
                Arc::new(Conn::new(addr, cfg, plan, Some(machine), expect, shared.clone()));
            conn.ensure_connected()?;
            barrier_conn.ensure_connected()?;
            shards.push(ShardConn {
                conn,
                barrier_conn,
                shared,
                health: Arc::new(ShardHealth::new()),
                conn_var: engine.new_var(),
            });
        }
        let hb_stop = Arc::new(AtomicBool::new(false));
        let hb_thread = cfg
            .heartbeat
            .map(|interval| {
                let stop = Arc::clone(&hb_stop);
                let targets: Vec<_> = addrs
                    .iter()
                    .copied()
                    .zip(shards.iter().map(|s| Arc::clone(&s.health)))
                    .collect();
                std::thread::Builder::new()
                    .name("mixnet-kv-heartbeat".into())
                    .spawn(move || heartbeat_loop(targets, machine, interval, stop))
                    .ok()
            })
            .flatten();
        let wire_delay = std::env::var("PALLAS_KV_WIRE_DELAY_US")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .map_or(Duration::ZERO, Duration::from_micros);
        Ok(DistKVStore {
            engine,
            machine,
            num_devices: num_devices.max(1),
            grad_rescale: 1.0,
            wire_delay,
            consistency,
            router,
            keys: Mutex::new(HashMap::new()),
            shards,
            async_err: Arc::new(Mutex::new(None)),
            hb_stop,
            hb_thread,
        })
    }

    /// Scale the level-1 merged gradient by `f` before shipping it.
    ///
    /// The merge is a *sum* over the machine's device shards; with
    /// mean-normalized per-shard gradients that sum is `devices x` the
    /// global-batch mean, so a data-parallel worker passes
    /// `1.0 / devices` to keep the server-side learning rate meaningful
    /// independent of the local device count (the local trainer achieves
    /// the same via its updater's `rescale`).
    pub fn with_grad_rescale(mut self, f: f32) -> Self {
        self.grad_rescale = f;
        self
    }

    /// Number of server shards this store fans out to.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The static key -> shard map in effect.
    pub fn router(&self) -> &ShardRouter {
        &self.router
    }

    /// Per-shard server receive/dedup/lease counters, in shard order —
    /// one `Msg::Stats` RPC per shard (uses the barrier connections:
    /// plain synchronous RPCs that must not interleave with
    /// engine-scheduled push/pull frames on the data connections).
    pub fn server_stats_sharded(&self) -> Result<Vec<ServerStats>> {
        self.shards
            .iter()
            .map(|sh| match sh.barrier_conn.rpc(&Msg::Stats)? {
                Msg::StatsReply { msgs, bytes, dedup_hits, lease_expiries, applies } => {
                    Ok(ServerStats { msgs, bytes, dedup_hits, lease_expiries, applies })
                }
                other => Err(Error::kv(format!("stats: unexpected reply {other:?}"))),
            })
            .collect()
    }

    /// Fleet-wide server counters: the sum over every shard's
    /// `StatsReply` — so harness observability and `--stats-every`
    /// report the whole fleet, not one shard posing as it.
    pub fn server_stats(&self) -> Result<ServerStats> {
        let mut sum = ServerStats::default();
        for s in self.server_stats_sharded()? {
            sum.add(&s);
        }
        Ok(sum)
    }

    /// Client-side transport counters: fleet sums plus the per-shard
    /// breakdown (liveness, heartbeats, retries, reconnects).
    pub fn client_stats(&self) -> ClientStats {
        let shards: Vec<ShardStats> = self
            .shards
            .iter()
            .map(|sh| ShardStats {
                alive: sh.health.alive.load(Ordering::Relaxed),
                heartbeats: sh.health.beats.load(Ordering::Relaxed),
                retries: sh.shared.retries.load(Ordering::Relaxed),
                reconnects: sh.shared.reconnects.load(Ordering::Relaxed),
            })
            .collect();
        ClientStats {
            retries: shards.iter().map(|s| s.retries).sum(),
            reconnects: shards.iter().map(|s| s.reconnects).sum(),
            shards,
        }
    }

    /// Surface (and clear) the first error captured inside an
    /// engine-scheduled push/pull op.
    fn take_async_err(&self) -> Result<()> {
        match lock(&self.async_err).take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Epoch barrier across machines, fanned out to every shard
    /// concurrently (each shard's id counter is its own — monotonic,
    /// idempotent server-side on retransmission, and fast-forwarded past
    /// that shard's released floor on restart).  Returns once *all*
    /// shards released their barrier; the first failure wins.
    pub fn barrier(&self) -> Result<()> {
        self.take_async_err()?;
        let machine = self.machine;
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter()
                .map(|sh| {
                    scope.spawn(move || -> Result<()> {
                        let id = sh.shared.barrier.fetch_add(1, Ordering::Relaxed) + 1;
                        match sh.barrier_conn.rpc_park(&Msg::Barrier { id, machine })? {
                            Msg::Ack => Ok(()),
                            other => {
                                Err(Error::kv(format!("barrier: unexpected reply {other:?}")))
                            }
                        }
                    })
                })
                .collect();
            let mut first = Ok(());
            for h in handles {
                let r = h
                    .join()
                    .unwrap_or_else(|_| Err(Error::kv("barrier fan-out thread panicked")));
                if first.is_ok() {
                    first = r;
                }
            }
            first
        })
    }
}

impl Drop for DistKVStore {
    fn drop(&mut self) {
        self.hb_stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.hb_thread.take() {
            let _ = t.join();
        }
    }
}

/// Multiplexed lease keep-alive loop: ONE thread round-robins every
/// shard on its own connection per shard (never fault-injected, so
/// injected chaos on the data path cannot spuriously expire a live
/// machine), reconnecting per shard on failure at heartbeat cadence.
/// Updates each shard's [`ShardHealth`] so `client_stats()` reports
/// per-shard liveness.
fn heartbeat_loop(
    targets: Vec<(std::net::SocketAddr, Arc<ShardHealth>)>,
    machine: u32,
    interval: Duration,
    stop: Arc<AtomicBool>,
) {
    let mut streams: Vec<Option<TcpStream>> = targets.iter().map(|_| None).collect();
    let mut elapsed = Duration::ZERO;
    let tick = Duration::from_millis(10);
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        elapsed += tick;
        if elapsed < interval {
            continue;
        }
        elapsed = Duration::ZERO;
        for ((addr, health), slot) in targets.iter().zip(streams.iter_mut()) {
            if slot.is_none() {
                if let Ok(s) = TcpStream::connect_timeout(addr, interval) {
                    s.set_nodelay(true).ok();
                    s.set_read_timeout(Some(interval)).ok();
                    s.set_write_timeout(Some(interval)).ok();
                    *slot = Some(s);
                } else {
                    health.alive.store(false, Ordering::Relaxed);
                    continue;
                }
            }
            if let Some(s) = slot.as_mut() {
                let ok = write_msg(s, &Msg::Heartbeat { machine })
                    .and_then(|_| read_msg(s))
                    .is_ok();
                health.alive.store(ok, Ordering::Relaxed);
                if ok {
                    health.beats.fetch_add(1, Ordering::Relaxed);
                } else {
                    *slot = None;
                }
            }
        }
    }
}

impl KVStore for DistKVStore {
    fn init(&self, key: &str, value: &NDArray) -> Result<()> {
        self.take_async_err()?;
        let placement = self.router.place(key, value.size());
        {
            let mut keys = lock(&self.keys);
            if keys.contains_key(key) {
                return Err(Error::kv(format!("key '{key}' already initialized")));
            }
            keys.insert(
                key.to_string(),
                KeyState {
                    accum: NDArray::zeros_on(value.shape(), self.engine.clone()),
                    pushed: 0,
                    stage: PartStage::new(self.num_devices),
                    rounds: 0,
                    shape: value.shape().to_vec(),
                    placement: placement.clone(),
                    cache: Arc::new(Mutex::new(PullCache {
                        version: u64::MAX,
                        data: Vec::new(),
                    })),
                },
            );
        }
        // Synchronous init (first writer wins on each server).  A split
        // key initializes each shard with exactly its sub-range.
        let data = value.to_vec();
        match &placement {
            KeyPlacement::Whole(home) => {
                match self.shards[*home].conn.rpc(&Msg::Init { key: key.to_string(), value: data })?
                {
                    Msg::Ack => Ok(()),
                    other => Err(Error::kv(format!("init: unexpected reply {other:?}"))),
                }
            }
            KeyPlacement::Split(ranges) => {
                for rg in ranges {
                    if rg.len == 0 {
                        continue; // same skip as placement_ranges
                    }
                    let slice = data[rg.offset..rg.offset + rg.len].to_vec();
                    match self.shards[rg.shard]
                        .conn
                        .rpc(&Msg::Init { key: key.to_string(), value: slice })?
                    {
                        Msg::Ack => {}
                        other => {
                            return Err(Error::kv(format!(
                                "init '{key}' shard {}: unexpected reply {other:?}",
                                rg.shard
                            )))
                        }
                    }
                }
                Ok(())
            }
        }
    }

    fn push(&self, key: &str, grad: &NDArray, _device: usize) -> Result<()> {
        self.take_async_err()?;
        let mut keys = lock(&self.keys);
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        if st.stage.in_progress() {
            return Err(Error::kv(format!("key '{key}': round mixes push and push_part")));
        }
        if st.pushed == 0 {
            st.accum.zero_();
        }
        st.accum.add_(grad); // level-1 aggregation (engine op)
        st.pushed += 1;
        if st.pushed == self.num_devices {
            st.pushed = 0;
            st.rounds += 1;
            // level-2: ship ONE aggregated message per involved shard,
            // inside engine ops reading the accumulation buffer.  Seqs
            // are taken here, on the caller thread, so per-shard wire
            // order equals program order whatever the engine does.
            let rescale = self.grad_rescale;
            let machine = self.machine;
            let wire = self.wire_delay;
            let ranges = placement_ranges(&st.placement, st.shape.iter().product());
            for (shard, off, len) in ranges {
                let sh = &self.shards[shard];
                let conn = Arc::clone(&sh.conn);
                let err_slot = Arc::clone(&self.async_err);
                let key = key.to_string();
                let seq = sh.shared.seq.fetch_add(1, Ordering::Relaxed) + 1;
                let accum = st.accum.clone();
                let storage = accum.storage();
                self.engine.push(
                    "kv.dist_push",
                    vec![accum.var()],
                    vec![sh.conn_var],
                    Box::new(move || {
                        let mut value =
                            unsafe { storage.slice() }[off..off + len].to_vec();
                        if rescale != 1.0 {
                            for v in value.iter_mut() {
                                *v *= rescale;
                            }
                        }
                        if wire > Duration::ZERO {
                            std::thread::sleep(wire);
                        }
                        if let Err(e) = conn.rpc(&Msg::Push { key, value, machine, seq }) {
                            let mut g = lock(&err_slot);
                            g.get_or_insert(e);
                        }
                    }),
                );
            }
        }
        Ok(())
    }

    fn push_part(&self, key: &str, grad: &[f32], part: usize) -> Result<()> {
        self.take_async_err()?;
        let mut keys = lock(&self.keys);
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        if st.pushed > 0 {
            return Err(Error::kv(format!("key '{key}': round mixes push and push_part")));
        }
        let n: usize = st.shape.iter().product();
        let parts = match st.stage.stage(key, grad, part, n)? {
            None => return Ok(()),
            Some(parts) => parts,
        };
        st.rounds += 1;
        // Round complete: ship ONE aggregated message per involved
        // shard, each reducing its own sub-range of the staged parts in
        // part-index order — bitwise identical to reducing the whole
        // array and slicing it, because the reduce is elementwise.  The
        // ops write only their shard's connection var, so the transfers
        // overlap whatever backward is still running AND each other.
        let rescale = self.grad_rescale;
        let machine = self.machine;
        let wire = self.wire_delay;
        let ranges = placement_ranges(&st.placement, n);
        let parts = Arc::new(parts);
        for (shard, off, len) in ranges {
            let sh = &self.shards[shard];
            let conn = Arc::clone(&sh.conn);
            let err_slot = Arc::clone(&self.async_err);
            let key = key.to_string();
            let seq = sh.shared.seq.fetch_add(1, Ordering::Relaxed) + 1;
            let parts = Arc::clone(&parts);
            self.engine.push(
                "kv.dist_push_parts",
                vec![],
                vec![sh.conn_var],
                Box::new(move || {
                    let mut value = vec![0.0f32; len];
                    for (i, part) in parts.iter().enumerate() {
                        let src = &part[off..off + len];
                        if i == 0 {
                            value.copy_from_slice(src);
                        } else {
                            for (d, s) in value.iter_mut().zip(src.iter()) {
                                *d += *s;
                            }
                        }
                    }
                    if rescale != 1.0 {
                        for v in value.iter_mut() {
                            *v *= rescale;
                        }
                    }
                    if wire > Duration::ZERO {
                        std::thread::sleep(wire);
                    }
                    if let Err(e) = conn.rpc(&Msg::Push { key, value, machine, seq }) {
                        let mut g = lock(&err_slot);
                        g.get_or_insert(e);
                    }
                    // The last shard op holding the staged buffers
                    // returns them to the pool.  If two finishers race
                    // the unwrap both fail and the buffers drop to the
                    // allocator instead — a benign missed recycle.
                    if let Ok(parts) = Arc::try_unwrap(parts) {
                        for p in parts {
                            crate::ndarray::pool::global().release(p);
                        }
                    }
                }),
            );
        }
        Ok(())
    }

    fn pull(&self, key: &str, out: &NDArray, _device: usize) -> Result<()> {
        self.take_async_err()?;
        let (after_version, shape, placement, cache) = {
            let keys = lock(&self.keys);
            let st = keys.get(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
            let v = match self.consistency {
                Consistency::Sequential => st.rounds,
                // Staleness ceiling: the server parks the pull until its
                // version reaches `rounds - k` — the level-2 analogue of
                // the local store's snapshot wait (server.rs watermark).
                Consistency::BoundedDelay(k) => st.rounds.saturating_sub(k),
                Consistency::Eventual => 0,
            };
            (v, st.shape.clone(), st.placement.clone(), Arc::clone(&st.cache))
        };
        if out.shape() != shape.as_slice() {
            return Err(Error::kv(format!(
                "pull '{key}': out shape {:?} != {:?}",
                out.shape(),
                shape
            )));
        }
        // Sequential / bounded-delay pulls within one round all wait on
        // the same watermark: serve repeats (other devices' pulls of
        // this round) from the version-stamped cache when the cached
        // server version already satisfies the watermark, so only one
        // RPC per shard crosses the wire per (key, round).  Eventual
        // pulls always refetch — their whole point is best-effort
        // freshness.
        let use_cache = self.consistency != Consistency::Eventual;
        let err_slot = Arc::clone(&self.async_err);
        let key = key.to_string();
        let storage = out.storage();
        let n: usize = shape.iter().product();
        // (offset, len, conn) for every sub-range; whole keys are one
        // full-width range on the home shard.  The op writes the
        // destination var plus every involved shard's connection var, so
        // it is ordered after the pushes that complete the round on each
        // of those shards.
        let mut writes = vec![out.var()];
        let targets: Vec<(usize, usize, Arc<Conn>)> = placement_ranges(&placement, n)
            .into_iter()
            .map(|(shard, off, len)| {
                writes.push(self.shards[shard].conn_var);
                (off, len, Arc::clone(&self.shards[shard].conn))
            })
            .collect();
        self.engine.push(
            "kv.dist_pull",
            vec![],
            writes,
            Box::new(move || {
                if use_cache {
                    let c = lock(&cache);
                    if c.version != u64::MAX
                        && c.version >= after_version
                        && c.data.len() == storage.len()
                    {
                        unsafe { storage.slice_mut() }.copy_from_slice(&c.data);
                        return;
                    }
                }
                // Fan the per-shard pulls out concurrently; each thread
                // returns its sub-range so the copy into the destination
                // happens sequentially after every join (no aliasing).
                type Fetched = Result<(usize, usize, Vec<f32>, u64)>;
                let results: Vec<Fetched> = std::thread::scope(|scope| {
                    let handles: Vec<_> = targets
                        .iter()
                        .map(|(off, len, conn)| {
                            let key = key.clone();
                            let (off, len) = (*off, *len);
                            let conn = Arc::clone(conn);
                            scope.spawn(move || -> Fetched {
                                match conn
                                    .rpc_park(&Msg::Pull { key: key.clone(), after_version })?
                                {
                                    Msg::Value { value, version, .. } => {
                                        if value.len() != len {
                                            return Err(Error::kv(format!(
                                                "pull '{key}': got {} values, expected {len}",
                                                value.len()
                                            )));
                                        }
                                        Ok((off, len, value, version))
                                    }
                                    other => Err(Error::kv(format!(
                                        "pull '{key}': unexpected reply {other:?}"
                                    ))),
                                }
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| {
                            h.join().unwrap_or_else(|_| {
                                Err(Error::kv("pull fan-out thread panicked"))
                            })
                        })
                        .collect()
                });
                let mut full = vec![0.0f32; storage.len()];
                let mut version = u64::MAX;
                for r in results {
                    match r {
                        Ok((off, len, value, v)) => {
                            full[off..off + len].copy_from_slice(&value);
                            version = version.min(v);
                        }
                        Err(e) => {
                            // Connection failure after retries: leave
                            // the buffer untouched, surface the error.
                            let mut g = lock(&err_slot);
                            g.get_or_insert(e);
                            return;
                        }
                    }
                }
                unsafe { storage.slice_mut() }.copy_from_slice(&full);
                if use_cache {
                    let mut c = lock(&cache);
                    c.version = version;
                    c.data = full;
                }
            }),
        );
        Ok(())
    }

    fn flush(&self) {
        self.engine.wait_all();
    }

    fn num_devices(&self) -> usize {
        self.num_devices
    }

    fn consistency(&self) -> Consistency {
        self.consistency
    }
}

/// Flatten a placement into `(shard, offset, len)` wire targets: a whole
/// key is one full-width range on its home shard; a split key is its
/// per-shard sub-ranges.
fn placement_ranges(placement: &KeyPlacement, len: usize) -> Vec<(usize, usize, usize)> {
    match placement {
        KeyPlacement::Whole(home) => vec![(*home, 0, len)],
        // Drop empty sub-ranges (key smaller than the shard count):
        // init/push/pull all route through here, so the uninvolved
        // shards consistently never hear about the key.
        KeyPlacement::Split(ranges) => ranges
            .iter()
            .filter(|rg| rg.len > 0)
            .map(|rg| (rg.shard, rg.offset, rg.len))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::kvstore::server::{PsServer, ServerConfig, ServerUpdater};

    fn plain_updater() -> ServerUpdater {
        ServerUpdater { lr: 1.0, momentum: 0.0, weight_decay: 0.0, rescale: 1.0 }
    }

    fn shard_cfg(i: u32, n: u32) -> ServerConfig {
        ServerConfig { shard: Some((i, n)), ..ServerConfig::default() }
    }

    #[test]
    fn single_machine_push_pull() {
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 1, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::from_vec_on(&[2], vec![1.0, 1.0], engine.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[2], vec![0.25, 0.5], engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[2], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.75, 0.5]);
    }

    #[test]
    fn level1_aggregation_reduces_messages() {
        // 4 local devices, 1 machine: the server must see ONE push per
        // round (plus the init).
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 4, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::zeros_on(&[8], engine.clone())).unwrap();
        for d in 0..4 {
            kv.push("w", &NDArray::from_vec_on(&[8], vec![1.0; 8], engine.clone()), d).unwrap();
        }
        let out = NDArray::zeros_on(&[8], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // w = 0 - (1+1+1+1) = -4 everywhere
        assert_eq!(out.to_vec(), vec![-4.0; 8]);
        // messages: 1 init + 1 aggregated push + 1 pull = 3
        assert_eq!(srv.messages_received(), 3, "level-1 must aggregate");
    }

    #[test]
    fn two_machines_synchronous_round() {
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let addr = srv.addr();
        let handles: Vec<_> = (0..2u32)
            .map(|m| {
                std::thread::spawn(move || {
                    let engine = create(EngineKind::Threaded, 2);
                    let kv = DistKVStore::connect(
                        addr,
                        m,
                        1,
                        Consistency::Sequential,
                        engine.clone(),
                    )
                    .unwrap();
                    kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
                    kv.push(
                        "w",
                        &NDArray::from_vec_on(&[1], vec![(m + 1) as f32], engine.clone()),
                        0,
                    )
                    .unwrap();
                    let out = NDArray::zeros_on(&[1], engine.clone());
                    kv.pull("w", &out, 0).unwrap();
                    kv.flush();
                    out.to_vec()[0]
                })
            })
            .collect();
        for h in handles {
            // w = 0 - (1 + 2) = -3 for both machines
            assert_eq!(h.join().unwrap(), -3.0);
        }
    }

    #[test]
    fn staged_parts_ship_one_aggregated_message() {
        // push_part deliveries in any order: one wire message per round,
        // reduced in part order.
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 3, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::zeros_on(&[2], engine.clone())).unwrap();
        for part in [2usize, 0, 1] {
            kv.push_part("w", &[part as f32, 1.0], part).unwrap();
        }
        let out = NDArray::zeros_on(&[2], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // lr=1: w = 0 - (0+1+2) and 0 - (1+1+1)
        assert_eq!(out.to_vec(), vec![-3.0, -3.0]);
        assert_eq!(srv.messages_received(), 3, "init + 1 aggregated push + pull");
    }

    #[test]
    fn grad_rescale_scales_the_wire_message() {
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 2, Consistency::Sequential, engine.clone())
                .unwrap()
                .with_grad_rescale(0.5);
        kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
        kv.push_part("w", &[3.0], 0).unwrap();
        kv.push_part("w", &[5.0], 1).unwrap();
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // lr=1: w = 0 - 0.5 * (3 + 5)
        assert_eq!(out.to_vec(), vec![-4.0]);
    }

    #[test]
    fn bounded_delay_pull_relaxes_the_watermark() {
        // 2 machines expected; only this machine pushes.  A sequential
        // pull would park on the incomplete round; BoundedDelay(1)
        // relaxes the watermark to rounds-1 = 0 and returns the last
        // committed weight immediately — staleness <= 1 round.
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv = DistKVStore::connect(
            srv.addr(),
            0,
            1,
            Consistency::BoundedDelay(1),
            engine.clone(),
        )
        .unwrap();
        kv.init("w", &NDArray::from_vec_on(&[1], vec![6.0], engine.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush(); // must NOT deadlock despite the incomplete round
        assert_eq!(out.to_vec(), vec![6.0]);
        let stats = kv.server_stats().unwrap();
        assert!(stats.msgs >= 3, "init + push + pull crossed the wire");
    }

    #[test]
    fn eventual_pull_is_stale_but_fast() {
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 1, Consistency::Eventual, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::from_vec_on(&[1], vec![9.0], engine.clone())).unwrap();
        // push once: round incomplete at the server (2 machines expected)
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], engine.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush(); // must NOT deadlock despite the incomplete round
        assert_eq!(out.to_vec(), vec![9.0]);
    }

    #[test]
    fn barrier_synchronizes_machines() {
        let srv = PsServer::start(0, 2, plain_updater()).unwrap();
        let addr = srv.addr();
        let t0 = std::time::Instant::now();
        let hs: Vec<_> = (0..2u32)
            .map(|m| {
                std::thread::spawn(move || {
                    let engine = create(EngineKind::Threaded, 2);
                    let kv =
                        DistKVStore::connect(addr, m, 1, Consistency::Sequential, engine)
                            .unwrap();
                    if m == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(60));
                    }
                    kv.barrier().unwrap();
                    t0.elapsed()
                })
            })
            .collect();
        let times: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        // both exit the barrier only after the slow machine arrives
        for t in times {
            assert!(t >= std::time::Duration::from_millis(55), "{t:?}");
        }
    }

    #[test]
    fn pull_shape_mismatch_rejected() {
        let srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv =
            DistKVStore::connect(srv.addr(), 0, 1, Consistency::Sequential, engine.clone())
                .unwrap();
        kv.init("w", &NDArray::zeros_on(&[4], engine.clone())).unwrap();
        let bad = NDArray::zeros_on(&[5], engine);
        assert!(kv.pull("w", &bad, 0).is_err());
    }

    /// With no server, connect must fail fast (bounded by the connect
    /// timeout), not hang.
    #[test]
    fn connect_fails_fast_without_server() {
        let engine = create(EngineKind::Threaded, 2);
        let cfg = RetryCfg {
            connect_timeout: Duration::from_millis(200),
            ..RetryCfg::default()
        };
        let addr: std::net::SocketAddr = "127.0.0.1:9".parse().unwrap(); // discard port
        let t0 = std::time::Instant::now();
        let res = DistKVStore::connect_with(
            addr,
            0,
            1,
            Consistency::Sequential,
            engine,
            cfg,
            None,
        );
        assert!(res.is_err());
        assert!(t0.elapsed() < Duration::from_secs(5), "must not hang");
    }

    /// When the server dies mid-run, retries are exhausted and the error
    /// surfaces from the store instead of hanging or panicking.
    #[test]
    fn retries_exhaust_and_surface_error() {
        let mut srv = PsServer::start(0, 1, plain_updater()).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let cfg = RetryCfg {
            connect_timeout: Duration::from_millis(200),
            op_timeout: Duration::from_millis(200),
            park_timeout: Duration::from_millis(200),
            max_retries: 2,
            backoff_base: Duration::from_millis(5),
            ..RetryCfg::default()
        };
        let kv = DistKVStore::connect_with(
            srv.addr(),
            0,
            1,
            Consistency::Sequential,
            engine.clone(),
            cfg,
            None,
        )
        .unwrap();
        kv.init("w", &NDArray::zeros_on(&[1], engine.clone())).unwrap();
        srv.shutdown();
        drop(srv);
        let err = kv.barrier();
        assert!(err.is_err(), "barrier against a dead server must error");
        assert!(kv.client_stats().retries > 0, "the client must have retried first");
    }

    /// Whole keys route to their home shards only; values stay correct
    /// and the fleet sum of messages matches the unsharded count.
    #[test]
    fn sharded_whole_keys_route_to_home_shards() {
        let s0 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(0, 2)).unwrap();
        let s1 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(1, 2)).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let router = ShardRouter::new(2).with_split_elems(0); // never split
        let kv = DistKVStore::connect_sharded(
            &[s0.addr(), s1.addr()],
            0,
            1,
            Consistency::Sequential,
            engine.clone(),
            RetryCfg::default(),
            vec![None, None],
            router.clone(),
        )
        .unwrap();
        assert_eq!(kv.num_shards(), 2);
        let keys = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias", "conv1_weight"];
        for key in keys {
            kv.init(key, &NDArray::zeros_on(&[2], engine.clone())).unwrap();
            kv.push(key, &NDArray::from_vec_on(&[2], vec![1.0, 2.0], engine.clone()), 0)
                .unwrap();
            let out = NDArray::zeros_on(&[2], engine.clone());
            kv.pull(key, &out, 0).unwrap();
            kv.flush();
            assert_eq!(out.to_vec(), vec![-1.0, -2.0], "{key}");
        }
        // Each key cost init + push + pull = 3 messages on its home
        // shard and zero on the other.
        let per_home: usize = keys.iter().map(|_| 3).sum();
        let (m0, m1) = (s0.messages_received() as usize, s1.messages_received() as usize);
        assert_eq!(m0 + m1, per_home, "no duplicate traffic across the fleet");
        let on_home: usize =
            keys.iter().map(|k| if router.home(k) == 0 { 3 } else { 0 }).sum();
        assert_eq!(m0, on_home, "traffic must follow the router's home map");
    }

    /// An oversized key splits across shards: each shard sees exactly
    /// one message per round carrying only its sub-range, and pull
    /// reassembles the full value transparently.
    #[test]
    fn split_key_roundtrip_one_message_per_shard() {
        let s0 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(0, 2)).unwrap();
        let s1 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(1, 2)).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let router = ShardRouter::new(2).with_split_elems(4); // tiny threshold
        let kv = DistKVStore::connect_sharded(
            &[s0.addr(), s1.addr()],
            0,
            2,
            Consistency::Sequential,
            engine.clone(),
            RetryCfg::default(),
            vec![None, None],
            router,
        )
        .unwrap();
        let init: Vec<f32> = (0..6).map(|i| i as f32).collect();
        kv.init("big", &NDArray::from_vec_on(&[6], init, engine.clone())).unwrap();
        // Two devices push 1.0 each -> merged gradient 2.0 per element.
        for d in 0..2 {
            kv.push("big", &NDArray::from_vec_on(&[6], vec![1.0; 6], engine.clone()), d)
                .unwrap();
        }
        let out = NDArray::zeros_on(&[6], engine);
        kv.pull("big", &out, 0).unwrap();
        kv.flush();
        // lr=1: w[i] = i - 2
        assert_eq!(out.to_vec(), vec![-2.0, -1.0, 0.0, 1.0, 2.0, 3.0]);
        // Per shard: 1 init + 1 aggregated push + 1 pull = 3 messages.
        assert_eq!(s0.messages_received(), 3, "shard 0: one message per round");
        assert_eq!(s1.messages_received(), 3, "shard 1: one message per round");
    }

    /// Split keys through the staged `push_part` path reduce each
    /// sub-range in part order — the value matches the unsharded merge.
    #[test]
    fn split_key_push_part_matches_whole_merge() {
        let s0 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(0, 2)).unwrap();
        let s1 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(1, 2)).unwrap();
        let engine = create(EngineKind::Threaded, 4);
        let router = ShardRouter::new(2).with_split_elems(2);
        let kv = DistKVStore::connect_sharded(
            &[s0.addr(), s1.addr()],
            0,
            3,
            Consistency::Sequential,
            engine.clone(),
            RetryCfg::default(),
            vec![None, None],
            router,
        )
        .unwrap();
        kv.init("big", &NDArray::zeros_on(&[4], engine.clone())).unwrap();
        // Rounding-sensitive parts delivered out of order: the per-shard
        // part-order reduce must still produce (1e8 + 1) - 1e8 = 0.
        let vals = [1.0e8f32, 1.0, -1.0e8];
        for part in [2usize, 0, 1] {
            kv.push_part("big", &vec![vals[part]; 4], part).unwrap();
        }
        let out = NDArray::zeros_on(&[4], engine);
        kv.pull("big", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.0; 4], "part-order reduce per sub-range");
        assert_eq!(s0.messages_received(), 3);
        assert_eq!(s1.messages_received(), 3);
    }

    /// A client dialing shard addresses in the wrong order must fail at
    /// connect (the server advertises its identity in `HelloAck`).
    #[test]
    fn misordered_shard_list_fails_at_connect() {
        let s0 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(0, 2)).unwrap();
        let s1 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(1, 2)).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let res = DistKVStore::connect_sharded(
            &[s1.addr(), s0.addr()], // swapped
            0,
            1,
            Consistency::Sequential,
            engine,
            RetryCfg::default(),
            vec![None, None],
            ShardRouter::new(2),
        );
        let err = format!("{:?}", res.err().expect("misordered list must be rejected"));
        assert!(err.contains("shard mismatch"), "{err}");
    }

    /// Barriers fan out to every shard: both shards must observe the
    /// barrier generation (fleet sum of stats proves each was reached).
    #[test]
    fn sharded_barrier_reaches_every_shard() {
        let s0 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(0, 2)).unwrap();
        let s1 = PsServer::start_with(0, 1, plain_updater(), shard_cfg(1, 2)).unwrap();
        let engine = create(EngineKind::Threaded, 2);
        let kv = DistKVStore::connect_sharded(
            &[s0.addr(), s1.addr()],
            0,
            1,
            Consistency::Sequential,
            engine,
            RetryCfg::default(),
            vec![None, None],
            ShardRouter::new(2),
        )
        .unwrap();
        kv.barrier().unwrap();
        let per = kv.server_stats_sharded().unwrap();
        assert_eq!(per.len(), 2);
        assert!(per.iter().all(|s| s.msgs >= 1), "every shard saw its barrier: {per:?}");
        let sum = kv.server_stats().unwrap();
        assert_eq!(sum.msgs, per[0].msgs + per[1].msgs, "summed stats");
        let cs = kv.client_stats();
        assert_eq!(cs.shards.len(), 2, "per-shard client stats");
        assert!(cs.shards.iter().all(|s| s.alive), "both shards alive");
    }
}

//! Key-space partitioning for the sharded parameter server (ISSUE 10).
//!
//! A [`ShardRouter`] is the *static* contract between a training client
//! and the fleet of [`PsServer`](super::server::PsServer) shard
//! processes: given only a key name, its element count, and the shard
//! count, it answers "which shard(s) own this key" — deterministically,
//! with no negotiation, no rebalancing, and no server-side state.  The
//! client ([`DistKVStore`](super::dist::DistKVStore)) and the launch
//! harness (`scripts/dist_train.sh`) share it implicitly through the
//! *ordered shard address list*: shard `i` of `N` is the `i`-th address,
//! every worker computes the same placement, and the servers themselves
//! stay key-agnostic (they store whatever is initialized on them).
//!
//! Placement has two regimes:
//!
//! - **Whole keys** go to one *home* shard chosen by a stable 64-bit
//!   FNV-1a hash of the key name modulo the shard count.  The hash is
//!   part of the protocol: it must never change, or a running fleet and
//!   its clients would disagree about ownership.
//! - **Oversized keys** (vgg's fc6 is ~103M parameters — bigger than
//!   everything else in the net combined) are *split* into one
//!   contiguous element sub-range per shard, using the same first-ranges
//!   -get-the-remainder geometry as the trainer's batch sharding.  Every
//!   shard carries an equal slice of the hot key instead of one shard
//!   carrying the whole straggler — the groundwork for intra-layer model
//!   parallelism.  The split is invisible above the store: `push_part`
//!   and `pull` slice and reassemble transparently.
//!
//! Determinism: placement is a pure function of
//! `(key, len, shards, split_elems)`.  Per-key update order on a shard
//! is machine-index-ordered (see `server::apply_round`), and elementwise
//! SGD on a sub-range is bitwise identical to the same elements updated
//! inside the whole array — so training is **bitwise identical for any
//! shard count** (`tests/sharded.rs` asserts it for shards {1, 2, 4}).

/// Default split threshold in f32 elements: keys at or above this size
/// are range-split across all shards (16 MiB of weights).  Far above
/// every conv/fc layer we train except the vgg-class fc giants, so the
/// common case stays "one key, one shard, one message".
pub const DEFAULT_SPLIT_ELEMS: usize = 1 << 22;

/// One shard's slice of a split key: `len` elements starting at
/// `offset` in the flat f32 array, owned by `shard`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubRange {
    /// Owning shard index.
    pub shard: usize,
    /// Element offset of the slice in the full array.
    pub offset: usize,
    /// Element count of the slice.
    pub len: usize,
}

/// Where a key lives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KeyPlacement {
    /// The whole key lives on one home shard.
    Whole(usize),
    /// The key is range-split: one contiguous sub-range per shard, in
    /// shard order, covering `[0, len)` exactly.
    Split(Vec<SubRange>),
}

/// Deterministic, static key -> shard map (see the module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardRouter {
    shards: usize,
    split_elems: usize,
}

/// Stable FNV-1a 64-bit hash of the key name.  Protocol-stable: changing
/// this function changes every key's home shard.
fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl ShardRouter {
    /// Router over `shards` shards with the default split threshold.
    pub fn new(shards: usize) -> ShardRouter {
        ShardRouter { shards: shards.max(1), split_elems: DEFAULT_SPLIT_ELEMS }
    }

    /// Override the split threshold (`0` disables splitting entirely).
    /// Tests use tiny thresholds to exercise the split path on small
    /// models.
    pub fn with_split_elems(mut self, elems: usize) -> ShardRouter {
        self.split_elems = elems;
        self
    }

    /// Router from the environment: `PALLAS_KV_SPLIT_ELEMS` overrides
    /// the split threshold (every worker must agree on it, like every
    /// other `PALLAS_KV_*` knob the harness exports fleet-wide).
    pub fn from_env(shards: usize) -> ShardRouter {
        let mut r = ShardRouter::new(shards);
        if let Some(n) = std::env::var("PALLAS_KV_SPLIT_ELEMS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
        {
            r.split_elems = n;
        }
        r
    }

    /// Shard count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Split threshold in elements (`0` = never split).
    pub fn split_elems(&self) -> usize {
        self.split_elems
    }

    /// The home shard of `key` (ignoring size-based splitting).
    pub fn home(&self, key: &str) -> usize {
        (fnv1a(key) % self.shards as u64) as usize
    }

    /// Would a key of `len` elements be range-split?
    pub fn splits(&self, len: usize) -> bool {
        self.shards > 1 && self.split_elems > 0 && len >= self.split_elems
    }

    /// Place a key of `len` f32 elements: its home shard, or its
    /// per-shard sub-ranges when oversized.  Pure and static — every
    /// client computes the same answer for the same inputs.
    pub fn place(&self, key: &str, len: usize) -> KeyPlacement {
        if !self.splits(len) {
            return KeyPlacement::Whole(self.home(key));
        }
        // Same geometry as the trainer's `shard_ranges`: base elements
        // per shard, the first `rem` shards carry one extra.
        let base = len / self.shards;
        let rem = len % self.shards;
        let mut ranges = Vec::with_capacity(self.shards);
        let mut off = 0usize;
        for s in 0..self.shards {
            let n = base + usize::from(s < rem);
            ranges.push(SubRange { shard: s, offset: off, len: n });
            off += n;
        }
        debug_assert_eq!(off, len);
        KeyPlacement::Split(ranges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_explain;

    #[test]
    fn home_is_deterministic_and_protocol_stable() {
        let r = ShardRouter::new(4);
        for key in ["fc1_weight", "fc1_bias", "conv3_weight", "w"] {
            assert_eq!(r.home(key), r.home(key));
        }
        // Pinned values: the FNV-1a mapping is part of the wire contract
        // between workers — a silent change here would scatter a running
        // fleet's keys.
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn one_shard_never_splits() {
        let r = ShardRouter::new(1).with_split_elems(8);
        assert_eq!(r.place("huge", 1 << 30), KeyPlacement::Whole(0));
    }

    #[test]
    fn small_keys_stay_whole_and_spread() {
        let r = ShardRouter::new(4).with_split_elems(1024);
        let mut seen = [false; 4];
        for i in 0..64 {
            match r.place(&format!("layer{i}_weight"), 100) {
                KeyPlacement::Whole(s) => seen[s] = true,
                p => panic!("small key split: {p:?}"),
            }
        }
        assert!(seen.iter().all(|&s| s), "64 keys should touch all 4 shards: {seen:?}");
    }

    #[test]
    fn split_ranges_tile_the_key_exactly() {
        check_explain(
            "shard-split-tiles",
            300,
            |r| {
                let shards = 1 + r.below(7);
                let thresh = 1 + r.below(64);
                let len = thresh + r.below(4096);
                (shards, thresh, len)
            },
            |&(shards, thresh, len)| {
                let router = ShardRouter::new(shards).with_split_elems(thresh);
                match router.place("k", len) {
                    KeyPlacement::Whole(s) => {
                        if shards > 1 {
                            return Err(format!("len {len} >= {thresh} must split, got Whole({s})"));
                        }
                        Ok(())
                    }
                    KeyPlacement::Split(ranges) => {
                        if ranges.len() != shards {
                            return Err(format!("{} ranges for {shards} shards", ranges.len()));
                        }
                        let mut off = 0usize;
                        for (s, rg) in ranges.iter().enumerate() {
                            if rg.shard != s {
                                return Err(format!("range {s} owned by shard {}", rg.shard));
                            }
                            if rg.offset != off {
                                return Err(format!(
                                    "range {s} starts at {} expected {off}",
                                    rg.offset
                                ));
                            }
                            off += rg.len;
                        }
                        if off != len {
                            return Err(format!("ranges cover {off} of {len} elements"));
                        }
                        // Balanced to within one element.
                        let min = ranges.iter().map(|r| r.len).min().unwrap();
                        let max = ranges.iter().map(|r| r.len).max().unwrap();
                        if max - min > 1 {
                            return Err(format!("imbalanced split: min {min} max {max}"));
                        }
                        Ok(())
                    }
                }
            },
        );
    }

    #[test]
    fn env_threshold_is_read() {
        // from_env without the knob equals new()
        if std::env::var("PALLAS_KV_SPLIT_ELEMS").is_err() {
            assert_eq!(ShardRouter::from_env(2), ShardRouter::new(2));
        }
    }
}

//! Deterministic fault injection for the distributed transport.
//!
//! A [`FaultPlan`] sits between message encoding and the socket: for each
//! outbound frame a seeded RNG decides whether to deliver it, drop it,
//! delay it, duplicate it, or truncate it mid-frame, and an independent
//! counter can kill the connection after every N frames.  The draw
//! sequence depends only on the seed and the number of frames sent, so a
//! failing chaos run replays exactly.
//!
//! Injection happens on the *send* side (client requests and, optionally,
//! server replies).  Truncation and kills return an error so the caller
//! tears the connection down — the same observable behavior as a peer
//! crashing mid-write.
//!
//! Environment knobs (all optional; a plan is only built when at least
//! one is set):
//!
//! | variable                | meaning                                   |
//! |-------------------------|-------------------------------------------|
//! | `PALLAS_FAULT_SEED`     | RNG seed (default `0xfa17`)               |
//! | `PALLAS_FAULT_DROP`     | per-frame drop probability (0..1)         |
//! | `PALLAS_FAULT_DUP`      | per-frame duplicate probability           |
//! | `PALLAS_FAULT_TRUNC`    | per-frame truncate-and-kill probability   |
//! | `PALLAS_FAULT_DELAY`    | per-frame delay probability               |
//! | `PALLAS_FAULT_DELAY_MS` | delay duration in ms (default 20)         |
//! | `PALLAS_FAULT_KILL_EVERY` | kill the connection after every N frames |

use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::Rng;

use super::wire::{encode, Msg};

/// Snapshot of a plan's injection counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Frames silently discarded.
    pub drops: u64,
    /// Frames sent twice.
    pub dups: u64,
    /// Frames delayed before sending.
    pub delays: u64,
    /// Frames cut mid-write (connection then killed).
    pub truncs: u64,
    /// Connections killed by the every-N counter.
    pub kills: u64,
}

impl FaultStats {
    /// Total faults injected.
    pub fn total(&self) -> u64 {
        self.drops + self.dups + self.delays + self.truncs + self.kills
    }
}

enum Decision {
    Deliver,
    Drop,
    Dup,
    Trunc,
    Delay,
}

/// A seeded, shareable fault-injection plan (see module docs).
pub struct FaultPlan {
    seed: u64,
    drop_p: f32,
    dup_p: f32,
    trunc_p: f32,
    delay_p: f32,
    delay: Duration,
    kill_every: u64,
    rng: Mutex<Rng>,
    sent: AtomicU64,
    drops: AtomicU64,
    dups: AtomicU64,
    delays: AtomicU64,
    truncs: AtomicU64,
    kills: AtomicU64,
}

impl FaultPlan {
    /// A plan that injects nothing until probabilities are configured.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            drop_p: 0.0,
            dup_p: 0.0,
            trunc_p: 0.0,
            delay_p: 0.0,
            delay: Duration::from_millis(20),
            kill_every: 0,
            rng: Mutex::new(Rng::seed_from_u64(seed)),
            sent: AtomicU64::new(0),
            drops: AtomicU64::new(0),
            dups: AtomicU64::new(0),
            delays: AtomicU64::new(0),
            truncs: AtomicU64::new(0),
            kills: AtomicU64::new(0),
        }
    }

    /// Set the per-frame drop probability.
    pub fn with_drop(mut self, p: f32) -> Self {
        self.drop_p = p;
        self
    }

    /// Set the per-frame duplicate probability.
    pub fn with_dup(mut self, p: f32) -> Self {
        self.dup_p = p;
        self
    }

    /// Set the per-frame truncate-and-kill probability.
    pub fn with_trunc(mut self, p: f32) -> Self {
        self.trunc_p = p;
        self
    }

    /// Set the per-frame delay probability and duration.
    pub fn with_delay(mut self, p: f32, delay: Duration) -> Self {
        self.delay_p = p;
        self.delay = delay;
        self
    }

    /// Kill the connection after every `n` frames (0 = never).
    pub fn with_kill_every(mut self, n: u64) -> Self {
        self.kill_every = n;
        self
    }

    /// A fresh plan with the same probabilities but a seed derived from
    /// `salt` — one independent draw sequence per shard connection, so
    /// a sharded client's injection schedule on shard `i` depends only
    /// on shard `i`'s frame count, never on cross-shard interleaving.
    /// `fork(0)` reproduces the original plan exactly (counters reset),
    /// keeping 1-shard chaos runs bit-for-bit compatible.
    pub fn fork(&self, salt: u64) -> FaultPlan {
        let seed = self.seed ^ salt.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        FaultPlan::new(seed)
            .with_drop(self.drop_p)
            .with_dup(self.dup_p)
            .with_trunc(self.trunc_p)
            .with_delay(self.delay_p, self.delay)
            .with_kill_every(self.kill_every)
    }

    /// Build a plan from `PALLAS_FAULT_*` environment knobs; `None` when
    /// no fault knob is set.
    pub fn from_env() -> Option<Arc<FaultPlan>> {
        fn envf(name: &str) -> Option<f32> {
            std::env::var(name).ok()?.trim().parse().ok()
        }
        fn envu(name: &str) -> Option<u64> {
            std::env::var(name).ok()?.trim().parse().ok()
        }
        let drop_p = envf("PALLAS_FAULT_DROP");
        let dup_p = envf("PALLAS_FAULT_DUP");
        let trunc_p = envf("PALLAS_FAULT_TRUNC");
        let delay_p = envf("PALLAS_FAULT_DELAY");
        let kill = envu("PALLAS_FAULT_KILL_EVERY");
        if drop_p.is_none()
            && dup_p.is_none()
            && trunc_p.is_none()
            && delay_p.is_none()
            && kill.is_none()
        {
            return None;
        }
        let delay_ms = envu("PALLAS_FAULT_DELAY_MS").unwrap_or(20);
        let seed = envu("PALLAS_FAULT_SEED").unwrap_or(0xfa17);
        let plan = FaultPlan::new(seed)
            .with_drop(drop_p.unwrap_or(0.0))
            .with_dup(dup_p.unwrap_or(0.0))
            .with_trunc(trunc_p.unwrap_or(0.0))
            .with_delay(delay_p.unwrap_or(0.0), Duration::from_millis(delay_ms))
            .with_kill_every(kill.unwrap_or(0));
        Some(Arc::new(plan))
    }

    /// Counter snapshot.
    pub fn stats(&self) -> FaultStats {
        FaultStats {
            drops: self.drops.load(Ordering::Relaxed),
            dups: self.dups.load(Ordering::Relaxed),
            delays: self.delays.load(Ordering::Relaxed),
            truncs: self.truncs.load(Ordering::Relaxed),
            kills: self.kills.load(Ordering::Relaxed),
        }
    }

    /// One seeded draw deciding this frame's fate.
    fn decide(&self) -> Decision {
        let x = self.rng.lock().unwrap_or_else(|p| p.into_inner()).next_f32();
        let mut edge = self.drop_p;
        if x < edge {
            return Decision::Drop;
        }
        edge += self.dup_p;
        if x < edge {
            return Decision::Dup;
        }
        edge += self.trunc_p;
        if x < edge {
            return Decision::Trunc;
        }
        edge += self.delay_p;
        if x < edge {
            return Decision::Delay;
        }
        Decision::Deliver
    }
}

/// Send one frame through the fault layer.  `allow_dup` guards duplicate
/// injection: requests may be duplicated (the server deduplicates by
/// sequence number), replies must not be (a doubled reply would desync
/// the client's request/reply framing rather than model a network fault).
///
/// Returns the number of complete copies of the frame actually written
/// (0 = dropped, 2 = duplicated): the peer will answer each copy, so the
/// sender must read exactly that many replies to keep the stream in
/// sync.  An `Err` return means the connection must be treated as dead.
pub fn inject_send<W: Write>(
    w: &mut W,
    msg: &Msg,
    plan: &FaultPlan,
    allow_dup: bool,
) -> Result<usize> {
    let frame = encode(msg);
    let nth = plan.sent.fetch_add(1, Ordering::Relaxed) + 1;
    let kill = plan.kill_every > 0 && nth % plan.kill_every == 0;
    let copies = match plan.decide() {
        Decision::Drop => {
            plan.drops.fetch_add(1, Ordering::Relaxed);
            0
        }
        Decision::Trunc => {
            plan.truncs.fetch_add(1, Ordering::Relaxed);
            let half = frame.len() / 2;
            w.write_all(&frame[..half])?;
            w.flush()?;
            return Err(Error::kv("fault: frame truncated, connection killed"));
        }
        Decision::Dup if allow_dup => {
            plan.dups.fetch_add(1, Ordering::Relaxed);
            w.write_all(&frame)?;
            w.write_all(&frame)?;
            w.flush()?;
            2
        }
        Decision::Delay => {
            plan.delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(plan.delay);
            w.write_all(&frame)?;
            w.flush()?;
            1
        }
        Decision::Deliver | Decision::Dup => {
            w.write_all(&frame)?;
            w.flush()?;
            1
        }
    };
    if kill {
        plan.kills.fetch_add(1, Ordering::Relaxed);
        return Err(Error::kv("fault: connection killed"));
    }
    Ok(copies)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same seed + same frame count = same injection sequence.
    #[test]
    fn plans_are_deterministic() {
        let run = |seed: u64| {
            let plan = FaultPlan::new(seed).with_drop(0.3).with_dup(0.2).with_kill_every(5);
            let mut sink = Vec::new();
            let mut outcomes = Vec::new();
            for i in 0..50u64 {
                let msg = Msg::Barrier { id: i, machine: 0 };
                outcomes.push(inject_send(&mut sink, &msg, &plan, true).is_ok());
            }
            (outcomes, plan.stats())
        };
        let (o1, s1) = run(42);
        let (o2, s2) = run(42);
        assert_eq!(o1, o2);
        assert_eq!(s1, s2);
        let (o3, _) = run(43);
        assert_ne!(o1, o3, "different seeds should diverge");
    }

    /// `fork(0)` replays the original plan; nonzero salts diverge (one
    /// independent schedule per shard connection).
    #[test]
    fn fork_is_deterministic_per_salt() {
        let run = |plan: FaultPlan| {
            let mut sink = Vec::new();
            let mut outcomes = Vec::new();
            for i in 0..50u64 {
                let msg = Msg::Barrier { id: i, machine: 0 };
                outcomes.push(inject_send(&mut sink, &msg, &plan, true).is_ok());
            }
            outcomes
        };
        let base = FaultPlan::new(42).with_drop(0.3).with_dup(0.2).with_kill_every(5);
        let o0a = run(base.fork(0));
        let o0b = run(base.fork(0));
        let o1 = run(base.fork(1));
        let orig = run(base);
        assert_eq!(o0a, orig, "fork(0) must replay the original plan");
        assert_eq!(o0a, o0b);
        assert_ne!(o0a, o1, "different salts should diverge");
    }

    #[test]
    fn kill_every_fires_on_schedule() {
        let plan = FaultPlan::new(1).with_kill_every(3);
        let mut sink = Vec::new();
        let mut killed = 0;
        for i in 0..9u64 {
            let msg = Msg::Barrier { id: i, machine: 0 };
            if inject_send(&mut sink, &msg, &plan, true).is_err() {
                killed += 1;
            }
        }
        assert_eq!(killed, 3);
        assert_eq!(plan.stats().kills, 3);
    }

    #[test]
    fn dup_suppressed_for_replies() {
        let plan = FaultPlan::new(7).with_dup(1.0);
        let mut sink = Vec::new();
        inject_send(&mut sink, &Msg::Ack, &plan, false).unwrap();
        assert_eq!(sink.len(), encode(&Msg::Ack).len(), "reply must be sent exactly once");
        assert_eq!(plan.stats().dups, 0);
    }

    #[test]
    fn env_plan_absent_without_knobs() {
        // Never set in the test environment.
        assert!(std::env::var("PALLAS_FAULT_DROP").is_err());
        assert!(FaultPlan::from_env().is_none() || std::env::var("PALLAS_FAULT_SEED").is_ok());
    }
}
